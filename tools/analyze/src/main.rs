//! Repo-specific static analysis for the CCE codebase (no external deps).
//!
//! Rules:
//! - R1-safety: every line containing an `unsafe` token (block, fn, impl)
//!   must carry a `// SAFETY:` justification — trailing on the same line or
//!   on the run of comment/attribute/blank lines immediately above. Doc
//!   comments with a `# Safety` section also count (public `unsafe fn`).
//! - R2-ordering: every `Ordering::{Relaxed,Acquire,Release,AcqRel,SeqCst}`
//!   site must carry a `// ORDERING:` justification (same placement rules).
//! - R3-determinism: inside the deterministic chunk-merge regions
//!   (`rust/src/kmeans/**`, `rust/src/util/threadpool.rs`) no wall-clock or
//!   RNG calls (`Instant::now`, `SystemTime::now`, `thread_rng`,
//!   `from_entropy`) may appear outside `#[cfg(test)]` code.
//! - R4-bench-sync: every bench-JSON field name asserted by the schema
//!   checks in `scripts/verify.sh` must exist as a string literal in the
//!   bench that emits it (`benches/perf_cluster.rs` for BENCH_cluster.json,
//!   `benches/perf_hot_paths.rs` for BENCH_serving.json).
//!
//! Exit status: 0 when the tree is clean, 1 when any violation is found.
//! Usage: `cargo run -p analyze -- [--root <repo-root>]`.

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

// ---------------------------------------------------------------------------
// Lexer: split each source line into code text and comment text
// ---------------------------------------------------------------------------

/// One source line with string/char literals blanked out of `code` and all
/// comment text (line + block, doc or not) collected into `comment`.
#[derive(Debug, Default, Clone)]
struct Line {
    code: String,
    comment: String,
}

#[derive(Clone, Copy)]
enum St {
    Normal,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

fn strip_lines(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = vec![Line::default()];
    let mut st = St::Normal;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Normal;
            }
            lines.push(Line::default());
            i += 1;
            continue;
        }
        let cur = lines.last_mut().expect("line buffer is never empty");
        match st {
            St::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    st = St::LineComment;
                    cur.code.push(' ');
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(1);
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.code.push(' ');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((hashes, skip)) = raw_str_open(&chars, i) {
                        st = St::RawStr(hashes);
                        cur.code.push(' ');
                        i += skip;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if let Some(skip) = char_literal_len(&chars, i) {
                        cur.code.push(' ');
                        i += skip;
                    } else {
                        // lifetime marker: keep as code
                        cur.code.push(c);
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            St::BlockComment(d) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if d == 1 { St::Normal } else { St::BlockComment(d - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::BlockComment(d + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // consume the escape; an escaped newline keeps its line break
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    st = St::Normal;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && count_hashes(&chars, i + 1) >= h {
                    st = St::Normal;
                    i += 1 + h as usize;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// If `chars[i..]` opens a raw (byte) string (`r"`, `r#"`, `br##"`, ...),
/// return (hash count, chars to skip past the opening quote).
fn raw_str_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut h = 0u32;
    while chars.get(j) == Some(&'#') {
        h += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((h, j + 1 - i))
    } else {
        None
    }
}

fn count_hashes(chars: &[char], mut i: usize) -> u32 {
    let mut h = 0u32;
    while chars.get(i) == Some(&'#') {
        h += 1;
        i += 1;
    }
    h
}

/// If `chars[i]` opens a char literal (not a lifetime), return its length.
fn char_literal_len(chars: &[char], i: usize) -> Option<usize> {
    if chars.get(i) != Some(&'\'') {
        return None;
    }
    if chars.get(i + 1) == Some(&'\\') {
        let mut j = i + 2;
        while j < chars.len() && j < i + 14 {
            if chars[j] == '\'' {
                return Some(j + 1 - i);
            }
            j += 1;
        }
        None
    } else if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// Token helpers
// ---------------------------------------------------------------------------

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Word-boundary token search over stripped code text.
fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut start = 0;
    while let Some(pos) = code[start..].find(tok) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let after = p + tok.len();
        let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

const ORDERING_VARIANTS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// True if the stripped code references a memory-ordering constant.
fn has_ordering_site(code: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find("Ordering::") {
        let p = start + pos;
        let rest = &code[p + "Ordering::".len()..];
        for v in ORDERING_VARIANTS {
            if rest.starts_with(v) {
                let tail = &rest[v.len()..];
                if !tail.starts_with(|c: char| c.is_alphanumeric() || c == '_') {
                    return true;
                }
            }
        }
        start = p + 1;
    }
    false
}

/// True if the line itself, or the run of comment/attribute/blank lines
/// immediately above it, carries one of the `markers`.
fn justified(lines: &[Line], line: usize, markers: &[&str]) -> bool {
    let hit = |l: &Line| markers.iter().any(|m| l.comment.contains(m));
    if hit(&lines[line]) {
        return true;
    }
    let mut i = line;
    while i > 0 {
        i -= 1;
        let l = &lines[i];
        if hit(l) {
            return true;
        }
        let code = l.code.trim();
        let passthrough = code.is_empty() || code.starts_with("#[") || code.starts_with("#![");
        if !passthrough {
            return false;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// Rules R1–R3 (per-file)
// ---------------------------------------------------------------------------

/// A violation inside one file: (1-based line, rule id, message).
type FileViolation = (usize, &'static str, String);

const DETERMINISM_BANNED: [&str; 4] =
    ["Instant::now", "SystemTime::now", "thread_rng", "from_entropy"];

/// True for files under the deterministic chunk-merge contract (R3).
fn is_determinism_region(relpath: &str) -> bool {
    let p = relpath.replace('\\', "/");
    p.contains("rust/src/kmeans/") || p.ends_with("rust/src/util/threadpool.rs")
}

fn check_file(relpath: &str, src: &str) -> Vec<FileViolation> {
    let lines = strip_lines(src);
    let mut out = Vec::new();

    // first line of `#[cfg(test)]`: code after it is exempt from R3
    let test_start = lines
        .iter()
        .position(|l| l.code.contains("#[cfg(test)]"))
        .unwrap_or(lines.len());
    let deterministic = is_determinism_region(relpath);

    for (idx, line) in lines.iter().enumerate() {
        if has_token(&line.code, "unsafe") && !justified(&lines, idx, &["SAFETY:", "# Safety"]) {
            out.push((
                idx + 1,
                "R1-safety",
                "`unsafe` without a `// SAFETY:` justification".to_string(),
            ));
        }
        if has_ordering_site(&line.code) && !justified(&lines, idx, &["ORDERING:"]) {
            out.push((
                idx + 1,
                "R2-ordering",
                "atomic `Ordering::` site without a `// ORDERING:` justification".to_string(),
            ));
        }
        if deterministic && idx < test_start {
            for banned in DETERMINISM_BANNED {
                if line.code.contains(banned) {
                    out.push((
                        idx + 1,
                        "R3-determinism",
                        format!("`{banned}` inside a deterministic chunk-merge region"),
                    ));
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule R4: verify.sh schema checks <-> bench JSON field names
// ---------------------------------------------------------------------------

/// Extract candidate JSON field names from a python schema-check snippet:
/// `.get("x")`, `ident["x"]` / `]["x"]` indexing, and the string tuple of a
/// `for key in (...)` loop (possibly spanning lines).
fn extract_fields(py: &str) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    let mut push = |s: &str| {
        if is_fieldish(s) && !out.iter().any(|x| x == s) {
            out.push(s.to_string());
        }
    };

    // .get("x")
    let mut start = 0;
    while let Some(pos) = py[start..].find(".get(\"") {
        let p = start + pos + ".get(\"".len();
        if let Some(end) = py[p..].find('"') {
            push(&py[p..p + end]);
        }
        start = p;
    }

    // ident["x"] or ]["x"] or )["x"]
    let mut start = 0;
    while let Some(pos) = py[start..].find("[\"") {
        let p = start + pos;
        let prev = py[..p].bytes().rev().find(|b| !b.is_ascii_whitespace());
        let indexing = matches!(prev, Some(b) if is_ident_byte(b) || b == b']' || b == b')');
        if indexing {
            let q = p + 2;
            if let Some(end) = py[q..].find('"') {
                push(&py[q..q + end]);
            }
        }
        start = p + 2;
    }

    // for key in ("a", "b", ...):  — tuple may span lines
    let mut start = 0;
    while let Some(pos) = py[start..].find("for key in (") {
        let mut p = start + pos + "for key in (".len();
        let bytes = py.as_bytes();
        let mut depth = 1u32;
        while p < bytes.len() && depth > 0 {
            match bytes[p] {
                b'(' => depth += 1,
                b')' => depth -= 1,
                b'"' => {
                    if let Some(end) = py[p + 1..].find('"') {
                        push(&py[p + 1..p + 1 + end]);
                        p += 1 + end;
                    }
                }
                _ => {}
            }
            p += 1;
        }
        start = p;
    }
    out
}

/// Field-name shape: lowercase start, then lowercase/digits/underscore.
/// Filters out schema version strings, mode values with hyphens, etc.
fn is_fieldish(s: &str) -> bool {
    let mut it = s.chars();
    matches!(it.next(), Some(c) if c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Check every field asserted in a verify.sh section exists as a `"literal"`
/// in the bench source that emits the corresponding JSON document.
fn check_bench_sync(
    verify_section: &str,
    bench_name: &str,
    bench_src: &str,
) -> Vec<(String, String)> {
    extract_fields(verify_section)
        .into_iter()
        .filter(|f| !bench_src.contains(&format!("\"{f}\"")))
        .map(|f| {
            let msg = format!("verify.sh asserts field \"{f}\" but {bench_name} never emits it");
            (f, msg)
        })
        .collect()
}

/// Marker separating the BENCH_cluster checks from the BENCH_serving checks.
const SERVING_MARKER: &str = "== BENCH_serving.json well-formed ==";

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

const SCAN_DIRS: [&str; 5] = ["rust/src", "benches", "tests", "examples", "tools/analyze/src"];

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name == "target" || name == ".git" || name == "bench_results" {
            continue;
        }
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if name.ends_with(".rs") {
            out.push(p);
        }
    }
}

struct Report {
    files: usize,
    violations: Vec<String>,
}

fn analyze_root(root: &Path) -> Report {
    let mut violations = Vec::new();
    let mut files = 0usize;

    for dir in SCAN_DIRS {
        let mut rs = Vec::new();
        collect_rs_files(&root.join(dir), &mut rs);
        for path in rs {
            let Ok(src) = fs::read_to_string(&path) else { continue };
            files += 1;
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            for (line, rule, msg) in check_file(&rel, &src) {
                violations.push(format!("{rel}:{line}: [{rule}] {msg}"));
            }
        }
    }

    // R4: verify.sh <-> bench field sync
    let verify = fs::read_to_string(root.join("scripts/verify.sh")).unwrap_or_default();
    if verify.is_empty() {
        violations.push("scripts/verify.sh: [R4-bench-sync] missing or unreadable".to_string());
    } else {
        let (cluster_sec, serving_sec) = match verify.find(SERVING_MARKER) {
            Some(p) => verify.split_at(p),
            None => (verify.as_str(), ""),
        };
        let pairs = [
            (cluster_sec, "benches/perf_cluster.rs"),
            (serving_sec, "benches/perf_hot_paths.rs"),
        ];
        for (section, bench) in pairs {
            let bench_src = fs::read_to_string(root.join(bench)).unwrap_or_default();
            for (_, msg) in check_bench_sync(section, bench, &bench_src) {
                violations.push(format!("scripts/verify.sh: [R4-bench-sync] {msg}"));
            }
        }
    }

    Report { files, violations }
}

fn main() -> ExitCode {
    let mut root = env::current_dir().expect("cwd");
    let mut args = env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => {
                let v = args.next().expect("--root needs a path");
                root = PathBuf::from(v);
            }
            other => {
                eprintln!("analyze: unknown argument `{other}`");
                return ExitCode::FAILURE;
            }
        }
    }

    let report = analyze_root(&root);
    if report.violations.is_empty() {
        println!(
            "analyze: OK ({} files clean: SAFETY/ORDERING/determinism/bench-sync)",
            report.files
        );
        ExitCode::SUCCESS
    } else {
        for v in &report.violations {
            eprintln!("{v}");
        }
        eprintln!(
            "analyze: {} violation(s) in {} files scanned",
            report.violations.len(),
            report.files
        );
        ExitCode::FAILURE
    }
}

// ---------------------------------------------------------------------------
// Self-tests: each rule must catch a seeded violation and pass a clean twin
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lexer_strips_comments_and_strings() {
        let src = "let a = 1; // trailing note\nlet s = \"unsafe Ordering::Relaxed\";\n";
        let lines = strip_lines(src);
        assert!(lines[0].code.contains("let a = 1;"));
        assert!(!lines[0].code.contains("trailing"));
        assert!(lines[0].comment.contains("trailing note"));
        assert!(!lines[1].code.contains("unsafe"));
        assert!(!lines[1].code.contains("Ordering"));
    }

    #[test]
    fn lexer_handles_raw_strings_and_nesting() {
        let src = "let r = r#\"unsafe \" quote\"#; /* outer /* unsafe */ still */ let b = 2;\n";
        let lines = strip_lines(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].code.contains("let b = 2;"));
        assert!(lines[0].comment.contains("still"));
    }

    #[test]
    fn lexer_keeps_lifetimes_but_blanks_char_literals() {
        let src = "fn f<'a>(x: &'a u8) -> char { '\"' }\n";
        let lines = strip_lines(src);
        assert!(lines[0].code.contains("<'a>"));
        assert!(!lines[0].code.contains('"'));
    }

    #[test]
    fn r1_flags_uncommented_unsafe_block() {
        let bad = "fn f(p: *mut u8) {\n    unsafe { *p = 1 };\n}\n";
        let v = check_file("rust/src/x.rs", bad);
        assert!(v.iter().any(|(l, r, _)| *l == 2 && *r == "R1-safety"), "{v:?}");
    }

    #[test]
    fn r1_accepts_safety_comment_above_and_through_attributes() {
        let good = "fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid\n    \
                    #[allow(unused)]\n    unsafe { *p = 1 };\n}\n";
        assert!(check_file("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn r1_accepts_safety_doc_section_on_unsafe_fn() {
        let good = "/// Does a thing.\n///\n/// # Safety\n/// `p` must be valid.\n\
                    pub unsafe fn f(p: *mut u8) {}\n";
        assert!(check_file("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn r1_ignores_unsafe_in_strings_and_attr_names() {
        let good = "#![deny(unsafe_op_in_unsafe_fn)]\nlet s = \"unsafe\";\n";
        assert!(check_file("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn r2_flags_unjustified_ordering() {
        let bad = "fn f(a: &AtomicU64) {\n    a.load(Ordering::Acquire);\n}\n";
        let v = check_file("rust/src/x.rs", bad);
        assert!(v.iter().any(|(l, r, _)| *l == 2 && *r == "R2-ordering"), "{v:?}");
    }

    #[test]
    fn r2_accepts_trailing_and_above_justifications() {
        let good = "fn f(a: &AtomicU64) {\n    a.load(Ordering::Acquire); // ORDERING: pairs \
                    with the Release store in install()\n    // ORDERING: counter, read after \
                    join\n    a.load(Ordering::Relaxed);\n}\n";
        assert!(check_file("rust/src/x.rs", good).is_empty());
    }

    #[test]
    fn r3_flags_wall_clock_in_determinism_region_only() {
        let bad = "fn f() { let t = Instant::now(); }\n";
        let v = check_file("rust/src/kmeans/lloyd.rs", bad);
        assert!(v.iter().any(|(_, r, _)| *r == "R3-determinism"), "{v:?}");
        // same source outside the region is fine
        assert!(check_file("rust/src/serving/engine.rs", bad).is_empty());
    }

    #[test]
    fn r3_exempts_test_code() {
        let good = "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let t = \
                    Instant::now(); }\n}\n";
        assert!(check_file("rust/src/util/threadpool.rs", good).is_empty());
    }

    #[test]
    fn r4_extracts_fields_and_flags_drift() {
        let verify = "assert doc.get(\"schema\") == \"cce.v1\"\nfor r in results:\n    \
                      for key in (\"mean_ns\",\n                \"p50_ns\"):\n        \
                      assert r[key] >= 0\nassert r[\"name\"] and tb[0][\"speedup\"] >= 10\n";
        let fields = extract_fields(verify);
        for f in ["schema", "mean_ns", "p50_ns", "name", "speedup"] {
            assert!(fields.iter().any(|x| x == f), "missing {f} in {fields:?}");
        }
        // schema version string and non-field literals are filtered out
        assert!(!fields.iter().any(|x| x == "cce.v1"));

        let bench = "m.insert(\"schema\", ..); m.insert(\"mean_ns\", ..); \
                     m.insert(\"p50_ns\", ..); m.insert(\"name\", ..);";
        let drift = check_bench_sync(verify, "bench.rs", bench);
        assert_eq!(drift.len(), 1, "{drift:?}");
        assert_eq!(drift[0].0, "speedup");
    }

    /// The repo itself must pass every rule clean (acceptance criterion).
    #[test]
    fn real_repo_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
        if !root.join("scripts/verify.sh").exists() {
            return; // detached build: nothing to scan
        }
        let report = analyze_root(&root);
        assert!(
            report.violations.is_empty(),
            "repo has analyze violations:\n{}",
            report.violations.join("\n")
        );
        assert!(report.files >= 20, "expected to scan the repo, saw {}", report.files);
    }

    /// Seeded-violation end-to-end check: a tree with an uncommented unsafe
    /// block, an unjustified Ordering, and a bench/schema drift must fail.
    #[test]
    fn seeded_violations_are_caught() {
        let dir = std::env::temp_dir().join(format!("analyze_seed_{}", std::process::id()));
        let src_dir = dir.join("rust/src");
        let scripts = dir.join("scripts");
        let benches = dir.join("benches");
        for d in [&src_dir, &scripts, &benches] {
            fs::create_dir_all(d).unwrap();
        }
        fs::write(
            src_dir.join("bad.rs"),
            "fn f(p: *mut u8, a: &AtomicU64) {\n    unsafe { *p = 1 };\n    \
             a.load(Ordering::Relaxed);\n}\n",
        )
        .unwrap();
        fs::write(
            scripts.join("verify.sh"),
            "assert doc.get(\"phantom_field\") == 1\n",
        )
        .unwrap();
        fs::write(benches.join("perf_cluster.rs"), "// emits nothing\n").unwrap();
        fs::write(benches.join("perf_hot_paths.rs"), "// emits nothing\n").unwrap();

        let report = analyze_root(&dir);
        fs::remove_dir_all(&dir).ok();

        let has = |rule: &str| report.violations.iter().any(|v| v.contains(rule));
        assert!(has("R1-safety"), "{:?}", report.violations);
        assert!(has("R2-ordering"), "{:?}", report.violations);
        assert!(has("R4-bench-sync"), "{:?}", report.violations);
    }
}
