//! Algorithm 2 — Sparse CCE for least squares.
//!
//! Each iteration rebuilds the sparse sketch `H = [A | C]`:
//!   * `A` (d₁ × k_clusters) — one-hot K-means assignments of the rows of
//!     the current estimate `T = H_{i−1} M_{i−1}` (the *learned* half);
//!   * `C` (d₁ × sketch_width) — a fresh count-sketch (the *random* half);
//! then refits `M = argmin ‖X H M − Y‖_F`. This is the least-squares
//! analogue of Algorithm 3's `h_i ← assignments, h'_i ← fresh hash`.

use crate::hashing::{SignHash, UniversalHash};
use crate::kmeans::{kmeans, KmeansConfig};
use crate::linalg::{lstsq, Matrix};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct SparseCceOptions {
    /// total sketch width k = clusters + sketch_width
    pub k: usize,
    /// columns reserved for the fresh count-sketch each iteration
    pub sketch_width: usize,
    pub iterations: usize,
    /// K-means Lloyd iterations per clustering
    pub kmeans_iters: usize,
    /// apply ±1 count-sketch signs to C (can be disabled; see Appendix D)
    pub signs: bool,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct SparseCceTrace {
    /// loss after each iteration (index 0 = initial random sketch)
    pub losses: Vec<f64>,
    /// final dense estimate `T = H M`
    pub t: Matrix,
    /// number of 1s per row of the final H (diagnostics: 2 for [A|C])
    pub nnz_per_row: usize,
}

/// Run Algorithm 2. `x: n×d₁`, `y: n×d₂`.
pub fn sparse_cce(x: &Matrix, y: &Matrix, opts: &SparseCceOptions) -> SparseCceTrace {
    let (d1, d2) = (x.cols, y.cols);
    assert!(opts.sketch_width < opts.k, "sketch_width must leave room for clusters");
    let clusters = opts.k - opts.sketch_width;
    assert!(opts.k < d1, "k must be < d1");
    let mut rng = Rng::new(opts.seed);

    // iteration 0: pure random sketch (the Hashing-Trick starting point)
    let mut h = count_sketch(&mut rng, d1, opts.k, opts.signs);
    let mut m = lstsq(&x.matmul(&h), y);
    let mut t = h.matmul(&m);
    let mut losses = vec![x.matmul(&t).sub(y).fro2()];

    for it in 0..opts.iterations {
        // cluster the rows of the current dense estimate T (d₁ points in d₂ dims)
        let pts: Vec<f32> = t.data.iter().map(|&v| v as f32).collect();
        let res = kmeans(
            &pts,
            d2,
            &KmeansConfig {
                k: clusters,
                n_iter: opts.kmeans_iters,
                seed: opts.seed ^ (it as u64 + 1).wrapping_mul(0x9E37),
                ..Default::default()
            },
        );
        // A: one-hot assignments; C: fresh count-sketch
        let mut new_h = Matrix::zeros(d1, opts.k);
        for (row, &a) in res.assignments.iter().enumerate() {
            new_h[(row, a as usize)] = 1.0;
        }
        if opts.sketch_width > 0 {
            let c = count_sketch(&mut rng, d1, opts.sketch_width, opts.signs);
            for row in 0..d1 {
                for j in 0..opts.sketch_width {
                    new_h[(row, clusters + j)] = c[(row, j)];
                }
            }
        }
        h = new_h;
        m = lstsq(&x.matmul(&h), y);
        t = h.matmul(&m);
        losses.push(x.matmul(&t).sub(y).fro2());
    }
    let nnz = if opts.sketch_width > 0 { 2 } else { 1 };
    SparseCceTrace { losses, t, nnz_per_row: nnz }
}

/// A count-sketch matrix: one ±1 per row (Appendix D).
fn count_sketch(rng: &mut Rng, d1: usize, width: usize, signs: bool) -> Matrix {
    let h = UniversalHash::new(rng, width as u32);
    let s = SignHash::new(rng);
    let mut m = Matrix::zeros(d1, width);
    for row in 0..d1 {
        let col = h.hash(row as u32) as usize;
        m[(row, col)] = if signs { s.sign(row as u32) as f64 } else { 1.0 };
    }
    m
}

/// The paper's Figure 1b comparators: factorize the OPTIMAL dense solution
/// `T*` post-hoc with K-means (1 one per row), returning the loss — i.e.
/// Product Quantization applied after solving the full problem.
pub fn pq_factorized_loss(
    x: &Matrix,
    y: &Matrix,
    k: usize,
    kmeans_iters: usize,
    seed: u64,
) -> f64 {
    let t_star = lstsq(x, y);
    let d2 = y.cols;
    let pts: Vec<f32> = t_star.data.iter().map(|&v| v as f32).collect();
    let res = kmeans(
        &pts,
        d2,
        &KmeansConfig { k, n_iter: kmeans_iters, seed, ..Default::default() },
    );
    let mut h = Matrix::zeros(x.cols, k);
    for (row, &a) in res.assignments.iter().enumerate() {
        h[(row, a as usize)] = 1.0;
    }
    // refit M on the compressed column space (strictly better than using
    // the centroids directly)
    let m = lstsq(&x.matmul(&h), y);
    x.matmul(&h.matmul(&m)).sub(y).fro2()
}

/// Figure 1b's "two 1s per row" comparator: factorize T* with
/// `H = [A | C]` — K-means assignments of T*'s rows plus a count-sketch —
/// and refit M. Strictly more expressive than the 1-nnz PQ above.
pub fn pq2_factorized_loss(
    x: &Matrix,
    y: &Matrix,
    k: usize,
    sketch_width: usize,
    kmeans_iters: usize,
    seed: u64,
) -> f64 {
    assert!(sketch_width < k);
    let clusters = k - sketch_width;
    let t_star = lstsq(x, y);
    let d2 = y.cols;
    let pts: Vec<f32> = t_star.data.iter().map(|&v| v as f32).collect();
    let res = kmeans(
        &pts,
        d2,
        &KmeansConfig { k: clusters, n_iter: kmeans_iters, seed, ..Default::default() },
    );
    let mut h = Matrix::zeros(x.cols, k);
    for (row, &a) in res.assignments.iter().enumerate() {
        h[(row, a as usize)] = 1.0;
    }
    let mut rng = Rng::new(seed ^ 0x2222);
    let c = count_sketch(&mut rng, x.cols, sketch_width, false);
    for row in 0..x.cols {
        for j in 0..sketch_width {
            h[(row, clusters + j)] = c[(row, j)];
        }
    }
    let m = lstsq(&x.matmul(&h), y);
    x.matmul(&h.matmul(&m)).sub(y).fro2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cce::optimal_loss;

    fn problem(seed: u64, n: usize, d1: usize, d2: usize) -> (Matrix, Matrix) {
        // clusterable T*: Y = X T_true with T_true rows drawn from few prototypes
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(&mut rng, n, d1);
        let protos = Matrix::randn(&mut rng, 8, d2);
        let mut t_true = Matrix::zeros(d1, d2);
        for i in 0..d1 {
            let p = rng.below(8) as usize;
            for j in 0..d2 {
                t_true[(i, j)] = protos[(p, j)] + 0.05 * rng.normal();
            }
        }
        let y = x.matmul(&t_true).add(&Matrix::randn(&mut rng, n, d2).scale(0.1));
        (x, y)
    }

    #[test]
    fn improves_over_pure_sketch() {
        let (x, y) = problem(0, 200, 80, 4);
        let tr = sparse_cce(
            &x,
            &y,
            &SparseCceOptions {
                k: 24, sketch_width: 8, iterations: 6, kmeans_iters: 25, signs: false, seed: 1,
            },
        );
        let first = tr.losses[0];
        let last = *tr.losses.last().unwrap();
        assert!(last < first * 0.8, "losses {:?}", tr.losses);
    }

    #[test]
    fn moves_toward_pq_of_optimal_solution() {
        // CCE never sees T*; the paper (Fig. 1) notes convergence toward
        // the post-hoc factorization takes many iterations, so the test
        // asserts steady movement toward it, not arrival.
        let (x, y) = problem(2, 250, 100, 4);
        let opt = optimal_loss(&x, &y);
        let pq = pq_factorized_loss(&x, &y, 16, 25, 3);
        assert!(pq >= opt);
        let run = |iters| {
            let tr = sparse_cce(
                &x,
                &y,
                &SparseCceOptions {
                    k: 24, sketch_width: 8, iterations: iters, kmeans_iters: 25,
                    signs: false, seed: 4,
                },
            );
            *tr.losses.last().unwrap() - opt
        };
        let e0 = run(0);
        let e8 = run(8);
        let e30 = run(30);
        assert!(e8 < e0 * 0.6, "8 iters: {e8} vs initial {e0}");
        assert!(e30 < e8 * 0.5, "30 iters: {e30} vs 8 iters {e8}");
    }

    #[test]
    fn signs_variant_runs() {
        let (x, y) = problem(5, 100, 40, 3);
        let tr = sparse_cce(
            &x,
            &y,
            &SparseCceOptions {
                k: 12, sketch_width: 4, iterations: 3, kmeans_iters: 10, signs: true, seed: 6,
            },
        );
        assert_eq!(tr.losses.len(), 4);
        assert!(tr.losses.iter().all(|l| l.is_finite()));
        assert_eq!(tr.nnz_per_row, 2);
    }

    #[test]
    fn pure_clustering_variant_has_one_nnz() {
        let (x, y) = problem(7, 100, 40, 3);
        let tr = sparse_cce(
            &x,
            &y,
            &SparseCceOptions {
                k: 12, sketch_width: 0, iterations: 2, kmeans_iters: 10, signs: false, seed: 8,
            },
        );
        assert_eq!(tr.nnz_per_row, 1);
    }
}
