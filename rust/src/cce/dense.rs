//! Algorithm 1 — Dense CCE for least squares.
//!
//! Iterates `H_i = [T_{i-1} | G_i]`, `M_i = argmin ‖X H_i M − Y‖`,
//! `T_i = H_i M_i`, where `G_i` is fresh noise of width `k − d₂`. Theorem
//! 3.1 proves `E‖XT_i − Y‖²` approaches the optimum at rate
//! `(1 − ρ)^{i(k−d₂)}`.
//!
//! Variants (paper Appendix B / Figure 6):
//!   * `NoiseKind::Iid` — `G ~ N(0,1)`, the base algorithm.
//!   * `NoiseKind::Smart` — `G = V Σ⁻¹ G'` (SVD-aligned), improving the
//!     rate to `(1 − 1/d₁)^{i(k−d₂)}`.
//!   * `half_update: true` — restrict `M_i = [I | M']` (only fit the noise
//!     block), the form the proof analyzes; `false` fits the full `M_i`.

use crate::linalg::{lstsq, svd, Matrix};
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseKind {
    Iid,
    /// SVD-aligned ("smart") noise
    Smart,
}

#[derive(Clone, Debug)]
pub struct DenseCceOptions {
    /// sketch width k (must satisfy d₂ < k < d₁)
    pub k: usize,
    pub iterations: usize,
    pub noise: NoiseKind,
    /// restrict M to the proof's `[I | M']` form
    pub half_update: bool,
    pub seed: u64,
}

/// Per-iteration trace of the run.
#[derive(Clone, Debug)]
pub struct DenseCceTrace {
    /// loss ‖XT_i − Y‖²_F after each iteration (index 0 = T₀ = 0)
    pub losses: Vec<f64>,
    /// final factor T (d₁ × d₂)
    pub t: Matrix,
}

/// Run Algorithm 1. `x: n×d₁`, `y: n×d₂`.
pub fn dense_cce(x: &Matrix, y: &Matrix, opts: &DenseCceOptions) -> DenseCceTrace {
    let (d1, d2) = (x.cols, y.cols);
    assert!(
        d2 < opts.k && opts.k < d1,
        "need d2 < k < d1, got d2={d2} k={} d1={d1}",
        opts.k
    );
    let mut rng = Rng::new(opts.seed);
    let g_width = opts.k - d2;

    // smart noise needs V Σ⁻¹ once
    let v_sinv = (opts.noise == NoiseKind::Smart).then(|| {
        let dec = svd(x);
        // V diag(1/σ) — σ=0 columns get 0 (null directions carry no loss)
        let mut vs = dec.v.clone();
        for j in 0..vs.cols {
            let s = dec.s[j];
            let inv = if s > 1e-12 * dec.s[0] { 1.0 / s } else { 0.0 };
            for i in 0..vs.rows {
                vs[(i, j)] *= inv;
            }
        }
        vs
    });

    let mut t = Matrix::zeros(d1, d2);
    let mut losses = Vec::with_capacity(opts.iterations + 1);
    losses.push(x.matmul(&t).sub(y).fro2());
    for _ in 0..opts.iterations {
        let g0 = Matrix::randn(&mut rng, d1, g_width);
        let g = match &v_sinv {
            None => g0,
            Some(vs) => vs.matmul(&Matrix::randn(&mut rng, vs.cols, g_width)),
        };
        let h = t.hcat(&g); // d₁ × k
        let xh = x.matmul(&h); // n × k
        let m = if opts.half_update {
            // M = [I | M'] with M' = argmin ‖X(T + G M') − Y‖
            let resid = y.sub(&x.matmul(&t));
            let xg = xh.cols_range(d2, opts.k);
            let m_prime = lstsq(&xg, &resid); // (k−d₂) × d₂
            let mut m = Matrix::zeros(opts.k, d2);
            for i in 0..d2 {
                m[(i, i)] = 1.0;
            }
            for i in 0..g_width {
                for j in 0..d2 {
                    m[(d2 + i, j)] = m_prime[(i, j)];
                }
            }
            m
        } else {
            lstsq(&xh, y)
        };
        t = h.matmul(&m);
        losses.push(x.matmul(&t).sub(y).fro2());
    }
    DenseCceTrace { losses, t }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cce::optimal_loss;

    fn problem(seed: u64, n: usize, d1: usize, d2: usize) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        (Matrix::randn(&mut rng, n, d1), Matrix::randn(&mut rng, n, d2))
    }

    #[test]
    fn loss_is_monotone_nonincreasing_full_update() {
        let (x, y) = problem(0, 120, 40, 4);
        let tr = dense_cce(
            &x,
            &y,
            &DenseCceOptions { k: 12, iterations: 15, noise: NoiseKind::Iid, half_update: false, seed: 1 },
        );
        for w in tr.losses.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-9), "{:?}", tr.losses);
        }
    }

    #[test]
    fn converges_toward_optimum() {
        let (x, y) = problem(2, 120, 30, 3);
        let opt = optimal_loss(&x, &y);
        let tr = dense_cce(
            &x,
            &y,
            &DenseCceOptions { k: 15, iterations: 40, noise: NoiseKind::Iid, half_update: false, seed: 3 },
        );
        let excess0 = tr.losses[0] - opt;
        let excess_end = tr.losses.last().unwrap() - opt;
        assert!(excess_end < excess0 * 0.01, "excess {excess_end} vs initial {excess0}");
    }

    #[test]
    fn smart_noise_converges_at_least_as_fast() {
        // low-rank-plus-noise X, the Figure 6 setup, averaged over seeds
        let mut rng = Rng::new(4);
        let b = Matrix::randn(&mut rng, 100, 10);
        let c = Matrix::randn(&mut rng, 10, 30);
        let x = b.matmul(&c).add(&Matrix::randn(&mut rng, 100, 30).scale(0.05));
        let y = Matrix::randn(&mut rng, 100, 3);
        let opt = optimal_loss(&x, &y);
        let mut exc_iid = 0.0;
        let mut exc_smart = 0.0;
        for seed in 0..5 {
            let base = DenseCceOptions {
                k: 8, iterations: 25, noise: NoiseKind::Iid, half_update: false, seed,
            };
            exc_iid += dense_cce(&x, &y, &base).losses.last().unwrap() - opt;
            let smart = DenseCceOptions { noise: NoiseKind::Smart, ..base };
            exc_smart += dense_cce(&x, &y, &smart).losses.last().unwrap() - opt;
        }
        assert!(
            exc_smart <= exc_iid * 1.5,
            "smart {exc_smart} much worse than iid {exc_iid}"
        );
    }

    #[test]
    fn half_update_still_converges() {
        let (x, y) = problem(5, 100, 25, 2);
        let opt = optimal_loss(&x, &y);
        let tr = dense_cce(
            &x,
            &y,
            &DenseCceOptions { k: 10, iterations: 60, noise: NoiseKind::Iid, half_update: true, seed: 6 },
        );
        let excess = tr.losses.last().unwrap() - opt;
        assert!(excess < (tr.losses[0] - opt) * 0.05, "excess {excess}");
    }

    #[test]
    #[should_panic(expected = "need d2 < k < d1")]
    fn rejects_bad_k() {
        let (x, y) = problem(7, 50, 10, 4);
        dense_cce(
            &x,
            &y,
            &DenseCceOptions { k: 3, iterations: 1, noise: NoiseKind::Iid, half_update: false, seed: 0 },
        );
    }
}
