//! CCE for least squares — the paper's Section 3 algorithms and the
//! Theorem 3.1 machinery, implemented over the in-repo linalg substrate.
//!
//! These are the *theoretical* CCE variants the paper uses to prove
//! convergence (and to generate Figures 1b, 6 and 8); the production
//! variant over DLRM lives in `coordinator::cluster`.

mod dense;
mod sparse;
pub mod theory;

pub use dense::{dense_cce, DenseCceOptions, DenseCceTrace, NoiseKind};
pub use sparse::{pq2_factorized_loss, pq_factorized_loss, sparse_cce, SparseCceOptions, SparseCceTrace};

use crate::linalg::Matrix;

/// Loss `‖X·T − Y‖²_F` of a candidate factorization `T = H·M`.
pub fn factored_loss(x: &Matrix, h: &Matrix, m: &Matrix, y: &Matrix) -> f64 {
    x.matmul(&h.matmul(m)).sub(y).fro2()
}

/// The optimal unfactored loss `min_T ‖XT − Y‖²_F` (the floor every CCE
/// variant approaches).
pub fn optimal_loss(x: &Matrix, y: &Matrix) -> f64 {
    let t = crate::linalg::lstsq(x, y);
    x.matmul(&t).sub(y).fro2()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn optimal_loss_zero_for_consistent_system() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(&mut rng, 30, 10);
        let t = Matrix::randn(&mut rng, 10, 3);
        let y = x.matmul(&t);
        assert!(optimal_loss(&x, &y) < 1e-16 * y.fro2());
    }

    #[test]
    fn factored_loss_matches_direct() {
        let mut rng = Rng::new(1);
        let x = Matrix::randn(&mut rng, 20, 8);
        let h = Matrix::randn(&mut rng, 8, 4);
        let m = Matrix::randn(&mut rng, 4, 2);
        let y = Matrix::randn(&mut rng, 20, 2);
        let direct = x.matmul(&h).matmul(&m).sub(&y).fro2();
        assert!((factored_loss(&x, &h, &m, &y) - direct).abs() < 1e-9);
    }
}
