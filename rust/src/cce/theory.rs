//! Theorem 3.1 — the convergence bound and its ingredients, used by the
//! Figure 8 bench to plot measured loss against the proven envelope.
//!
//!   E‖X T_i − Y‖²_F ≤ (1 − ρ)^{i(k−d₂)} ‖X T*‖²_F + ‖X T* − Y‖²_F
//!
//! with ρ = σ_min(X)² / ‖X‖²_F, and the improved ρ = 1/d₁ for the
//! SVD-aligned noise variant (Corollary B.1 / Appendix B discussion).

use crate::linalg::{lstsq, svd, Matrix};

/// Ingredients of the bound for a concrete (X, Y) instance.
#[derive(Clone, Debug)]
pub struct BoundParams {
    /// ρ = σ_min²/‖X‖²_F
    pub rho: f64,
    /// the improved rate constant 1/d₁ (smart noise)
    pub rho_smart: f64,
    /// ‖X T*‖²_F — the decaying term's scale
    pub signal: f64,
    /// ‖X T* − Y‖²_F — the irreducible floor
    pub floor: f64,
}

pub fn bound_params(x: &Matrix, y: &Matrix) -> BoundParams {
    let dec = svd(x);
    let t_star = lstsq(x, y);
    let xt = x.matmul(&t_star);
    BoundParams {
        rho: dec.rho(),
        rho_smart: 1.0 / x.cols as f64,
        signal: xt.fro2(),
        floor: xt.sub(y).fro2(),
    }
}

impl BoundParams {
    /// Bound after `i` iterations of width-`k` sketches for output dim d₂.
    pub fn bound_at(&self, i: usize, k: usize, d2: usize, smart: bool) -> f64 {
        let rho = if smart { self.rho_smart } else { self.rho };
        let exponent = (i * (k - d2)) as f64;
        (1.0 - rho).powf(exponent) * self.signal + self.floor
    }

    /// Iterations needed for a (1+ε) approximation per the paper:
    /// i = O((d₁/k)·log(1/ε)) under the smart rate.
    pub fn iters_for_eps(&self, k: usize, d2: usize, eps: f64) -> usize {
        let rho = self.rho_smart;
        // (1−ρ)^{i(k−d₂)} ≤ ε·floor/signal
        let target = (eps * self.floor.max(1e-300) / self.signal.max(1e-300)).ln();
        let per_iter = ((k - d2) as f64) * (1.0 - rho).ln();
        (target / per_iter).ceil().max(1.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cce::{dense_cce, DenseCceOptions, NoiseKind};
    use crate::util::Rng;

    #[test]
    fn bound_decreases_to_floor() {
        let mut rng = Rng::new(0);
        let x = Matrix::randn(&mut rng, 80, 20);
        let y = Matrix::randn(&mut rng, 80, 3);
        let bp = bound_params(&x, &y);
        assert!(bp.rho > 0.0 && bp.rho <= bp.rho_smart + 1e-12);
        let b0 = bp.bound_at(0, 10, 3, false);
        let b5 = bp.bound_at(5, 10, 3, false);
        let b50 = bp.bound_at(50, 10, 3, false);
        assert!(b0 > b5 && b5 > b50);
        assert!(b50 >= bp.floor);
        assert!((b0 - (bp.signal + bp.floor)).abs() < 1e-9);
    }

    #[test]
    fn measured_loss_respects_the_bound_in_expectation() {
        // average dense-CCE losses over seeds; they must sit at or below
        // the theory envelope (the bound holds in expectation)
        let mut rng = Rng::new(1);
        let x = Matrix::randn(&mut rng, 100, 25);
        let y = Matrix::randn(&mut rng, 100, 2);
        let bp = bound_params(&x, &y);
        let k = 10;
        let iters = 8;
        let n_seeds = 8;
        let mut mean_losses = vec![0.0; iters + 1];
        for seed in 0..n_seeds {
            let tr = dense_cce(
                &x,
                &y,
                &DenseCceOptions {
                    k, iterations: iters, noise: NoiseKind::Iid, half_update: true, seed,
                },
            );
            for (i, &l) in tr.losses.iter().enumerate() {
                mean_losses[i] += l / n_seeds as f64;
            }
        }
        for (i, &l) in mean_losses.iter().enumerate() {
            let b = bp.bound_at(i, k, 2, false);
            assert!(
                l <= b * 1.15, // slack for finite-sample noise
                "iteration {i}: mean loss {l} above bound {b}"
            );
        }
    }

    #[test]
    fn iters_for_eps_scales_like_log() {
        let mut rng = Rng::new(2);
        let x = Matrix::randn(&mut rng, 60, 20);
        let y = Matrix::randn(&mut rng, 60, 2);
        let bp = bound_params(&x, &y);
        let i1 = bp.iters_for_eps(10, 2, 1e-1);
        let i2 = bp.iters_for_eps(10, 2, 1e-2);
        let i4 = bp.iters_for_eps(10, 2, 1e-4);
        assert!(i1 <= i2 && i2 <= i4);
        // log scaling: doubling the digits roughly doubles the extra iterations
        assert!((i4 - i2) as f64 <= 2.5 * (i2 - i1).max(1) as f64 + 2.0);
    }
}
