//! Per-buffer training session over one DLRM artifact.
//!
//! The flat host state is split across one device buffer per layout
//! group (`pool` / `dense` / `metrics`, see `manifest.buffers` and
//! docs/CALLING_CONVENTION.md). `train` takes one parameter per group
//! and returns a tuple root, re-fed buffer-for-buffer with no host
//! round-trips; metrics are read by downloading the 16-byte metrics
//! buffer directly (the manifest still ships a `readout` HLO for older
//! tooling, but the session never compiles it).
//!
//! `pull_field`/`set_field` move only the device buffer holding the
//! field: a clustering event's pull → cluster → patch round trip costs
//! pool-buffer bytes on the wire, never the dense-layer share. When a
//! field *is* its buffer (the pool field always is), `set_field` is a
//! pure upload — no download-patch-reupload. Transfer counters
//! (`transfer_bytes`) account every state byte crossing the PCIe/host
//! boundary; per-batch inputs (dense/emb/labels) are not state and are
//! not counted.
//!
//! Every call validates input sizes/dtypes against the manifest FIRST —
//! PJRT aborts the process on shape mismatch (DESIGN.md §7.2), so the
//! validation here is what turns config bugs into `Err` instead of SIGABRT.

use crate::runtime::manifest::{DType, FieldDesc, Manifest};
use crate::runtime::ArtifactStore;
use anyhow::{anyhow, bail, Result};
use std::cell::Cell;

/// The embedding-side input of one batch (dtype depends on method kind).
pub enum EmbInput<'a> {
    Rows(&'a [i32]),
    Hashes(&'a [f32]),
}

pub struct DlrmSession {
    pub manifest: Manifest,
    train: xla::PjRtLoadedExecutable,
    predict: xla::PjRtLoadedExecutable,
    /// one device buffer per manifest buffer, in manifest order
    /// (pool, dense, metrics); `None` until the first `set_state`
    buffers: Option<Vec<xla::PjRtBuffer>>,
    /// steps executed since the last `set_state`
    pub steps_since_upload: u64,
    /// state bytes moved device→host since open (buffer downloads only)
    bytes_downloaded: Cell<u64>,
    /// state bytes moved host→device since open (buffer uploads only)
    bytes_uploaded: Cell<u64>,
}

impl DlrmSession {
    /// Load + compile an artifact's executables. Compilation happens once;
    /// all steps reuse the loaded executables.
    pub fn open(store: &ArtifactStore, name: &str) -> Result<DlrmSession> {
        let manifest = store.manifest(name)?;
        // the calling convention is load-bearing: every state.* input of
        // every executable must match a manifest buffer exactly, or
        // execute would feed a wrong-sized buffer (process-fatal in PJRT)
        for exec in ["train", "predict"] {
            for d in manifest.inputs_for(exec)? {
                if let Some(g) = d.name.strip_prefix("state.") {
                    let b = manifest.buffer(g)?;
                    if d.elems() != b.size {
                        bail!(
                            "{exec}:{} expects {} elements but buffer {g} has {}",
                            d.name,
                            d.elems(),
                            b.size
                        );
                    }
                }
            }
        }
        let train = store.compile(&manifest, "train")?;
        let predict = store.compile(&manifest, "predict")?;
        Ok(DlrmSession {
            manifest,
            train,
            predict,
            buffers: None,
            steps_since_upload: 0,
            bytes_downloaded: Cell::new(0),
            bytes_uploaded: Cell::new(0),
        })
    }

    /// (bytes_downloaded, bytes_uploaded) of state-buffer traffic so far.
    pub fn transfer_bytes(&self) -> (u64, u64) {
        (self.bytes_downloaded.get(), self.bytes_uploaded.get())
    }

    /// Wire cost (bytes) of moving the buffer holding `name` once.
    pub fn buffer_bytes(&self, name: &str) -> Result<u64> {
        Ok(self.manifest.buffer(name)?.bytes())
    }

    fn upload_group(&self, idx: usize, data: &[f32]) -> Result<xla::PjRtBuffer> {
        let b = &self.manifest.buffers[idx];
        debug_assert_eq!(data.len(), b.size);
        let buf = crate::runtime::with_client(|c| {
            Ok(c.buffer_from_host_buffer(data, &[b.size], None)?)
        })?;
        self.bytes_uploaded.set(self.bytes_uploaded.get() + b.bytes());
        // registry mirror of the session counter: transfer traffic shows up
        // on a live scrape/stats stream, cumulative across sessions
        crate::obs_counter!("runtime.bytes_uploaded").add(b.bytes());
        Ok(buf)
    }

    fn download_group(&self, idx: usize) -> Result<Vec<f32>> {
        let bufs = self.buffers.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        let out = bufs[idx].to_literal_sync()?.to_vec::<f32>()?;
        let bytes = self.manifest.buffers[idx].bytes();
        self.bytes_downloaded.set(self.bytes_downloaded.get() + bytes);
        crate::obs_counter!("runtime.bytes_downloaded").add(bytes);
        Ok(out)
    }

    /// Upload a fresh state vector (initialization or post-clustering),
    /// split into one device buffer per group.
    pub fn set_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.manifest.state_size {
            bail!(
                "state has {} elements, artifact {} expects {}",
                state.len(),
                self.manifest.name,
                self.manifest.state_size
            );
        }
        let mut bufs = Vec::with_capacity(self.manifest.buffers.len());
        for i in 0..self.manifest.buffers.len() {
            let b = self.manifest.buffers[i].clone();
            bufs.push(self.upload_group(i, &state[b.offset..b.offset + b.size])?);
        }
        self.buffers = Some(bufs);
        self.steps_since_upload = 0;
        Ok(())
    }

    /// Download the full state vector (checkpoints, snapshot baking) by
    /// concatenating every group buffer.
    pub fn pull_state(&self) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(self.manifest.state_size);
        for i in 0..self.manifest.buffers.len() {
            out.extend_from_slice(&self.download_group(i)?);
        }
        Ok(out)
    }

    /// A layout field passed by the caller must be the manifest's own
    /// description of that field — a stale/mismatched descriptor would
    /// silently read or patch the wrong state range.
    fn validate_field(&self, field: &FieldDesc) -> Result<()> {
        let d = self.manifest.field(&field.name)?;
        if d.offset != field.offset || d.size != field.size || d.group != field.group {
            bail!(
                "field {:?} (offset {}, size {}, group {}) does not match artifact {} \
                 layout (offset {}, size {}, group {})",
                field.name,
                field.offset,
                field.size,
                field.group,
                self.manifest.name,
                d.offset,
                d.size,
                d.group
            );
        }
        Ok(())
    }

    /// Download ONE layout field (e.g. the embedding pool around a
    /// clustering event). Only the device buffer holding the field
    /// crosses the wire — a pool pull costs pool-buffer bytes, not the
    /// full state.
    pub fn pull_field(&self, field: &FieldDesc) -> Result<Vec<f32>> {
        self.validate_field(field)?;
        let idx = self.manifest.buffer_for_field(field)?;
        let b = &self.manifest.buffers[idx];
        let group = self.download_group(idx)?;
        let rel = field.offset - b.offset;
        Ok(group[rel..rel + field.size].to_vec())
    }

    /// Patch ONE layout field; every other group buffer keeps its current
    /// device value untouched. When the field covers its whole buffer
    /// (the pool field always does) this is a pure upload; otherwise the
    /// buffer is downloaded, patched, and re-uploaded — still bounded by
    /// that one buffer, never the full state.
    pub fn set_field(&mut self, field: &FieldDesc, data: &[f32]) -> Result<()> {
        self.validate_field(field)?;
        if data.len() != field.size {
            bail!(
                "field {:?} patch has {} elements, expected {}",
                field.name,
                data.len(),
                field.size
            );
        }
        let idx = self.manifest.buffer_for_field(field)?;
        let b = self.manifest.buffers[idx].clone();
        let buf = if field.offset == b.offset && field.size == b.size {
            self.upload_group(idx, data)?
        } else {
            let mut group = self.download_group(idx)?;
            let rel = field.offset - b.offset;
            group[rel..rel + field.size].copy_from_slice(data);
            self.upload_group(idx, &group)?
        };
        let bufs = self.buffers.as_mut().ok_or_else(|| anyhow!("no state uploaded"))?;
        bufs[idx] = buf;
        Ok(())
    }

    fn validate(&self, exec: &str, name: &str, dtype: DType, len: usize) -> Result<()> {
        let descs = self.manifest.inputs_for(exec)?;
        let d = descs
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| anyhow!("executable {exec} has no input {name}"))?;
        if d.dtype != dtype {
            bail!("{exec}:{name} dtype mismatch: manifest {:?}, got {dtype:?}", d.dtype);
        }
        if d.elems() != len {
            bail!(
                "{exec}:{name} size mismatch: manifest {} elements {:?}, got {len}",
                d.elems(),
                d.shape
            );
        }
        Ok(())
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        crate::runtime::with_client(|c| Ok(c.buffer_from_host_buffer(data, shape, None)?))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        crate::runtime::with_client(|c| Ok(c.buffer_from_host_buffer(data, shape, None)?))
    }

    fn emb_buffer(&self, exec: &str, emb: &EmbInput) -> Result<xla::PjRtBuffer> {
        let desc = self
            .manifest
            .inputs_for(exec)?
            .iter()
            .find(|d| d.name == "emb")
            .ok_or_else(|| anyhow!("{exec} has no emb input"))?
            .clone();
        match emb {
            EmbInput::Rows(idx) => {
                self.validate(exec, "emb", DType::I32, idx.len())?;
                self.upload_i32(idx, &desc.shape)
            }
            EmbInput::Hashes(h) => {
                self.validate(exec, "emb", DType::F32, h.len())?;
                self.upload_f32(h, &desc.shape)
            }
        }
    }

    /// One fused fwd+bwd+SGD step. The group buffers advance in place:
    /// train's tuple root yields one result buffer per group, re-fed
    /// as-is next step with no host round-trip.
    pub fn train_step(&mut self, dense: &[f32], emb: EmbInput, labels: &[f32]) -> Result<()> {
        self.validate("train", "dense", DType::F32, dense.len())?;
        self.validate("train", "labels", DType::F32, labels.len())?;
        let spec = &self.manifest.spec;
        let dense_b = self.upload_f32(dense, &[spec.batch, spec.n_dense])?;
        let emb_b = self.emb_buffer("train", &emb)?;
        let labels_b = self.upload_f32(labels, &[spec.batch])?;
        let bufs = self.buffers.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
        for d in self.manifest.inputs_for("train")? {
            match d.name.strip_prefix("state.") {
                Some(g) => args.push(&bufs[self.manifest.buffer_index(g)?]),
                None => match d.name.as_str() {
                    "dense" => args.push(&dense_b),
                    "emb" => args.push(&emb_b),
                    "labels" => args.push(&labels_b),
                    other => bail!("unexpected train input {other:?}"),
                },
            }
        }
        let outs = self.train.execute_b(&args)?;
        let results = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("train step returned no buffers"))?;
        if results.len() != self.manifest.buffers.len() {
            bail!(
                "train step returned {} buffers, expected {} (one per state group)",
                results.len(),
                self.manifest.buffers.len()
            );
        }
        self.buffers = Some(results);
        self.steps_since_upload += 1;
        Ok(())
    }

    /// Read the in-graph metric slots: [loss_sum, examples, steps, last_loss].
    /// A direct download of the metrics buffer — no executable runs.
    pub fn metrics(&self) -> Result<Vec<f32>> {
        self.download_group(self.manifest.buffer_index("metrics")?)
    }

    /// Batched prediction: probabilities for `eval_batch` samples.
    pub fn predict(&self, dense: &[f32], emb: EmbInput) -> Result<Vec<f32>> {
        self.validate("predict", "dense", DType::F32, dense.len())?;
        let spec = &self.manifest.spec;
        let dense_b = self.upload_f32(dense, &[spec.eval_batch, spec.n_dense])?;
        let emb_b = self.emb_buffer("predict", &emb)?;
        let bufs = self.buffers.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::new();
        for d in self.manifest.inputs_for("predict")? {
            match d.name.strip_prefix("state.") {
                Some(g) => args.push(&bufs[self.manifest.buffer_index(g)?]),
                None => match d.name.as_str() {
                    "dense" => args.push(&dense_b),
                    "emb" => args.push(&emb_b),
                    other => bail!("unexpected predict input {other:?}"),
                },
            }
        }
        let outs = self.predict.execute_b(&args)?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Shapes of the embedding input per executable (for buffer sizing).
    pub fn emb_elems(&self, exec: &str) -> Result<usize> {
        Ok(self
            .manifest
            .inputs_for(exec)?
            .iter()
            .find(|d| d.name == "emb")
            .ok_or_else(|| anyhow!("no emb input"))?
            .elems())
    }
}
