//! Packed-state training session over one DLRM artifact.
//!
//! Owns the state device buffer and chains `execute_b` step-to-step with
//! no host round-trips; metrics come from the tiny `readout` executable.
//! `pull_field`/`set_field` move single layout fields (clustering events
//! only touch the pool field, never the dense-layer share) with a
//! generation-tagged download cache so a field round trip costs the same
//! one download + one upload as the full-state pair. NOTE: the PJRT
//! wrapper only exposes whole-buffer transfers and the state is one
//! device buffer, so the full state still crosses the wire internally —
//! the field API bounds what callers see/copy host-side and is the seam
//! a future per-field buffer split would slot into (ROADMAP "true
//! partial state transfer").
//! Every call validates input sizes/dtypes against the manifest FIRST —
//! PJRT aborts the process on shape mismatch (DESIGN.md §7.2), so the
//! validation here is what turns config bugs into `Err` instead of SIGABRT.

use crate::runtime::manifest::{DType, FieldDesc, Manifest};
use crate::runtime::ArtifactStore;
use anyhow::{anyhow, bail, Result};

/// The embedding-side input of one batch (dtype depends on method kind).
pub enum EmbInput<'a> {
    Rows(&'a [i32]),
    Hashes(&'a [f32]),
}

pub struct DlrmSession {
    pub manifest: Manifest,
    train: xla::PjRtLoadedExecutable,
    predict: xla::PjRtLoadedExecutable,
    readout: xla::PjRtLoadedExecutable,
    state: Option<xla::PjRtBuffer>,
    /// steps executed since the last `set_state`
    pub steps_since_upload: u64,
    /// device-state version: bumped by every mutation (`set_state`,
    /// `set_field`, `train_step`); tags `pull_cache` entries
    generation: u64,
    /// full-state download kept between a `pull_field` and the `set_field`
    /// that finishes a field-ranged round trip, so the pair costs one
    /// download + one upload (same as `pull_state`/`set_state`) while the
    /// caller only ever holds the field-sized slice. Invalidated whenever
    /// the device state advances.
    pull_cache: std::cell::RefCell<Option<(u64, Vec<f32>)>>,
}

impl DlrmSession {
    /// Load + compile an artifact's executables. Compilation happens once;
    /// all steps reuse the loaded executables.
    pub fn open(store: &ArtifactStore, name: &str) -> Result<DlrmSession> {
        let manifest = store.manifest(name)?;
        let train = store.compile(&manifest, "train")?;
        let predict = store.compile(&manifest, "predict")?;
        let readout = store.compile(&manifest, "readout")?;
        Ok(DlrmSession {
            manifest,
            train,
            predict,
            readout,
            state: None,
            steps_since_upload: 0,
            generation: 0,
            pull_cache: std::cell::RefCell::new(None),
        })
    }

    /// Upload a fresh state vector (initialization or post-clustering).
    pub fn set_state(&mut self, state: &[f32]) -> Result<()> {
        if state.len() != self.manifest.state_size {
            bail!(
                "state has {} elements, artifact {} expects {}",
                state.len(),
                self.manifest.name,
                self.manifest.state_size
            );
        }
        self.state = Some(crate::runtime::with_client(|c| {
            Ok(c.buffer_from_host_buffer(state, &[state.len()], None)?)
        })?);
        self.steps_since_upload = 0;
        self.generation += 1;
        *self.pull_cache.get_mut() = None;
        Ok(())
    }

    /// Download the full state vector (clustering events, checkpoints).
    pub fn pull_state(&self) -> Result<Vec<f32>> {
        let buf = self.state.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        Ok(buf.to_literal_sync()?.to_vec::<f32>()?)
    }

    /// A layout field passed by the caller must be the manifest's own
    /// description of that field — a stale/mismatched descriptor would
    /// silently read or patch the wrong state range.
    fn validate_field(&self, field: &FieldDesc) -> Result<()> {
        let d = self.manifest.field(&field.name)?;
        if d.offset != field.offset || d.size != field.size {
            bail!(
                "field {:?} (offset {}, size {}) does not match artifact {} layout \
                 (offset {}, size {})",
                field.name,
                field.offset,
                field.size,
                self.manifest.name,
                d.offset,
                d.size
            );
        }
        Ok(())
    }

    /// Download ONE layout field (e.g. the embedding pool around a
    /// clustering event) instead of the whole state vector. The caller
    /// only ever sees the field-sized slice; the full download backing it
    /// is cached (tagged with the state generation) so a following
    /// `set_field` finishes the round trip without a second download.
    pub fn pull_field(&self, field: &FieldDesc) -> Result<Vec<f32>> {
        self.validate_field(field)?;
        let range = field.offset..field.offset + field.size;
        {
            let cache = self.pull_cache.borrow();
            if let Some((gen, full)) = cache.as_ref() {
                if *gen == self.generation {
                    return Ok(full[range].to_vec());
                }
            }
        }
        let full = self.pull_state()?;
        let out = full[range.clone()].to_vec();
        *self.pull_cache.borrow_mut() = Some((self.generation, full));
        Ok(out)
    }

    /// Patch ONE layout field and re-upload; every other field keeps its
    /// current device value. Completes the `pull_field` → mutate →
    /// `set_field` round trip of a clustering event: only the field data
    /// crosses the API, and the cached download (if still current) covers
    /// the untouched remainder of the state.
    pub fn set_field(&mut self, field: &FieldDesc, data: &[f32]) -> Result<()> {
        self.validate_field(field)?;
        if data.len() != field.size {
            bail!(
                "field {:?} patch has {} elements, expected {}",
                field.name,
                data.len(),
                field.size
            );
        }
        let cached = self.pull_cache.get_mut().take();
        let mut full = match cached {
            Some((gen, full)) if gen == self.generation => full,
            _ => self.pull_state()?,
        };
        full[field.offset..field.offset + field.size].copy_from_slice(data);
        self.set_state(&full)
    }

    fn validate(&self, exec: &str, name: &str, dtype: DType, len: usize) -> Result<()> {
        let descs = self.manifest.inputs_for(exec)?;
        let d = descs
            .iter()
            .find(|d| d.name == name)
            .ok_or_else(|| anyhow!("executable {exec} has no input {name}"))?;
        if d.dtype != dtype {
            bail!("{exec}:{name} dtype mismatch: manifest {:?}, got {dtype:?}", d.dtype);
        }
        if d.elems() != len {
            bail!(
                "{exec}:{name} size mismatch: manifest {} elements {:?}, got {len}",
                d.elems(),
                d.shape
            );
        }
        Ok(())
    }

    fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        crate::runtime::with_client(|c| Ok(c.buffer_from_host_buffer(data, shape, None)?))
    }

    fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        crate::runtime::with_client(|c| Ok(c.buffer_from_host_buffer(data, shape, None)?))
    }

    fn emb_buffer(&self, exec: &str, emb: &EmbInput) -> Result<xla::PjRtBuffer> {
        let desc = self
            .manifest
            .inputs_for(exec)?
            .iter()
            .find(|d| d.name == "emb")
            .ok_or_else(|| anyhow!("{exec} has no emb input"))?
            .clone();
        match emb {
            EmbInput::Rows(idx) => {
                self.validate(exec, "emb", DType::I32, idx.len())?;
                self.upload_i32(idx, &desc.shape)
            }
            EmbInput::Hashes(h) => {
                self.validate(exec, "emb", DType::F32, h.len())?;
                self.upload_f32(h, &desc.shape)
            }
        }
    }

    /// One fused fwd+bwd+SGD step. The state buffer advances in place.
    pub fn train_step(&mut self, dense: &[f32], emb: EmbInput, labels: &[f32]) -> Result<()> {
        let state = self.state.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        self.validate("train", "dense", DType::F32, dense.len())?;
        self.validate("train", "labels", DType::F32, labels.len())?;
        let spec = &self.manifest.spec;
        let dense_b = self.upload_f32(dense, &[spec.batch, spec.n_dense])?;
        let emb_b = self.emb_buffer("train", &emb)?;
        let labels_b = self.upload_f32(labels, &[spec.batch])?;
        let outs = self.train.execute_b(&[state, &dense_b, &emb_b, &labels_b])?;
        let new_state = outs
            .into_iter()
            .next()
            .and_then(|r| r.into_iter().next())
            .ok_or_else(|| anyhow!("train step returned no buffers"))?;
        self.state = Some(new_state);
        self.steps_since_upload += 1;
        self.generation += 1;
        *self.pull_cache.get_mut() = None;
        Ok(())
    }

    /// Read the in-graph metric slots: [loss_sum, examples, steps, last_loss].
    pub fn metrics(&self) -> Result<Vec<f32>> {
        let state = self.state.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        let outs = self.readout.execute_b(&[state])?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Batched prediction: probabilities for `eval_batch` samples.
    pub fn predict(&self, dense: &[f32], emb: EmbInput) -> Result<Vec<f32>> {
        let state = self.state.as_ref().ok_or_else(|| anyhow!("no state uploaded"))?;
        self.validate("predict", "dense", DType::F32, dense.len())?;
        let spec = &self.manifest.spec;
        let dense_b = self.upload_f32(dense, &[spec.eval_batch, spec.n_dense])?;
        let emb_b = self.emb_buffer("predict", &emb)?;
        let outs = self.predict.execute_b(&[state, &dense_b, &emb_b])?;
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    /// Shapes of the embedding input per executable (for buffer sizing).
    pub fn emb_elems(&self, exec: &str) -> Result<usize> {
        Ok(self
            .manifest
            .inputs_for(exec)?
            .iter()
            .find(|d| d.name == "emb")
            .ok_or_else(|| anyhow!("no emb input"))?
            .elems())
    }
}
