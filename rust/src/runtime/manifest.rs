//! Artifact manifests — the contract between `python/compile/aot.py` and
//! the coordinator: state layout, input/output shapes, hyperparameters.
//! Parsed with the in-repo JSON parser; every field access is validated so
//! a stale or hand-edited manifest fails loudly instead of aborting inside
//! PJRT (execute with wrong shapes is a process-fatal CHECK).

use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};

/// The manifest schema this runtime speaks: 2 = per-group device buffers
/// (top-level "buffers" list, per-field "group" tags, `train` lowered
/// with a tuple root). Mirrors `python/compile/layout.py::SCHEMA_VERSION`.
pub const SCHEMA_VERSION: u64 = 2;

/// Element type of an executable input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// Initialization spec for one layout field (applied by `tables::init`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Normal(f32),
    Uniform(f32),
}

/// One field of the flat state vector. `offset` is the field's absolute
/// position in the flat (host interchange) state; the field lives in the
/// device buffer named by `group` at `offset - buffer.offset`.
#[derive(Clone, Debug)]
pub struct FieldDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: InitSpec,
    pub group: String,
}

/// One per-group device buffer: a contiguous range of the flat state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BufferDesc {
    pub name: String,
    pub offset: usize,
    pub size: usize,
}

impl BufferDesc {
    /// Wire cost of moving this buffer once (f32 elements).
    pub fn bytes(&self) -> u64 {
        self.size as u64 * 4
    }
}

/// One executable input.
#[derive(Clone, Debug)]
pub struct InputDesc {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl InputDesc {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Hyperparameters of a DLRM artifact (mirror of `specs.ArtifactSpec`).
#[derive(Clone, Debug)]
pub struct DlrmSpec {
    pub batch: usize,
    pub eval_batch: usize,
    pub dim: usize,
    pub dc: usize,
    pub t: usize,
    pub c: usize,
    pub cap: usize,
    pub lr: f64,
    pub n_features: usize,
    pub n_dense: usize,
    pub pool_rows: usize,
    pub dhe_hidden: usize,
    pub n_hash: usize,
    pub impl_name: String,
    pub embedding_params: usize,
}

/// A parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    /// calling-convention version the artifact was lowered with
    pub schema_version: u64,
    pub family: String,
    pub kind: String,
    pub dataset: String,
    pub method: String,
    pub spec: DlrmSpec,
    pub vocabs: Vec<usize>,
    pub state_size: usize,
    pub layout: Vec<FieldDesc>,
    /// per-group device buffers, in upload/result order (pool, dense,
    /// metrics); together they tile the flat state exactly
    pub buffers: Vec<BufferDesc>,
    pub metrics_offset: usize,
    pub metric_names: Vec<String>,
    /// executable kind → hlo file name
    pub executables: std::collections::BTreeMap<String, String>,
    /// executable kind → ordered inputs
    pub inputs: std::collections::BTreeMap<String, Vec<InputDesc>>,
    /// executable kind → output element count
    pub output_elems: std::collections::BTreeMap<String, usize>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let name = j.str_field("name")?.to_string();
        let family = j.str_field("family")?.to_string();
        let schema_version =
            j.get("schema_version").and_then(|v| v.as_usize()).unwrap_or(1) as u64;
        if family == "dlrm" && schema_version != SCHEMA_VERSION {
            bail!(
                "artifact {name:?} was lowered with manifest schema v{schema_version} \
                 (this runtime speaks v{SCHEMA_VERSION}). Schema v1 is the old \
                 single-buffer convention — re-run `python -m compile.aot --force` \
                 to re-lower the artifact with per-group state buffers"
            );
        }
        let kind = j
            .get("kind")
            .and_then(|k| k.as_str())
            .unwrap_or("kmeans")
            .to_string();
        let dataset = j.get("dataset").and_then(|k| k.as_str()).unwrap_or("").to_string();
        let method = j.get("method").and_then(|k| k.as_str()).unwrap_or("").to_string();

        let sj = j.req("spec")?;
        let spec = DlrmSpec {
            batch: sj.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            eval_batch: sj.get("eval_batch").and_then(|v| v.as_usize()).unwrap_or(0),
            dim: sj.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
            dc: sj.get("dc").and_then(|v| v.as_usize()).unwrap_or(0),
            t: sj.get("t").and_then(|v| v.as_usize()).unwrap_or(0),
            c: sj.get("c").and_then(|v| v.as_usize()).unwrap_or(0),
            cap: sj.get("cap").and_then(|v| v.as_usize()).unwrap_or(0),
            lr: sj.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.0),
            n_features: sj.get("n_features").and_then(|v| v.as_usize()).unwrap_or(0),
            n_dense: sj.get("n_dense").and_then(|v| v.as_usize()).unwrap_or(0),
            pool_rows: sj.get("pool_rows").and_then(|v| v.as_usize()).unwrap_or(0),
            dhe_hidden: sj.get("dhe_hidden").and_then(|v| v.as_usize()).unwrap_or(0),
            n_hash: sj.get("n_hash").and_then(|v| v.as_usize()).unwrap_or(0),
            impl_name: sj.get("impl").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            embedding_params: sj
                .get("embedding_params")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
        };

        let vocabs = j
            .get("vocabs")
            .map(|v| {
                v.as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default()
            })
            .unwrap_or_default();

        let state_size = j.get("state_size").and_then(|v| v.as_usize()).unwrap_or(0);

        let mut layout = Vec::new();
        if let Some(fields) = j.get("layout").and_then(|v| v.as_arr()) {
            for f in fields {
                let init_arr = f
                    .req("init")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("init not an array"))?;
                let init = match init_arr
                    .first()
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("init[0] not a string"))?
                {
                    "zeros" => InitSpec::Zeros,
                    "normal" => InitSpec::Normal(
                        init_arr.get(1).and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                    ),
                    "uniform" => InitSpec::Uniform(
                        init_arr.get(1).and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                    ),
                    other => bail!("unknown init {other:?}"),
                };
                layout.push(FieldDesc {
                    name: f.str_field("name")?.to_string(),
                    shape: f.usize_array("shape")?,
                    offset: f.usize_field("offset")?,
                    size: f.usize_field("size")?,
                    init,
                    group: f
                        .get("group")
                        .and_then(|g| g.as_str())
                        .ok_or_else(|| anyhow!("layout field without group tag"))?
                        .to_string(),
                });
            }
        }

        let mut buffers = Vec::new();
        if let Some(arr) = j.get("buffers").and_then(|v| v.as_arr()) {
            for b in arr {
                buffers.push(BufferDesc {
                    name: b.str_field("name")?.to_string(),
                    offset: b.usize_field("offset")?,
                    size: b.usize_field("size")?,
                });
            }
        }
        if family == "dlrm" && buffers.is_empty() {
            bail!("artifact {name:?}: schema v{schema_version} manifest without buffers");
        }

        let (metrics_offset, metric_names) = match j.get("metrics") {
            Some(m) => (
                m.usize_field("offset")?,
                m.req("names")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("metric names"))?
                    .iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect(),
            ),
            None => (0, Vec::new()),
        };

        let mut executables = std::collections::BTreeMap::new();
        for (k, v) in j
            .req("executables")?
            .as_obj()
            .ok_or_else(|| anyhow!("executables not an object"))?
        {
            executables.insert(
                k.clone(),
                v.as_str().ok_or_else(|| anyhow!("executable path"))?.to_string(),
            );
        }

        let mut inputs = std::collections::BTreeMap::new();
        for (k, v) in j
            .req("inputs")?
            .as_obj()
            .ok_or_else(|| anyhow!("inputs not an object"))?
        {
            let descs = v
                .as_arr()
                .ok_or_else(|| anyhow!("inputs[{k}] not an array"))?
                .iter()
                .map(|d| -> Result<InputDesc> {
                    Ok(InputDesc {
                        name: d.str_field("name")?.to_string(),
                        dtype: DType::parse(d.str_field("dtype")?)?,
                        shape: d.usize_array("shape")?,
                    })
                })
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("inputs[{k}]"))?;
            inputs.insert(k.clone(), descs);
        }

        let mut output_elems = std::collections::BTreeMap::new();
        if let Some(outs) = j.get("outputs").and_then(|v| v.as_obj()) {
            for (k, v) in outs {
                // tuple-root executables (train) list one shape per result;
                // single-root ones keep a plain "shape"
                let n: usize = match v.get("tuple_shapes").and_then(|t| t.as_arr()) {
                    Some(shapes) => shapes
                        .iter()
                        .map(|s| -> Result<usize> {
                            let dims = s
                                .as_arr()
                                .ok_or_else(|| anyhow!("outputs[{k}] tuple shape"))?;
                            Ok(dims.iter().filter_map(|d| d.as_usize()).product())
                        })
                        .sum::<Result<usize>>()?,
                    None => v.usize_array("shape")?.iter().product(),
                };
                output_elems.insert(k.clone(), n);
            }
        }

        // cross-validation: layout must tile the state exactly
        if !layout.is_empty() {
            let mut off = 0usize;
            for f in &layout {
                if f.offset != off {
                    bail!("layout field {} at offset {} (expected {off})", f.name, f.offset);
                }
                let expect: usize = f.shape.iter().product();
                if expect != f.size {
                    bail!("layout field {} size mismatch", f.name);
                }
                off += f.size;
            }
            if off != state_size {
                bail!("layout covers {off} of {state_size} state elements");
            }
        }

        // cross-validation: buffers must tile the state exactly, and every
        // field must sit inside the buffer named by its group tag
        if !buffers.is_empty() {
            let mut off = 0usize;
            for b in &buffers {
                if b.offset != off {
                    bail!("buffer {} at offset {} (expected {off})", b.name, b.offset);
                }
                if b.size == 0 {
                    bail!("buffer {} is empty", b.name);
                }
                off += b.size;
            }
            if off != state_size {
                bail!("buffers cover {off} of {state_size} state elements");
            }
            for f in &layout {
                let b = buffers.iter().find(|b| b.name == f.group).ok_or_else(|| {
                    anyhow!("field {} tagged with unknown group {:?}", f.name, f.group)
                })?;
                if f.offset < b.offset || f.offset + f.size > b.offset + b.size {
                    bail!("field {} leaks out of buffer {}", f.name, b.name);
                }
            }
        }

        Ok(Manifest {
            name,
            schema_version,
            family,
            kind,
            dataset,
            method,
            spec,
            vocabs,
            state_size,
            layout,
            buffers,
            metrics_offset,
            metric_names,
            executables,
            inputs,
            output_elems,
        })
    }

    pub fn field(&self, name: &str) -> Result<&FieldDesc> {
        self.layout
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| anyhow!("no layout field {name:?} in {}", self.name))
    }

    pub fn buffer(&self, name: &str) -> Result<&BufferDesc> {
        self.buffers
            .iter()
            .find(|b| b.name == name)
            .ok_or_else(|| anyhow!("no state buffer {name:?} in {}", self.name))
    }

    pub fn buffer_index(&self, name: &str) -> Result<usize> {
        self.buffers
            .iter()
            .position(|b| b.name == name)
            .ok_or_else(|| anyhow!("no state buffer {name:?} in {}", self.name))
    }

    /// Index of the device buffer holding `field` (by its group tag).
    pub fn buffer_for_field(&self, field: &FieldDesc) -> Result<usize> {
        self.buffer_index(&field.group)
    }

    pub fn inputs_for(&self, exec: &str) -> Result<&[InputDesc]> {
        Ok(self
            .inputs
            .get(exec)
            .ok_or_else(|| anyhow!("no inputs for executable {exec:?}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "t", "schema_version": 2, "family": "dlrm", "kind": "rowwise",
      "dataset": "smoke", "method": "cce",
      "spec": {"batch": 64, "eval_batch": 128, "dim": 8, "dc": 2, "t": 2,
               "c": 4, "cap": 32, "lr": 0.05, "n_features": 4, "n_dense": 13,
               "pool_rows": 856, "dhe_hidden": 0, "n_hash": 0,
               "impl": "pallas", "embedding_params": 1712},
      "vocabs": [11, 50, 200, 1000],
      "state_size": 24,
      "layout": [
        {"name": "pool", "shape": [4, 4], "offset": 0, "size": 16,
         "init": ["normal", 0.125], "group": "pool"},
        {"name": "bot_w0", "shape": [2, 2], "offset": 16, "size": 4,
         "init": ["uniform", 0.5], "group": "dense"},
        {"name": "metrics", "shape": [4], "offset": 20, "size": 4,
         "init": ["zeros"], "group": "metrics"}
      ],
      "buffers": [
        {"name": "pool", "offset": 0, "size": 16},
        {"name": "dense", "offset": 16, "size": 4},
        {"name": "metrics", "offset": 20, "size": 4}
      ],
      "metrics": {"offset": 20, "names": ["loss_sum", "examples", "steps", "last_loss"]},
      "executables": {"train": "t.train.hlo.txt"},
      "inputs": {"train": [
        {"name": "state.pool", "dtype": "f32", "shape": [16]},
        {"name": "state.dense", "dtype": "f32", "shape": [4]},
        {"name": "state.metrics", "dtype": "f32", "shape": [4]},
        {"name": "emb", "dtype": "i32", "shape": [64, 4, 2, 4]}
      ]},
      "outputs": {"train": {"dtype": "f32", "tuple_shapes": [[16], [4], [4]]}}
    }"#;

    #[test]
    fn parses_complete_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.schema_version, SCHEMA_VERSION);
        assert_eq!(m.spec.batch, 64);
        assert_eq!(m.vocabs, vec![11, 50, 200, 1000]);
        assert_eq!(m.layout.len(), 3);
        assert_eq!(m.field("pool").unwrap().init, InitSpec::Normal(0.125));
        assert_eq!(m.metrics_offset, 20);
        let ins = m.inputs_for("train").unwrap();
        assert_eq!(ins[3].dtype, DType::I32);
        assert_eq!(ins[3].elems(), 64 * 4 * 2 * 4);
        // tuple root: output_elems is the summed element count
        assert_eq!(m.output_elems["train"], 24);
    }

    #[test]
    fn resolves_buffers_and_field_groups() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.buffers.len(), 3);
        assert_eq!(m.buffer("pool").unwrap().size, 16);
        assert_eq!(m.buffer("pool").unwrap().bytes(), 64);
        assert_eq!(m.buffer_index("metrics").unwrap(), 2);
        let f = m.field("bot_w0").unwrap().clone();
        assert_eq!(m.buffer_for_field(&f).unwrap(), 1);
        assert!(m.buffer("nope").is_err());
    }

    #[test]
    fn rejects_single_buffer_schema_v1() {
        let bad = SAMPLE.replace("\"schema_version\": 2, ", "");
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("schema v1"), "{err}");
        assert!(err.contains("single-buffer"), "{err}");
        assert!(err.contains("compile.aot"), "{err}");
    }

    #[test]
    fn rejects_field_without_group_tag() {
        let bad = SAMPLE.replace(", \"group\": \"dense\"", "");
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("group"), "{err}");
    }

    #[test]
    fn rejects_buffers_not_tiling_state() {
        let bad = SAMPLE.replace(
            "{\"name\": \"dense\", \"offset\": 16, \"size\": 4}",
            "{\"name\": \"dense\", \"offset\": 17, \"size\": 4}",
        );
        assert!(Manifest::parse(&bad).unwrap_err().to_string().contains("buffer"));
    }

    #[test]
    fn rejects_field_leaking_out_of_its_buffer() {
        let bad = SAMPLE.replace("\"group\": \"dense\"", "\"group\": \"metrics\"");
        let err = Manifest::parse(&bad).unwrap_err().to_string();
        assert!(err.contains("leaks out of"), "{err}");
    }

    #[test]
    fn rejects_bad_layout_offsets() {
        let bad = SAMPLE.replace(
            "\"offset\": 20, \"size\": 4,\n         \"init\": [\"zeros\"]",
            "\"offset\": 21, \"size\": 4,\n         \"init\": [\"zeros\"]",
        );
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_layout_not_covering_state() {
        let bad = SAMPLE.replace("\"state_size\": 24", "\"state_size\": 25");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_inputs_for_unknown_exec() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.inputs_for("predict").is_err());
        assert!(m.field("nope").is_err());
    }
}
