//! Artifact manifests — the contract between `python/compile/aot.py` and
//! the coordinator: state layout, input/output shapes, hyperparameters.
//! Parsed with the in-repo JSON parser; every field access is validated so
//! a stale or hand-edited manifest fails loudly instead of aborting inside
//! PJRT (execute with wrong shapes is a process-fatal CHECK).

use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Element type of an executable input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// Initialization spec for one layout field (applied by `tables::init`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitSpec {
    Zeros,
    Normal(f32),
    Uniform(f32),
}

/// One field of the packed state vector.
#[derive(Clone, Debug)]
pub struct FieldDesc {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: InitSpec,
}

/// One executable input.
#[derive(Clone, Debug)]
pub struct InputDesc {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl InputDesc {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Hyperparameters of a DLRM artifact (mirror of `specs.ArtifactSpec`).
#[derive(Clone, Debug)]
pub struct DlrmSpec {
    pub batch: usize,
    pub eval_batch: usize,
    pub dim: usize,
    pub dc: usize,
    pub t: usize,
    pub c: usize,
    pub cap: usize,
    pub lr: f64,
    pub n_features: usize,
    pub n_dense: usize,
    pub pool_rows: usize,
    pub dhe_hidden: usize,
    pub n_hash: usize,
    pub impl_name: String,
    pub embedding_params: usize,
}

/// A parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub family: String,
    pub kind: String,
    pub dataset: String,
    pub method: String,
    pub spec: DlrmSpec,
    pub vocabs: Vec<usize>,
    pub state_size: usize,
    pub layout: Vec<FieldDesc>,
    pub metrics_offset: usize,
    pub metric_names: Vec<String>,
    /// executable kind → hlo file name
    pub executables: std::collections::BTreeMap<String, String>,
    /// executable kind → ordered inputs
    pub inputs: std::collections::BTreeMap<String, Vec<InputDesc>>,
    /// executable kind → output element count
    pub output_elems: std::collections::BTreeMap<String, usize>,
}

impl Manifest {
    pub fn parse(src: &str) -> Result<Manifest> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let name = j.str_field("name")?.to_string();
        let family = j.str_field("family")?.to_string();
        let kind = j
            .get("kind")
            .and_then(|k| k.as_str())
            .unwrap_or("kmeans")
            .to_string();
        let dataset = j.get("dataset").and_then(|k| k.as_str()).unwrap_or("").to_string();
        let method = j.get("method").and_then(|k| k.as_str()).unwrap_or("").to_string();

        let sj = j.req("spec")?;
        let spec = DlrmSpec {
            batch: sj.get("batch").and_then(|v| v.as_usize()).unwrap_or(0),
            eval_batch: sj.get("eval_batch").and_then(|v| v.as_usize()).unwrap_or(0),
            dim: sj.get("dim").and_then(|v| v.as_usize()).unwrap_or(0),
            dc: sj.get("dc").and_then(|v| v.as_usize()).unwrap_or(0),
            t: sj.get("t").and_then(|v| v.as_usize()).unwrap_or(0),
            c: sj.get("c").and_then(|v| v.as_usize()).unwrap_or(0),
            cap: sj.get("cap").and_then(|v| v.as_usize()).unwrap_or(0),
            lr: sj.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.0),
            n_features: sj.get("n_features").and_then(|v| v.as_usize()).unwrap_or(0),
            n_dense: sj.get("n_dense").and_then(|v| v.as_usize()).unwrap_or(0),
            pool_rows: sj.get("pool_rows").and_then(|v| v.as_usize()).unwrap_or(0),
            dhe_hidden: sj.get("dhe_hidden").and_then(|v| v.as_usize()).unwrap_or(0),
            n_hash: sj.get("n_hash").and_then(|v| v.as_usize()).unwrap_or(0),
            impl_name: sj.get("impl").and_then(|v| v.as_str()).unwrap_or("").to_string(),
            embedding_params: sj
                .get("embedding_params")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
        };

        let vocabs = j
            .get("vocabs")
            .map(|v| {
                v.as_arr()
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default()
            })
            .unwrap_or_default();

        let state_size = j.get("state_size").and_then(|v| v.as_usize()).unwrap_or(0);

        let mut layout = Vec::new();
        if let Some(fields) = j.get("layout").and_then(|v| v.as_arr()) {
            for f in fields {
                let init_arr = f
                    .req("init")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("init not an array"))?;
                let init = match init_arr
                    .first()
                    .and_then(|x| x.as_str())
                    .ok_or_else(|| anyhow!("init[0] not a string"))?
                {
                    "zeros" => InitSpec::Zeros,
                    "normal" => InitSpec::Normal(
                        init_arr.get(1).and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                    ),
                    "uniform" => InitSpec::Uniform(
                        init_arr.get(1).and_then(|x| x.as_f64()).unwrap_or(0.0) as f32,
                    ),
                    other => bail!("unknown init {other:?}"),
                };
                layout.push(FieldDesc {
                    name: f.str_field("name")?.to_string(),
                    shape: f.usize_array("shape")?,
                    offset: f.usize_field("offset")?,
                    size: f.usize_field("size")?,
                    init,
                });
            }
        }

        let (metrics_offset, metric_names) = match j.get("metrics") {
            Some(m) => (
                m.usize_field("offset")?,
                m.req("names")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("metric names"))?
                    .iter()
                    .filter_map(|x| x.as_str().map(String::from))
                    .collect(),
            ),
            None => (0, Vec::new()),
        };

        let mut executables = std::collections::BTreeMap::new();
        for (k, v) in j
            .req("executables")?
            .as_obj()
            .ok_or_else(|| anyhow!("executables not an object"))?
        {
            executables.insert(
                k.clone(),
                v.as_str().ok_or_else(|| anyhow!("executable path"))?.to_string(),
            );
        }

        let mut inputs = std::collections::BTreeMap::new();
        for (k, v) in j
            .req("inputs")?
            .as_obj()
            .ok_or_else(|| anyhow!("inputs not an object"))?
        {
            let descs = v
                .as_arr()
                .ok_or_else(|| anyhow!("inputs[{k}] not an array"))?
                .iter()
                .map(|d| -> Result<InputDesc> {
                    Ok(InputDesc {
                        name: d.str_field("name")?.to_string(),
                        dtype: DType::parse(d.str_field("dtype")?)?,
                        shape: d.usize_array("shape")?,
                    })
                })
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("inputs[{k}]"))?;
            inputs.insert(k.clone(), descs);
        }

        let mut output_elems = std::collections::BTreeMap::new();
        if let Some(outs) = j.get("outputs").and_then(|v| v.as_obj()) {
            for (k, v) in outs {
                let n: usize = v.usize_array("shape")?.iter().product();
                output_elems.insert(k.clone(), n);
            }
        }

        // cross-validation: layout must tile the state exactly
        if !layout.is_empty() {
            let mut off = 0usize;
            for f in &layout {
                if f.offset != off {
                    bail!("layout field {} at offset {} (expected {off})", f.name, f.offset);
                }
                let expect: usize = f.shape.iter().product();
                if expect != f.size {
                    bail!("layout field {} size mismatch", f.name);
                }
                off += f.size;
            }
            if off != state_size {
                bail!("layout covers {off} of {state_size} state elements");
            }
        }

        Ok(Manifest {
            name,
            family,
            kind,
            dataset,
            method,
            spec,
            vocabs,
            state_size,
            layout,
            metrics_offset,
            metric_names,
            executables,
            inputs,
            output_elems,
        })
    }

    pub fn field(&self, name: &str) -> Result<&FieldDesc> {
        self.layout
            .iter()
            .find(|f| f.name == name)
            .ok_or_else(|| anyhow!("no layout field {name:?} in {}", self.name))
    }

    pub fn inputs_for(&self, exec: &str) -> Result<&[InputDesc]> {
        Ok(self
            .inputs
            .get(exec)
            .ok_or_else(|| anyhow!("no inputs for executable {exec:?}"))?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "name": "t", "family": "dlrm", "kind": "rowwise",
      "dataset": "smoke", "method": "cce",
      "spec": {"batch": 64, "eval_batch": 128, "dim": 8, "dc": 2, "t": 2,
               "c": 4, "cap": 32, "lr": 0.05, "n_features": 4, "n_dense": 13,
               "pool_rows": 856, "dhe_hidden": 0, "n_hash": 0,
               "impl": "pallas", "embedding_params": 1712},
      "vocabs": [11, 50, 200, 1000],
      "state_size": 20,
      "layout": [
        {"name": "pool", "shape": [4, 4], "offset": 0, "size": 16,
         "init": ["normal", 0.125]},
        {"name": "metrics", "shape": [4], "offset": 16, "size": 4,
         "init": ["zeros"]}
      ],
      "metrics": {"offset": 16, "names": ["loss_sum", "examples", "steps", "last_loss"]},
      "executables": {"train": "t.train.hlo.txt"},
      "inputs": {"train": [
        {"name": "state", "dtype": "f32", "shape": [20]},
        {"name": "emb", "dtype": "i32", "shape": [64, 4, 2, 4]}
      ]},
      "outputs": {"train": {"dtype": "f32", "shape": [20]}}
    }"#;

    #[test]
    fn parses_complete_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.spec.batch, 64);
        assert_eq!(m.vocabs, vec![11, 50, 200, 1000]);
        assert_eq!(m.layout.len(), 2);
        assert_eq!(m.field("pool").unwrap().init, InitSpec::Normal(0.125));
        assert_eq!(m.metrics_offset, 16);
        let ins = m.inputs_for("train").unwrap();
        assert_eq!(ins[1].dtype, DType::I32);
        assert_eq!(ins[1].elems(), 64 * 4 * 2 * 4);
        assert_eq!(m.output_elems["train"], 20);
    }

    #[test]
    fn rejects_bad_layout_offsets() {
        let bad = SAMPLE.replace("\"offset\": 16", "\"offset\": 17");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_layout_not_covering_state() {
        let bad = SAMPLE.replace("\"state_size\": 20", "\"state_size\": 21");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_inputs_for_unknown_exec() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.inputs_for("predict").is_err());
        assert!(m.field("nope").is_err());
    }
}
