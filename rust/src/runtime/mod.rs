//! PJRT runtime: loads AOT-compiled HLO-text artifacts and drives them
//! with the packed-state calling convention (DESIGN.md §7).

pub mod artifact;
pub mod manifest;
pub mod session;

pub use artifact::ArtifactStore;
pub use manifest::{DType, InitSpec, InputDesc, Manifest};
pub use session::DlrmSession;

use anyhow::Result;

thread_local! {
    static CLIENT: std::cell::OnceCell<xla::PjRtClient> = const { std::cell::OnceCell::new() };
}

/// Thread-local PJRT CPU client.
///
/// The `xla` crate's `PjRtClient` is an `Rc` wrapper (not `Send`/`Sync`),
/// so all PJRT objects — client, buffers, executables — must live on the
/// thread that created them. The coordinator keeps every PJRT interaction
/// on a single exec thread by construction; producer threads only build
/// host arrays. `with_client` runs `f` against this thread's client,
/// creating it on first use.
pub fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = xla::PjRtClient::cpu()?;
            let _ = cell.set(c);
        }
        f(cell.get().unwrap())
    })
}

/// Compile an HLO-text file on this thread's client.
pub fn compile_hlo_file(path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    with_client(|c| Ok(c.compile(&comp)?))
}
