//! Artifact store: the on-disk `artifacts/` directory produced by
//! `make artifacts` — manifests, HLO files, and the dataset-preset index.

use crate::data::synthetic::DatasetSpec;
use crate::runtime::manifest::Manifest;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    index: Json,
}

impl ArtifactStore {
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let index_path = dir.join("index.json");
        let src = std::fs::read_to_string(&index_path).with_context(|| {
            format!(
                "reading {index_path:?} — did you run `make artifacts`?"
            )
        })?;
        let index = Json::parse(&src).map_err(|e| anyhow!("{e}"))?;
        Ok(ArtifactStore { dir, index })
    }

    /// Artifact names present in the index.
    pub fn artifact_names(&self) -> Vec<String> {
        self.index
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default()
    }

    pub fn has(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.json")).exists()
    }

    /// Load an artifact's manifest.
    pub fn manifest(&self, name: &str) -> Result<Manifest> {
        let path = self.dir.join(format!("{name}.json"));
        let src = std::fs::read_to_string(&path).with_context(|| {
            format!("reading manifest {path:?} — run `make artifacts` (or artifacts-sweep)")
        })?;
        Manifest::parse(&src).with_context(|| format!("parsing {path:?}"))
    }

    /// Compile one of an artifact's executables.
    pub fn compile(&self, manifest: &Manifest, exec: &str) -> Result<xla::PjRtLoadedExecutable> {
        let file = manifest
            .executables
            .get(exec)
            .ok_or_else(|| anyhow!("artifact {} has no executable {exec:?}", manifest.name))?;
        crate::runtime::compile_hlo_file(&self.dir.join(file))
            .with_context(|| format!("compiling {}:{exec}", manifest.name))
    }

    /// Dataset preset from the index (the single source of truth shared
    /// with `python/compile/specs.py`).
    pub fn dataset(&self, name: &str, seed: u64) -> Result<DatasetSpec> {
        let ds = self
            .index
            .get("datasets")
            .and_then(|d| d.get(name))
            .ok_or_else(|| anyhow!("dataset preset {name:?} not in index.json"))?;
        Ok(DatasetSpec {
            name: name.to_string(),
            vocabs: ds.usize_array("vocabs")?,
            n_dense: ds.usize_field("n_dense")?,
            train_samples: ds.usize_field("train_samples")?,
            val_samples: ds.usize_field("val_samples")?,
            test_samples: ds.usize_field("test_samples")?,
            latent_clusters: ds.usize_field("latent_clusters")?,
            zipf_exponent: ds.f64_field("zipf_exponent")?,
            label_noise: ds.f64_field("label_noise")?,
            seed,
        })
    }

    /// Default artifacts directory: `$CCE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("CCE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_gives_actionable_error() {
        let err = ArtifactStore::open("/nonexistent/path").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn index_round_trip(){
        let dir = std::env::temp_dir().join(format!("cce_store_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("index.json"),
            r#"{"artifacts": ["a", "b"], "kmeans": [],
                "datasets": {"d": {"vocabs": [3, 5], "n_dense": 2,
                  "train_samples": 10, "val_samples": 2, "test_samples": 2,
                  "latent_clusters": 2, "zipf_exponent": 1.05,
                  "label_noise": 0.1}}}"#,
        )
        .unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        assert_eq!(store.artifact_names(), vec!["a", "b"]);
        let ds = store.dataset("d", 3).unwrap();
        assert_eq!(ds.vocabs, vec![3, 5]);
        assert_eq!(ds.seed, 3);
        assert!(store.dataset("missing", 0).is_err());
        assert!(!store.has("a"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
