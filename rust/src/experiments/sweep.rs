//! Figure-4-style sweeps: (method × parameter budget × seed) training runs
//! collected into per-method curves, feeding Table 1's compression math.

use crate::config::TrainConfig;
use crate::coordinator::trainer::{train, TrainOutcome};
use crate::metrics::extrapolate::{params_to_reach, Crossing, SweepPoint as XPoint};
use crate::runtime::ArtifactStore;
use anyhow::Result;

/// One sweep cell result.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: String,
    pub cap: usize,
    pub seed: u64,
    pub outcome: TrainOutcome,
}

/// What to sweep.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    /// dataset preset prefix used in artifact names (e.g. "kaggle_small")
    pub dataset: String,
    pub methods: Vec<String>,
    pub caps: Vec<usize>,
    pub seeds: Vec<u64>,
    /// base train config (epochs / clustering / early stop)
    pub base: TrainConfig,
}

impl SweepSpec {
    pub fn artifact_name(&self, method: &str, cap: usize) -> String {
        if method == "full" {
            format!("sweep_{}_full_0", self.dataset)
        } else {
            format!("sweep_{}_{}_{}", self.dataset, method, cap)
        }
    }
}

/// Run the sweep serially (each run already parallelizes internally).
/// Missing artifacts are reported, not fatal — so a partial
/// `artifacts-sweep` build still produces the available rows.
pub fn run_sweep(store: &ArtifactStore, spec: &SweepSpec) -> Result<Vec<SweepPoint>> {
    let mut out = Vec::new();
    for method in &spec.methods {
        let caps: Vec<usize> =
            if method == "full" { vec![0] } else { spec.caps.clone() };
        for &cap in &caps {
            let name = spec.artifact_name(method, cap);
            if !store.has(&name) {
                log::warn!("skipping {name}: artifact not built (run `make artifacts-sweep`)");
                continue;
            }
            for &seed in &spec.seeds {
                let mut cfg = spec.base.clone();
                cfg.artifact = name.clone();
                cfg.seed = seed;
                // clustering only applies to CCE
                if method != "cce" {
                    cfg.cluster_times = 0;
                }
                log::info!("sweep: {name} seed {seed}");
                let mut outcome = train(store, &cfg)?;
                // sweeps only consume scalar metrics; keeping every run's
                // full-model checkpoint (state vector + index maps) alive
                // for the whole sweep would balloon peak memory
                outcome.best_checkpoint = None;
                out.push(SweepPoint { method: method.clone(), cap, seed, outcome });
            }
        }
    }
    Ok(out)
}

/// Mean test BCE per (method, cap) over seeds, sorted by params.
pub fn curve_for(points: &[SweepPoint], method: &str) -> Vec<(f64, f64, f64, f64)> {
    // (params, mean, min, max)
    let mut by_cap: std::collections::BTreeMap<usize, Vec<&SweepPoint>> = Default::default();
    for p in points.iter().filter(|p| p.method == method) {
        by_cap.entry(p.cap).or_default().push(p);
    }
    by_cap
        .values()
        .map(|ps| {
            let params = ps[0].outcome.embedding_params as f64;
            let bces: Vec<f64> = ps.iter().map(|p| p.outcome.test_bce).collect();
            let mean = bces.iter().sum::<f64>() / bces.len() as f64;
            let min = bces.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = bces.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (params, mean, min, max)
        })
        .collect()
}

/// Table-1 crossing estimate for a method against a baseline BCE.
pub fn crossing_for(points: &[SweepPoint], method: &str, baseline: f64) -> Option<Crossing> {
    let curve = curve_for(points, method);
    if curve.len() < 2 {
        return None;
    }
    let pts: Vec<XPoint> =
        curve.iter().map(|&(p, m, _, _)| XPoint { params: p, bce: m }).collect();
    Some(params_to_reach(&pts, baseline))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_point(method: &str, cap: usize, seed: u64, params: usize, bce: f64) -> SweepPoint {
        SweepPoint {
            method: method.into(),
            cap,
            seed,
            outcome: TrainOutcome {
                embedding_params: params,
                test_bce: bce,
                ..Default::default()
            },
        }
    }

    #[test]
    fn curve_aggregates_seeds() {
        let pts = vec![
            fake_point("cce", 64, 0, 1000, 0.50),
            fake_point("cce", 64, 1, 1000, 0.52),
            fake_point("cce", 256, 0, 4000, 0.45),
            fake_point("hash", 64, 0, 1000, 0.55),
        ];
        let c = curve_for(&pts, "cce");
        assert_eq!(c.len(), 2);
        assert!((c[0].1 - 0.51).abs() < 1e-12);
        assert_eq!(c[0].2, 0.50);
        assert_eq!(c[0].3, 0.52);
        assert_eq!(c[1].0, 4000.0);
    }

    #[test]
    fn crossing_detected() {
        let pts = vec![
            fake_point("cce", 64, 0, 1000, 0.50),
            fake_point("cce", 256, 0, 4000, 0.40),
        ];
        match crossing_for(&pts, "cce", 0.45) {
            Some(Crossing::Measured(p)) => assert!(p > 1000.0 && p < 4000.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn artifact_naming() {
        let spec = SweepSpec {
            dataset: "kaggle_small".into(),
            methods: vec![],
            caps: vec![],
            seeds: vec![],
            base: TrainConfig::default(),
        };
        assert_eq!(spec.artifact_name("cce", 64), "sweep_kaggle_small_cce_64");
        assert_eq!(spec.artifact_name("full", 0), "sweep_kaggle_small_full_0");
    }
}
