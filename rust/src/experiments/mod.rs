//! Experiment harness shared by the paper-table benches and the CLI:
//! sweep running, result tables, and CSV persistence.

pub mod pq;
pub mod report;
pub mod sweep;

pub use report::Table;
pub use sweep::{run_sweep, SweepPoint as SweepRun, SweepSpec};
