//! ASCII table + CSV output for experiment results — the benches print
//! the same rows/series the paper's tables and figures report.

use std::fmt::Write as _;

/// A simple column-aligned table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.header);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<w$}|", "", w = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write CSV under `bench_results/<name>.csv` (best effort).
    pub fn save_csv(&self, name: &str) {
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{name}.csv")), self.to_csv());
        }
    }
}

/// Format a compression factor the way Table 1 does: `8,500×` or a
/// `127-155×` range.
pub fn fmt_compression(optimistic: f64, conservative: Option<f64>) -> String {
    let fmt1 = |x: f64| {
        if x >= 1000.0 {
            format!("{:.0},{:03.0}", (x / 1000.0).floor(), x % 1000.0)
        } else if x >= 10.0 {
            format!("{x:.0}")
        } else {
            format!("{x:.1}")
        }
    };
    match conservative {
        None => format!("{}x", fmt1(optimistic)),
        Some(c) if !c.is_finite() || c <= 0.0 => format!("<{}x", fmt1(optimistic)),
        Some(c) => format!("{}-{}x", fmt1(c.min(optimistic)), fmt1(optimistic.max(c))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["method", "bce"]);
        t.row(vec!["cce".into(), "0.4500".into()]);
        t.row(vec!["hashing trick".into(), "0.4600".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| cce           |"));
        assert!(s.lines().count() == 5);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }

    #[test]
    fn compression_formatting() {
        assert_eq!(fmt_compression(8500.0, None), "8,500x");
        assert_eq!(fmt_compression(155.0, Some(127.0)), "127-155x");
        assert_eq!(fmt_compression(25.0, Some(f64::INFINITY)), "<25x");
        assert_eq!(fmt_compression(4.2, None), "4.2x");
    }
}
