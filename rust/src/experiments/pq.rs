//! The Product-Quantization baseline for the fig4 curves: train the FULL
//! model once, then post-hoc quantize its tables at each budget and
//! re-evaluate — a post-training method can never beat the model it
//! quantizes, which is exactly the paper's point about PQ in Figure 4a.

use crate::baselines::pq::pq_quantize_pool;
use crate::config::TrainConfig;
use crate::coordinator::eval::evaluate;
use crate::coordinator::trainer::build_indexer;
use crate::data::batch::Split;
use crate::data::SyntheticDataset;
use crate::runtime::{ArtifactStore, DlrmSession};
use crate::tables::layout::TablePlan;
use anyhow::{anyhow, Result};

/// One PQ budget point.
#[derive(Clone, Debug)]
pub struct PqPoint {
    /// codewords per block (the budget knob; rows in Table-1 units)
    pub k: usize,
    /// effective parameter count (codebooks + ½-word index pointers)
    pub params: f64,
    pub test_bce: f64,
    pub test_auc: f64,
}

/// Train the full artifact, then evaluate PQ at each `k` (codewords per
/// block, `c_blocks` blocks). Returns (full-model outcome BCE, pq points).
pub fn pq_curve(
    store: &ArtifactStore,
    full_artifact: &str,
    cfg: &TrainConfig,
    ks: &[usize],
    c_blocks: usize,
) -> Result<(f64, Vec<PqPoint>)> {
    let mut cfg = cfg.clone();
    cfg.artifact = full_artifact.to_string();
    cfg.cluster_times = 0;
    // `coordinator::train` drops its session (and with it the trained
    // state), so the PQ curve uses a pull-aware variant of the loop.
    let (state, test_bce) = train_and_pull(store, &cfg)?;

    let mut session = DlrmSession::open(store, full_artifact)?;
    let m = session.manifest.clone();
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, cfg.seed)?);
    let indexer = build_indexer(&m, cfg.seed)?;
    let plan = TablePlan::new(&m.vocabs, usize::MAX, 1, 1, m.spec.dc);
    let pool = m.field("pool")?.clone();

    let mut points = Vec::new();
    for &k in ks {
        let mut quantized = state.clone();
        let report =
            pq_quantize_pool(&mut quantized, &pool, &plan, k, c_blocks, 25, cfg.seed ^ 0x9A);
        session.set_state(&quantized)?;
        let acc = evaluate(&session, &indexer, &ds, Split::Test)?;
        points.push(PqPoint {
            k,
            params: report.codebook_params as f64 + report.index_entries as f64 * 0.5,
            test_bce: acc.bce(),
            test_auc: acc.auc(),
        });
        log::info!("pq k={k}: test BCE {:.5}", points.last().unwrap().test_bce);
    }
    Ok((test_bce, points))
}

/// Train and return (final best state, its test BCE). Mirrors
/// `coordinator::train` but keeps the state. Used only by the PQ curve.
fn train_and_pull(store: &ArtifactStore, cfg: &TrainConfig) -> Result<(Vec<f32>, f64)> {
    use crate::coordinator::pipeline::BatchPipeline;
    use crate::runtime::session::EmbInput;
    use crate::tables::init::init_state;
    use crate::util::Rng;

    let mut session = DlrmSession::open(store, &cfg.artifact)?;
    let m = session.manifest.clone();
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, cfg.seed)?);
    let indexer = build_indexer(&m, cfg.seed)?;
    let mut rng = Rng::new(cfg.seed ^ 0x57A7E);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng))?;
    let batch = m.spec.batch;
    let mut rows = vec![0i32; session.emb_elems("train")?];
    let mut best: Option<(f64, Vec<f32>)> = None;
    let n_train_batches = ds.spec.train_samples.div_ceil(batch);
    let eval_every =
        if cfg.eval_every > 0 { cfg.eval_every } else { n_train_batches.div_ceil(6).max(1) };
    let mut step = 0usize;
    'outer: for epoch in 0..cfg.epochs {
        let shuffle = cfg.shuffle.then(|| cfg.seed ^ 0xE90C ^ epoch as u64);
        let mut pipe = BatchPipeline::start(
            &ds,
            Split::Train,
            batch,
            shuffle,
            cfg.pipeline_workers,
            cfg.pipeline_depth,
        );
        while let Some(b) = pipe.next() {
            indexer.fill_rowwise(&b.cats, batch, &mut rows);
            session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels)?;
            step += 1;
            if step % eval_every == 0 {
                let v = evaluate(&session, &indexer, &ds, Split::Val)?.bce();
                if best.as_ref().map(|(bv, _)| v < *bv).unwrap_or(true) {
                    best = Some((v, session.pull_state()?));
                }
            }
            if cfg.max_batches > 0 && step >= cfg.max_batches {
                break 'outer;
            }
        }
    }
    let (_, state) = best.ok_or_else(|| anyhow!("no evaluation happened; raise max_batches"))?;
    session.set_state(&state)?;
    let bce = evaluate(&session, &indexer, &ds, Split::Test)?.bce();
    Ok((state, bce))
}
