//! The serving subsystem — Appendix E made first-class.
//!
//! The paper's system argument is that CCE's index maps stay cheap to
//! evaluate on CPU at serving time; the ROADMAP north-star is heavy traffic
//! from millions of users. This module is the inference half of the stack:
//!
//! * [`snapshot`] — bake a trained `(state, Indexer)` into a read-only
//!   [`ServingSnapshot`]: learned/random/identity maps are materialized into
//!   flat `u32` gather tables with subtable bases folded in, replacing the
//!   training indexer's per-lookup enum dispatch.
//! * [`batcher`] — a bounded request queue with max-batch/max-wait dynamic
//!   admission, fed by a Zipf-skewed synthetic [`TrafficGen`] (skew is a CLI
//!   knob, so hot-id scenarios are a flag away, not a code change).
//! * [`engine`] — N index-generation workers fan the snapshot gather over
//!   cores and feed one device-execution thread; per-request p50/p95/p99
//!   latency and queue-wait are captured honestly.
//!
//! # Snapshot lifecycle
//!
//! 1. **Train** with a live `Indexer`; CCE clustering events rewrite its
//!    `IndexMap`s freely (`Algorithm 3` lines 14–16).
//! 2. **Bake** once training (or a clustering event mid-deploy) finishes:
//!    `ServingSnapshot::bake(&indexer)` materializes every map. The snapshot
//!    is immutable and `Sync` — workers share it by reference.
//! 3. **Serve** via `engine::run`; a model update means baking a *new*
//!    snapshot and swapping it in between runs. Parity with the live
//!    indexer is bit-exact (pinned by `tests/proptests.rs`), so train-time
//!    and serve-time index generation can never drift.
//!
//! `coordinator::serve` is a thin adapter wiring a `DlrmSession` + dataset
//! into this module; `cce serve` exposes the knobs via `config::ServeConfig`.

pub mod batcher;
pub mod engine;
pub mod snapshot;

pub use batcher::{BatchQueue, Request, TrafficGen};
pub use engine::{
    prepare, run, CountingExecutor, EngineConfig, Executor, PreparedBatch, PreparedEmb,
    ServeReport, SessionExecutor,
};
pub use snapshot::ServingSnapshot;
