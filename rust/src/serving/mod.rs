//! The serving subsystem — Appendix E made first-class.
//!
//! The paper's system argument is that CCE's index maps stay cheap to
//! evaluate on CPU at serving time; the ROADMAP north-star is heavy traffic
//! from millions of users. This module is the inference half of the stack:
//!
//! * [`snapshot`] — bake a trained `(state, Indexer)` into a read-only
//!   [`ServingSnapshot`]: learned/random/identity maps are materialized into
//!   flat `u32` gather tables with subtable bases folded in, replacing the
//!   training indexer's per-lookup enum dispatch. Tables are either owned
//!   (fresh bake) or zero-copy views of a mapped segment file.
//! * [`segment`] — the versioned on-disk snapshot format: checksummed
//!   64-byte-aligned sections behind a fixed little-endian header, written
//!   atomically, loaded via `mmap` in milliseconds regardless of table size.
//! * [`batcher`] — a bounded request queue with max-batch/max-wait dynamic
//!   admission, fed by a Zipf-skewed synthetic [`TrafficGen`] (skew is a CLI
//!   knob, so hot-id scenarios are a flag away, not a code change).
//! * [`engine`] — N index-generation workers fan the snapshot gather over
//!   cores and feed one device-execution thread; per-request p50/p95/p99
//!   latency and queue-wait are captured honestly. The engine serves from a
//!   generation-tagged [`SnapshotSlot`] so snapshots hot-swap under load.
//!
//! # Snapshot lifecycle: bake → write → mmap → swap
//!
//! 1. **Train** with a live `Indexer`; CCE clustering events rewrite its
//!    `IndexMap`s freely (`Algorithm 3` lines 14–16).
//! 2. **Bake**: `ServingSnapshot::bake(&indexer)` materializes every map
//!    into flat gather tables. The snapshot is immutable and `Sync`.
//! 3. **Write**: `segment::write_segment` persists the bake as generation N
//!    (`--snapshot-dir` makes `cce train` do this after every clustering
//!    event and at the end of the run).
//! 4. **Load**: `segment::load_segment` maps the file and serves straight
//!    off the page cache — cold start is O(header), not O(table), so a
//!    serving process boots in milliseconds (`cce serve --snapshot`).
//! 5. **Swap**: `SnapshotSlot::install_snapshot(path)` publishes generation
//!    N+1 to a running engine; workers pick it up at the next batch boundary
//!    while in-flight batches finish on generation N.
//!
//! Parity with the live indexer is bit-exact through the whole cycle —
//! bake, write, load, swap — pinned by `tests/proptests.rs`, so train-time
//! and serve-time index generation can never drift.
//!
//! `coordinator::serve` is a thin adapter wiring a `DlrmSession` + dataset
//! into this module; `cce serve` exposes the knobs via `config::ServeConfig`
//! and `cce snapshot write|inspect` manages segment files.

pub mod batcher;
pub mod engine;
pub mod segment;
pub mod snapshot;

pub use batcher::{BatchQueue, Request, TrafficGen};
pub use engine::{
    prepare, run, CountingExecutor, EngineConfig, Executor, PreparedBatch, PreparedEmb,
    ServeReport, SessionExecutor, SnapshotSlot,
};
pub use segment::{load_segment, load_segment_verified, write_segment, LoadedSegment};
pub use snapshot::ServingSnapshot;
