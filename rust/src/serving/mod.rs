//! The serving subsystem — Appendix E made first-class.
//!
//! The paper's system argument is that CCE's index maps stay cheap to
//! evaluate on CPU at serving time; the ROADMAP north-star is heavy traffic
//! from millions of users. This module is the inference half of the stack:
//!
//! * [`snapshot`] — bake a trained `(state, Indexer)` into a read-only
//!   [`ServingSnapshot`]: learned/random/identity maps are materialized into
//!   flat `u32` gather tables with subtable bases folded in, replacing the
//!   training indexer's per-lookup enum dispatch. Tables are either owned
//!   (fresh bake) or zero-copy views of a mapped segment file.
//! * [`segment`] — the versioned on-disk snapshot format: checksummed
//!   64-byte-aligned sections behind a fixed little-endian header, written
//!   atomically, loaded via `mmap` in milliseconds regardless of table size.
//! * [`batcher`] — a bounded request queue with max-batch/max-wait dynamic
//!   admission, fed by a Zipf-skewed synthetic [`TrafficGen`] (skew is a CLI
//!   knob, so hot-id scenarios are a flag away, not a code change).
//! * [`engine`] — N index-generation workers fan the snapshot gather over
//!   cores and feed one device-execution thread; per-request p50/p95/p99
//!   latency and queue-wait are captured honestly. The engine serves from a
//!   generation-tagged [`SnapshotSlot`] so snapshots hot-swap under load.
//! * [`watcher`] — a fault-tolerant directory poller that auto-installs new
//!   snapshot generations into the slot with full checksum verification,
//!   bounded retry + exponential backoff, and graceful skip of corrupt,
//!   torn, or incompatible files.
//!
//! # Admission: `Block` vs `Shed`
//!
//! The queue between traffic and workers is bounded either way; the
//! [`AdmissionPolicy`] knob (`[serve] admission`) decides what a full queue
//! means:
//!
//! * **`Block`** — producers wait for room. Every request is eventually
//!   served, which is the right contract for offline replay (benchmarks,
//!   batch scoring). Under sustained overload it is the WRONG contract for
//!   a service: queue wait grows with the backlog, so every latency
//!   percentile climbs without bound while throughput stays pinned at
//!   capacity — clients time out on their side, invisibly to the server.
//! * **`Shed { queue_depth, deadline }`** — a full queue rejects new
//!   requests at admission (counted in `ServeReport::rejected`), and each
//!   admitted request is stamped `arrival + deadline`; workers drop
//!   already-expired requests at batch formation (counted in
//!   `ServeReport::expired`, never executed — device time is never spent on
//!   an answer nobody is waiting for). The latency of every request that IS
//!   served stays bounded near `queue_depth / capacity` regardless of
//!   offered load. `requests + rejected + expired == offered` always holds.
//!
//! **Deadline semantics**: the deadline clock starts at *arrival* (the
//! intended emission time under paced load — see `EngineConfig::pace`), not
//! at admission. Expiry is checked when a batch is formed; a request that
//! expires between formation and device completion still executes and is
//! counted as a `deadline_miss` instead. `ServeReport` carries the derived
//! rates (`shed_rate`, `deadline_miss_rate`) plus `goodput_rps` — requests
//! served *within* deadline per second, the number a capacity planner
//! actually wants. The `overload` group in `perf_hot_paths` drives both
//! policies at {0.5×, 1×, 2×, 4×} capacity and `scripts/verify.sh` gates
//! that shed-mode p99 stays bounded at 4× while block-mode p99 explodes.
//!
//! # Snapshot lifecycle: bake → write → load → watch → swap
//!
//! 1. **Train** with a live `Indexer`; CCE clustering events rewrite its
//!    `IndexMap`s freely (`Algorithm 3` lines 14–16).
//! 2. **Bake**: `ServingSnapshot::bake(&indexer)` materializes every map
//!    into flat gather tables. The snapshot is immutable and `Sync`.
//! 3. **Write**: `segment::write_segment` persists the bake as generation N
//!    (`--snapshot-dir` makes `cce train` do this after every clustering
//!    event and at the end of the run; `[train] snapshot_keep = K` prunes
//!    all but the newest K generations after each write).
//! 4. **Load**: `segment::load_segment` maps the file and serves straight
//!    off the page cache — cold start is O(header), not O(table), so a
//!    serving process boots in milliseconds (`cce serve --snapshot`).
//!    `load_segment_verified` additionally checksums every section.
//! 5. **Watch**: `cce serve --snapshot-dir` boots from the newest segment
//!    that passes full verification ([`watcher::load_newest_verified`]) and
//!    attaches a [`SnapshotWatcher`] that polls for newer generations.
//! 6. **Swap**: `SnapshotSlot::install_snapshot(path)` verifies every
//!    section checksum, then publishes generation N+1 to the running
//!    engine; workers pick it up at the next batch boundary while in-flight
//!    batches finish on generation N.
//!
//! **Corrupt segments cannot reach traffic.** A cold boot may trust the
//! header-only load (a bad table crashes one process at startup), but every
//! live-swap path — explicit `install_snapshot` or the watcher — pays the
//! O(file) checksum first. The watcher additionally survives the failure:
//! a corrupt or torn file is retried with exponential backoff up to its
//! retry budget, then skipped (counted in `WatcherReport::skipped_corrupt`)
//! until its `(len, mtime)` identity changes; a shape-incompatible file is
//! skipped immediately and never retried; an old generation reappearing
//! cannot roll the slot backwards. The engine keeps serving the generation
//! it has through all of it — `testutil::fault` is the corruption harness
//! that pins this in tests.
//!
//! Parity with the live indexer is bit-exact through the whole cycle —
//! bake, write, load, swap — pinned by `tests/proptests.rs`, so train-time
//! and serve-time index generation can never drift.
//!
//! `coordinator::serve` is a thin adapter wiring a `DlrmSession` + dataset
//! into this module; `cce serve` exposes the knobs via `config::ServeConfig`
//! and `cce snapshot write|inspect` manages segment files.
//!
//! # Observability
//!
//! The engine, batcher, and watcher mirror their report counters into the
//! process-global metrics registry (`crate::obs`) at the same source
//! sites, and the hot phases run under `span!` guards — so a live run can
//! be scraped (`cce serve --metrics-addr`, Prometheus text), streamed
//! (`--stats-out`, JSONL), or traced (`--trace-out`, Chrome `trace.json`)
//! without the numbers ever disagreeing with the final `ServeReport`.
//! Naming scheme, span taxonomy, and overhead budget: docs/OBSERVABILITY.md;
//! report↔registry equality is pinned by `tests/obs_metrics.rs`.

pub mod batcher;
pub mod engine;
pub mod segment;
pub mod snapshot;
pub mod watcher;

pub use batcher::{AdmissionPolicy, BatchQueue, Request, TrafficGen, TryPush};
pub use engine::{
    prepare, run, CountingExecutor, EngineConfig, Executor, FaultyExecutor, PreparedBatch,
    PreparedEmb, ServeReport, SessionExecutor, SnapshotSlot,
};
pub use segment::{load_segment, load_segment_verified, write_segment, LoadedSegment};
pub use snapshot::ServingSnapshot;
pub use watcher::{SnapshotWatcher, WatcherConfig, WatcherReport, WatcherState};
