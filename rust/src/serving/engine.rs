//! Multi-worker serving engine: traffic → bounded queue → N index-generation
//! workers → device execution, with honest per-request latency capture.
//!
//! Thread layout (all scoped, graceful shutdown by queue close + channel
//! drop, no detached threads):
//!
//! ```text
//!   producer ──push──▶ BatchQueue ──pop_batch──▶ worker 0..N ──▶ ready
//!   (traffic)          (bounded,                 (snapshot       channel
//!                       admission)                gather +         │
//!                                                 padding)         ▼
//!                                                         exec thread (owns
//!                                                         the PJRT session)
//! ```
//!
//! Index generation is the CPU-side cost Appendix E argues is cheap; baking
//! it into a snapshot gather and fanning it over workers keeps the single
//! device-execution thread saturated. Per-request latency is measured from
//! arrival at the queue to completion of the request's device batch — the
//! queue wait, admission wait, index generation, and execution all count,
//! unlike the seed loop which charged every request the whole burst's
//! end-to-end time and computed (then discarded) a percentile.

use crate::runtime::session::{DlrmSession, EmbInput};
use crate::serving::batcher::{AdmissionPolicy, BatchQueue, Request, TrafficGen, TryPush};
use crate::serving::segment;
use crate::serving::snapshot::ServingSnapshot;
use crate::tables::indexer::MethodKind;
use crate::util::timer::TimingStats;
use anyhow::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Engine tuning knobs (derived from `config::ServeConfig`).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// index-generation worker threads
    pub workers: usize,
    /// admitted requests per device batch (clamped to the device batch)
    pub max_batch: usize,
    /// batch-formation fill window for partial batches
    pub max_wait: Duration,
    /// bounded request-queue depth (Block mode; Shed carries its own budget)
    pub queue_depth: usize,
    /// block the producer on a full queue, or shed (reject + drop expired)
    pub admission: AdmissionPolicy,
    /// offered-load pacing: emit one request per this interval, stamping
    /// each with its INTENDED arrival time. `None` = emit as fast as the
    /// queue accepts (the replay-benchmark behavior). Pacing is what makes
    /// overload honest: a blocked producer falls behind its schedule, and
    /// the backlog shows up in every subsequent request's measured latency
    /// instead of being silently absorbed.
    pub pace: Option<Duration>,
}

/// Embedding-side input of one prepared batch, padded to the device batch.
pub enum PreparedEmb {
    Rows(Vec<i32>),
    Hashes(Vec<f32>),
}

/// Generation-tagged snapshot slot the engine serves from. Workers re-read
/// the current `(generation, snapshot)` pair per batch, so a new snapshot
/// installed mid-run (a post-clustering-event segment from `--cluster-overlap`
/// training) takes effect at the next batch boundary while in-flight batches
/// finish on the old generation — no pause, no partial batches.
///
/// The slot is a mutex around an `Arc` swap, not a lock-free pointer: the
/// critical section is one refcount bump, held for nanoseconds, and every
/// worker takes it once per *batch* (hundreds of requests), so contention is
/// unmeasurable next to the gather itself — `perf_hot_paths` pins the
/// swap-pause p99 to keep that claim honest.
pub struct SnapshotSlot {
    inner: Mutex<(u64, Arc<ServingSnapshot>)>,
    /// lock-free mirror of the installed generation (for reporting)
    generation: AtomicU64,
}

impl SnapshotSlot {
    pub fn new(snap: ServingSnapshot) -> SnapshotSlot {
        SnapshotSlot { inner: Mutex::new((0, Arc::new(snap))), generation: AtomicU64::new(0) }
    }

    /// Latest installed generation (0 = the snapshot the slot started with).
    ///
    /// Coherence contract (pinned by `tests/interleavings.rs`): this mirror
    /// is never AHEAD of what `current()` returns — `install` publishes the
    /// mirror inside the lock, after updating the pair — and a `current()`
    /// call happening-after an install observes at least that generation
    /// via the mutex. So for any thread: `generation() <= current().0 <=
    /// generation()` sampled in that order never decreases.
    pub fn generation(&self) -> u64 {
        // ORDERING: Acquire pairs with the Release store in install() so a
        // reader that sees generation N also sees everything the installer
        // wrote before publishing N (report fields, segment bookkeeping).
        self.generation.load(Ordering::Acquire)
    }

    /// The coherent `(generation, snapshot)` pair to serve the next batch on.
    pub fn current(&self) -> (u64, Arc<ServingSnapshot>) {
        let g = self.inner.lock().unwrap();
        (g.0, g.1.clone())
    }

    /// Swap in a new snapshot; returns its generation. Rejects snapshots the
    /// running executable cannot serve (different method or sample stride —
    /// the device side is compiled for a fixed embedding-input shape).
    pub fn install(&self, snap: ServingSnapshot) -> Result<u64> {
        let mut g = self.inner.lock().unwrap();
        anyhow::ensure!(
            snap.kind() == g.1.kind() && snap.sample_stride() == g.1.sample_stride(),
            "incompatible snapshot: {:?}/{} installed, {:?}/{} offered",
            g.1.kind(),
            g.1.sample_stride(),
            snap.kind(),
            snap.sample_stride()
        );
        g.0 += 1;
        g.1 = Arc::new(snap);
        // ORDERING: Release pairs with the Acquire load in generation().
        // The placement is load-bearing for the audit invariant "no worker
        // observes generation N+1 while reading snapshot N": the store
        // happens INSIDE the critical section and AFTER the pair update, so
        // the mirror can lag the pair (benign: a reader sees N, then
        // current() returns N+1) but can never lead it — and once a reader
        // DOES see N+1 here, the mutex release/acquire guarantees its next
        // current() returns generation >= N+1.
        self.generation.store(g.0, Ordering::Release);
        Ok(g.0)
    }

    /// Load a segment file and swap it in — the live-deploy API. Every
    /// section checksum is verified first: a quick (header-only) load is
    /// fine for a cold boot, where a corrupt table crashes one process at
    /// startup, but swapping into a LIVE engine must never publish a
    /// bit-flipped gather table to in-flight traffic, so this path pays the
    /// O(file) hash before the old generation is released.
    pub fn install_snapshot(&self, path: &Path) -> Result<u64> {
        let loaded = segment::load_segment_verified(path)?;
        self.install(loaded.snapshot)
    }
}

/// One device-ready batch: fixed-shape inputs plus the bookkeeping needed
/// to attribute latency to each real request.
pub struct PreparedBatch {
    pub dense: Vec<f32>,
    pub emb: PreparedEmb,
    /// real (admitted) requests; rows `real..device_batch` are padding
    pub real: usize,
    pub arrivals: Vec<Instant>,
    /// per-request deadlines (shed mode; `None` entries never miss)
    pub deadlines: Vec<Option<Instant>>,
    /// per-request queue+formation wait, measured at batch formation
    pub queue_wait_ns: Vec<u64>,
    /// time this batch spent in snapshot index generation
    pub index_ns: u64,
    /// snapshot generation the batch was prepared on (hot-swap attribution)
    pub generation: u64,
}

/// Pack admitted requests into a device-shaped batch. Index generation runs
/// over the `real` admitted rows only; padding rows are a memcpy of the last
/// real row (mirroring `BatchIter`'s tail padding). Gather work thus scales
/// with admitted requests — the seed loop regenerated indices for the full
/// `eval_batch` regardless — while buffer allocation stays device-shaped.
pub fn prepare(snap: &ServingSnapshot, reqs: &[Request], device_batch: usize) -> PreparedBatch {
    assert!(!reqs.is_empty() && reqs.len() <= device_batch);
    let formed = Instant::now();
    let real = reqs.len();
    let f_n = snap.n_features();
    let n_dense = reqs[0].dense.len();
    let mut cats = vec![0u32; real * f_n];
    let mut dense = vec![0f32; device_batch * n_dense];
    for (i, r) in reqs.iter().enumerate() {
        cats[i * f_n..(i + 1) * f_n].copy_from_slice(&r.cats);
        dense[i * n_dense..(i + 1) * n_dense].copy_from_slice(&r.dense);
    }
    for b in real..device_batch {
        dense.copy_within((real - 1) * n_dense..real * n_dense, b * n_dense);
    }
    let stride = snap.sample_stride();
    // index_ns times the snapshot gather ONLY — buffer allocation, dense
    // packing, and padding memcpys are batching overhead, not the Appendix E
    // CPU-side index cost the report attributes to it
    let index_ns;
    let emb = match snap.kind() {
        MethodKind::RowWise | MethodKind::ElementWise => {
            let mut out = vec![0i32; device_batch * stride];
            let t0 = Instant::now();
            match snap.kind() {
                MethodKind::RowWise => snap.fill_rowwise(&cats, real, &mut out[..real * stride]),
                _ => snap.fill_elementwise(&cats, real, &mut out[..real * stride]),
            }
            index_ns = t0.elapsed().as_nanos() as u64;
            for b in real..device_batch {
                out.copy_within((real - 1) * stride..real * stride, b * stride);
            }
            PreparedEmb::Rows(out)
        }
        MethodKind::Dhe => {
            let mut out = vec![0f32; device_batch * stride];
            let t0 = Instant::now();
            snap.fill_dhe(&cats, real, &mut out[..real * stride]);
            index_ns = t0.elapsed().as_nanos() as u64;
            for b in real..device_batch {
                out.copy_within((real - 1) * stride..real * stride, b * stride);
            }
            PreparedEmb::Hashes(out)
        }
    };
    PreparedBatch {
        dense,
        emb,
        real,
        arrivals: reqs.iter().map(|r| r.arrival).collect(),
        deadlines: reqs.iter().map(|r| r.deadline).collect(),
        queue_wait_ns: reqs
            .iter()
            .map(|r| formed.duration_since(r.arrival).as_nanos() as u64)
            .collect(),
        index_ns,
        generation: 0,
    }
}

/// The device-execution step the engine drives. `DlrmSession` is the real
/// backend; `CountingExecutor` lets tests and benches run the full engine
/// without PJRT artifacts.
pub trait Executor {
    /// Fixed batch size the compiled executable expects.
    fn device_batch(&self) -> usize;
    fn execute(&mut self, batch: &PreparedBatch) -> Result<()>;
}

/// Executor over a live PJRT session's `predict` executable.
pub struct SessionExecutor<'a> {
    session: &'a DlrmSession,
}

impl<'a> SessionExecutor<'a> {
    pub fn new(session: &'a DlrmSession) -> SessionExecutor<'a> {
        SessionExecutor { session }
    }
}

impl Executor for SessionExecutor<'_> {
    fn device_batch(&self) -> usize {
        self.session.manifest.spec.eval_batch
    }

    fn execute(&mut self, batch: &PreparedBatch) -> Result<()> {
        let emb = match &batch.emb {
            PreparedEmb::Rows(r) => EmbInput::Rows(r),
            PreparedEmb::Hashes(h) => EmbInput::Hashes(h),
        };
        let _probs = self.session.predict(&batch.dense, emb)?;
        Ok(())
    }
}

/// Device stand-in for tests/benches: records what it executed and keeps a
/// checksum so the compiler cannot elide the batch contents.
#[derive(Debug, Default)]
pub struct CountingExecutor {
    pub batch: usize,
    pub batches: usize,
    pub rows_seen: usize,
    pub checksum: u64,
}

impl CountingExecutor {
    pub fn new(batch: usize) -> CountingExecutor {
        CountingExecutor { batch, ..Default::default() }
    }
}

impl Executor for CountingExecutor {
    fn device_batch(&self) -> usize {
        self.batch
    }

    fn execute(&mut self, batch: &PreparedBatch) -> Result<()> {
        self.batches += 1;
        self.rows_seen += batch.real;
        match &batch.emb {
            PreparedEmb::Rows(r) => {
                for &x in r {
                    self.checksum = self.checksum.wrapping_add(x as u32 as u64);
                }
            }
            PreparedEmb::Hashes(h) => {
                for &x in h {
                    self.checksum = self.checksum.wrapping_add(x.to_bits() as u64);
                }
            }
        }
        Ok(())
    }
}

/// Fault-injection executor for tests and chaos drills: behaves like
/// [`CountingExecutor`] until `fail_after` batches have executed, then every
/// further `execute` fails — the "device fell over mid-stream" scenario the
/// engine must shut down cleanly from (producer and workers unblocked, error
/// propagated, no hang). `fail_after = 0` fails immediately.
#[derive(Debug)]
pub struct FaultyExecutor {
    pub inner: CountingExecutor,
    pub fail_after: usize,
}

impl FaultyExecutor {
    pub fn new(batch: usize, fail_after: usize) -> FaultyExecutor {
        FaultyExecutor { inner: CountingExecutor::new(batch), fail_after }
    }
}

impl Executor for FaultyExecutor {
    fn device_batch(&self) -> usize {
        self.inner.batch
    }

    fn execute(&mut self, batch: &PreparedBatch) -> Result<()> {
        if self.inner.batches >= self.fail_after {
            anyhow::bail!("injected device fault after {} batches", self.inner.batches);
        }
        self.inner.execute(batch)
    }
}

/// What a serving run reports (printed by `cce serve` and the bench).
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// requests actually executed on the device
    pub requests: usize,
    /// requests the traffic source offered (`requests + rejected + expired`)
    pub offered: usize,
    /// shed at admission: the queue was at its budget when they arrived
    pub rejected: usize,
    /// shed at batch formation: their deadline passed while they queued
    pub expired: usize,
    /// `(rejected + expired) / offered`
    pub shed_rate: f64,
    /// served requests that completed after their deadline
    pub deadline_misses: usize,
    /// `deadline_misses / requests` (0 when no deadlines are in force)
    pub deadline_miss_rate: f64,
    /// served-within-deadline requests per second — the throughput that
    /// actually mattered to callers
    pub goodput_rps: f64,
    pub batches: usize,
    /// padding rows sent to the device (tail batches only under backlog)
    pub padded_rows: usize,
    pub workers: usize,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
    /// per-request end-to-end latency: queue wait + admission + index + exec
    pub latency: TimingStats,
    /// per-request queue + admission wait alone
    pub queue_wait: TimingStats,
    /// summed index-generation time across workers (can exceed wall time)
    pub index_secs: f64,
    pub exec_secs: f64,
    /// snapshot bake cost, filled in by callers that bake per run
    pub snapshot_bytes: usize,
    pub bake_secs: f64,
    /// device state bytes moved at bake time (serve_trained uploads the
    /// checkpoint's group buffers; pure indexer bakes and mmap boots
    /// transfer nothing and report 0)
    pub bake_transfer_bytes: u64,
    /// segment load cost, filled in by callers that boot from a segment
    pub load_secs: f64,
    /// generation transitions observed at the exec thread (hot swaps that
    /// actually reached device batches during the run)
    pub snapshot_swaps: usize,
    /// generation of the last executed batch
    pub generation: u64,
}

/// Run the engine until `n_requests` have been **offered**. In `Block` mode
/// every offered request is eventually served; in `Shed` mode requests the
/// queue budget rejects or whose deadline expires in the queue are counted
/// and dropped, never executed — `requests + rejected + expired == offered`
/// always holds. The engine serves whatever snapshot `slot` currently holds;
/// `SnapshotSlot::install` / `install_snapshot` from any other thread
/// hot-swaps it between batches.
pub fn run<E: Executor>(
    executor: &mut E,
    slot: &SnapshotSlot,
    traffic: TrafficGen<'_>,
    cfg: &EngineConfig,
    n_requests: usize,
) -> Result<ServeReport> {
    assert!(n_requests >= 1 && cfg.workers >= 1);
    let device_batch = executor.device_batch();
    let max_batch = cfg.max_batch.clamp(1, device_batch);
    let depth = match &cfg.admission {
        AdmissionPolicy::Block => cfg.queue_depth,
        AdmissionPolicy::Shed { queue_depth, .. } => *queue_depth,
    };
    let queue = BatchQueue::new(depth);
    let index_ns = AtomicU64::new(0);
    let rejected = AtomicU64::new(0);
    let expired = AtomicU64::new(0);
    let mut latencies = Vec::with_capacity(n_requests);
    let mut queue_waits = Vec::with_capacity(n_requests);
    let mut batches = 0usize;
    let mut padded_rows = 0usize;
    let mut served = 0usize;
    let mut deadline_misses = 0usize;
    let mut exec_secs = 0f64;
    let mut snapshot_swaps = 0usize;
    let mut last_gen: Option<u64> = None;
    let mut exec_err: Option<anyhow::Error> = None;
    let t_all = Instant::now();

    // live registry mirrors (docs/OBSERVABILITY.md): incremented at the same
    // sites as the local totals below, so a mid-run /metrics scrape and the
    // final ServeReport cannot drift — tests/obs_metrics.rs pins registry
    // deltas == report fields, including the conservation invariant.
    let m_offered = crate::obs_counter!("serve.requests.offered");
    let m_served = crate::obs_counter!("serve.requests.served");
    let m_rejected = crate::obs_counter!("serve.requests.rejected");
    let m_expired = crate::obs_counter!("serve.requests.expired");
    let m_batches = crate::obs_counter!("serve.batches");
    let m_padded = crate::obs_counter!("serve.padded_rows");
    let m_misses = crate::obs_counter!("serve.deadline_misses");
    let m_swaps = crate::obs_counter!("serve.snapshot.swaps");
    let g_gen = crate::obs_gauge!("serve.snapshot.generation");
    let h_latency = crate::obs_hist!("serve.latency.ns");
    let h_rows = crate::obs_hist!("serve.batch.rows");

    std::thread::scope(|s| {
        let (ready_tx, ready_rx) = sync_channel::<PreparedBatch>(cfg.workers * 2);

        // producer: stamp arrivals and feed the bounded queue under the
        // configured admission policy
        let (producer_queue, rejected) = (&queue, &rejected);
        let admission = cfg.admission.clone();
        let pace = cfg.pace;
        s.spawn(move || {
            let mut traffic = traffic;
            let t0 = Instant::now();
            for i in 0..n_requests {
                let mut req = traffic.next_request();
                if let Some(gap) = pace {
                    // the request's arrival is its INTENDED emission time on
                    // the offered-load schedule, whether or not the producer
                    // is on time — a blocked producer's backlog then shows up
                    // in every subsequent request's measured latency, which
                    // is exactly how real clients experience an overloaded
                    // blocking server
                    let target_ns = (gap.as_nanos() as u64).saturating_mul(i as u64);
                    let target = t0 + Duration::from_nanos(target_ns);
                    let now = Instant::now();
                    if let Some(ahead) = target.checked_duration_since(now) {
                        if ahead > Duration::from_micros(50) {
                            std::thread::sleep(ahead);
                        }
                    }
                    req.arrival = target;
                }
                m_offered.inc();
                match &admission {
                    AdmissionPolicy::Block => {
                        if !producer_queue.push(req) {
                            return; // queue closed under us (exec error)
                        }
                    }
                    AdmissionPolicy::Shed { deadline, .. } => {
                        req.deadline = deadline.map(|d| req.arrival + d);
                        match producer_queue.try_push(req) {
                            TryPush::Pushed => {}
                            TryPush::Full(_) => {
                                // ORDERING: Relaxed counter; aggregated only
                                // after the scope joins every thread
                                rejected.fetch_add(1, Ordering::Relaxed);
                                m_rejected.inc();
                            }
                            TryPush::Closed(_) => return,
                        }
                    }
                }
            }
            producer_queue.close();
        });

        // index-generation workers: re-read the slot per batch so installed
        // snapshots take effect at the next batch boundary; drop requests
        // whose deadline already passed — executing them would burn device
        // time on answers nobody is waiting for
        for _ in 0..cfg.workers {
            let tx = ready_tx.clone();
            let (queue, index_ns, expired) = (&queue, &index_ns, &expired);
            s.spawn(move || {
                while let Some(mut reqs) = queue.pop_batch(max_batch, cfg.max_wait) {
                    let now = Instant::now();
                    let before = reqs.len();
                    reqs.retain(|r| r.deadline.map_or(true, |d| d > now));
                    // ORDERING: Relaxed counter; aggregated after scope join
                    expired.fetch_add((before - reqs.len()) as u64, Ordering::Relaxed);
                    m_expired.add((before - reqs.len()) as u64);
                    if reqs.is_empty() {
                        continue; // whole batch expired in the queue
                    }
                    let (generation, snap) = slot.current();
                    h_rows.record(reqs.len() as u64);
                    let _sp = crate::span!("serve.batch.prepare");
                    let mut pb = prepare(&snap, &reqs, device_batch);
                    drop(_sp);
                    pb.generation = generation;
                    // ORDERING: Relaxed counter; aggregated after scope join
                    index_ns.fetch_add(pb.index_ns, Ordering::Relaxed);
                    if tx.send(pb).is_err() {
                        return; // exec thread gone
                    }
                }
            });
        }
        drop(ready_tx);

        // exec loop on the calling thread — it owns the PJRT objects
        while let Ok(pb) = ready_rx.recv() {
            if exec_err.is_none() {
                let te = Instant::now();
                let sp_exec = crate::span!("serve.batch.exec");
                let exec_res = executor.execute(&pb);
                drop(sp_exec);
                if let Err(e) = exec_res {
                    // fail fast but shut down cleanly: close the queue so the
                    // producer and workers unblock, then drain the channel
                    exec_err = Some(e);
                    queue.close();
                    continue;
                }
                exec_secs += te.elapsed().as_secs_f64();
                // batches from different workers can interleave generations
                // briefly after a swap; count the transitions actually seen
                if last_gen != Some(pb.generation) {
                    snapshot_swaps += usize::from(last_gen.is_some());
                    m_swaps.add(u64::from(last_gen.is_some()));
                    g_gen.set(pb.generation);
                    last_gen = Some(pb.generation);
                }
                let done = Instant::now();
                for ((arrival, wait_ns), deadline) in
                    pb.arrivals.iter().zip(&pb.queue_wait_ns).zip(&pb.deadlines)
                {
                    let lat_ns = done.duration_since(*arrival).as_nanos() as u64;
                    latencies.push(lat_ns as f64);
                    h_latency.record(lat_ns);
                    queue_waits.push(*wait_ns as f64);
                    let miss = deadline.map_or(false, |d| done > d);
                    deadline_misses += usize::from(miss);
                    m_misses.add(u64::from(miss));
                }
                served += pb.real;
                m_served.add(pb.real as u64);
                batches += 1;
                m_batches.inc();
                padded_rows += device_batch - pb.real;
                m_padded.add((device_batch - pb.real) as u64);
            }
        }
    });
    if let Some(e) = exec_err {
        return Err(e);
    }

    let elapsed = t_all.elapsed().as_secs_f64();
    let rejected = rejected.into_inner() as usize;
    let expired = expired.into_inner() as usize;
    // Always-on accounting invariant (was a release-mode no-op
    // debug_assert): a run that lost or double-counted requests must fail
    // the report, not ship corrupt admission metrics. Checked only on the
    // clean path — the exec-error return above legitimately abandons
    // in-flight batches.
    check_conservation(served, rejected, expired, n_requests)?;
    Ok(ServeReport {
        requests: served,
        offered: n_requests,
        rejected,
        expired,
        shed_rate: (rejected + expired) as f64 / (n_requests as f64).max(1.0),
        deadline_misses,
        deadline_miss_rate: deadline_misses as f64 / (served as f64).max(1.0),
        goodput_rps: (served - deadline_misses) as f64 / elapsed.max(1e-12),
        batches,
        padded_rows,
        workers: cfg.workers,
        elapsed_secs: elapsed,
        throughput_rps: served as f64 / elapsed.max(1e-12),
        latency: TimingStats::from_samples(latencies),
        queue_wait: TimingStats::from_samples(queue_waits),
        // ORDERING: Relaxed — the scope joined; all worker adds are visible
        index_secs: index_ns.load(Ordering::Relaxed) as f64 / 1e9,
        exec_secs,
        snapshot_bytes: slot.current().1.host_bytes(),
        bake_secs: 0.0,
        bake_transfer_bytes: 0,
        load_secs: 0.0,
        snapshot_swaps,
        generation: last_gen.unwrap_or(0),
    })
}

/// Request-conservation invariant: every offered request must be accounted
/// for as served, rejected at admission, or expired in the queue — exactly
/// once. Split out of `run` so the failure path is unit-testable.
fn check_conservation(
    served: usize,
    rejected: usize,
    expired: usize,
    offered: usize,
) -> Result<()> {
    anyhow::ensure!(
        served + rejected + expired == offered,
        "request conservation violated: served {served} + rejected {rejected} + \
         expired {expired} != offered {offered}"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{DatasetSpec, SyntheticDataset};
    use crate::tables::indexer::Indexer;
    use crate::tables::layout::TablePlan;
    use crate::util::Rng;

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50],
            n_dense: 3,
            train_samples: 40,
            val_samples: 8,
            test_samples: 32,
            latent_clusters: 4,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: 1,
        })
    }

    fn snapshot() -> ServingSnapshot {
        let mut rng = Rng::new(0);
        let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[11, 50], 8, 2, 2, 4));
        ServingSnapshot::bake(&ix)
    }

    fn cfg(workers: usize, max_batch: usize) -> EngineConfig {
        EngineConfig {
            workers,
            max_batch,
            max_wait: Duration::from_millis(20),
            queue_depth: 256,
            admission: AdmissionPolicy::Block,
            pace: None,
        }
    }

    #[test]
    fn engine_serves_every_request_once() {
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        for workers in [1usize, 4] {
            let mut exec = CountingExecutor::new(16);
            let traffic = TrafficGen::new(&ds, 0.99, 7);
            let rep = run(&mut exec, &slot, traffic, &cfg(workers, 16), 100).unwrap();
            assert_eq!(rep.requests, 100, "workers={workers}");
            assert_eq!(exec.rows_seen, 100);
            assert_eq!(rep.latency.n, 100);
            assert_eq!(rep.queue_wait.n, 100);
            assert!(rep.throughput_rps > 0.0);
            assert_eq!(rep.batches, exec.batches);
            assert_eq!(rep.padded_rows, rep.batches * 16 - 100);
            assert_eq!(rep.snapshot_swaps, 0, "nothing installed mid-run");
            assert_eq!(rep.generation, 0);
        }
    }

    #[test]
    fn only_tail_batches_are_padded_under_backlog() {
        // regression for the seed loop's wasted work: with a generous
        // admission window and a single worker, every batch fills to
        // max_batch except the final tail of the burst
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        let mut exec = CountingExecutor::new(16);
        let traffic = TrafficGen::new(&ds, 0.0, 3);
        let c = EngineConfig {
            workers: 1,
            max_batch: 16,
            max_wait: Duration::from_millis(200),
            queue_depth: 256,
            admission: AdmissionPolicy::Block,
            pace: None,
        };
        let rep = run(&mut exec, &slot, traffic, &c, 100).unwrap();
        assert_eq!(rep.requests, 100);
        assert_eq!(rep.batches, 100usize.div_ceil(16));
        assert_eq!(rep.padded_rows, rep.batches * 16 - 100, "padding beyond the tail");
    }

    #[test]
    fn prepare_pads_tail_rows_with_last_real_request() {
        let ds = ds();
        let snap = snapshot();
        let mut tg = TrafficGen::new(&ds, 0.0, 5);
        let reqs: Vec<Request> = (0..3).map(|_| tg.next_request()).collect();
        let pb = prepare(&snap, &reqs, 8);
        assert_eq!(pb.real, 3);
        let n_dense = reqs[0].dense.len();
        let stride = snap.sample_stride();
        let rows = match &pb.emb {
            PreparedEmb::Rows(r) => r,
            _ => panic!("rowwise snapshot"),
        };
        assert_eq!(rows.len(), 8 * stride);
        // real rows match a direct snapshot fill over the admitted requests
        let cats: Vec<u32> = reqs.iter().flat_map(|r| r.cats.iter().copied()).collect();
        let mut want = vec![0i32; 3 * stride];
        snap.fill_rowwise(&cats, 3, &mut want);
        assert_eq!(&rows[..3 * stride], &want[..]);
        // padding rows replicate the last real row (indices AND dense)
        for b in 3..8 {
            assert_eq!(rows[b * stride..(b + 1) * stride], rows[2 * stride..3 * stride]);
            assert_eq!(
                pb.dense[b * n_dense..(b + 1) * n_dense],
                pb.dense[2 * n_dense..3 * n_dense]
            );
        }
    }

    #[test]
    fn executor_error_shuts_down_cleanly() {
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        for fail_after in [0usize, 3] {
            let mut exec = FaultyExecutor::new(16, fail_after);
            let traffic = TrafficGen::new(&ds, 0.0, 1);
            let err = run(&mut exec, &slot, traffic, &cfg(4, 16), 1000);
            assert!(err.is_err(), "error must propagate (fail_after={fail_after})");
            assert_eq!(exec.inner.batches, fail_after, "fails exactly at the injection point");
        }
    }

    #[test]
    fn shed_mode_conserves_every_offered_request() {
        // a tiny queue budget against a generous burst: some requests are
        // rejected at admission, but served + rejected + expired must equal
        // offered exactly — nothing lost, nothing double-counted
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        let mut exec = CountingExecutor::new(16);
        let traffic = TrafficGen::new(&ds, 0.99, 13);
        let c = EngineConfig {
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_micros(100),
            queue_depth: 256, // ignored in Shed mode
            admission: AdmissionPolicy::Shed { queue_depth: 4, deadline: None },
            pace: None,
        };
        let rep = run(&mut exec, &slot, traffic, &c, 500).unwrap();
        assert_eq!(rep.offered, 500);
        assert_eq!(rep.requests + rep.rejected + rep.expired, 500, "conservation");
        assert_eq!(rep.requests, exec.rows_seen, "every served request hit the device once");
        assert_eq!(rep.latency.n, rep.requests);
        assert_eq!(rep.expired, 0, "no deadline configured, so nothing can expire");
        let want_rate = (rep.rejected + rep.expired) as f64 / 500.0;
        assert!((rep.shed_rate - want_rate).abs() < 1e-12);
        assert!(rep.requests >= 1, "an unloaded engine must serve something");
    }

    #[test]
    fn expired_requests_are_dropped_at_batch_formation() {
        // a zero deadline expires every request the instant it is admitted:
        // the device must execute NOTHING, and the report must say so
        // without panicking on the empty latency set
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        let mut exec = CountingExecutor::new(16);
        let traffic = TrafficGen::new(&ds, 0.0, 17);
        let c = EngineConfig {
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(5),
            queue_depth: 256,
            admission: AdmissionPolicy::Shed {
                queue_depth: 64,
                deadline: Some(Duration::ZERO),
            },
            pace: None,
        };
        let rep = run(&mut exec, &slot, traffic, &c, 200).unwrap();
        assert_eq!(rep.requests, 0, "expired requests must never execute");
        assert_eq!(exec.batches, 0);
        assert_eq!(rep.requests + rep.rejected + rep.expired, 200, "conservation");
        assert!(rep.expired >= 1, "zero deadline must expire whatever was admitted");
        assert_eq!(rep.latency.n, 0);
        assert_eq!(rep.deadline_misses, 0, "nothing served, nothing can miss");
        assert!((rep.shed_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn generous_deadline_sheds_nothing_and_misses_nothing() {
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        let mut exec = CountingExecutor::new(16);
        let traffic = TrafficGen::new(&ds, 0.5, 19);
        let c = EngineConfig {
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(20),
            queue_depth: 256,
            admission: AdmissionPolicy::Shed {
                queue_depth: 4096,
                deadline: Some(Duration::from_secs(3600)),
            },
            pace: None,
        };
        let rep = run(&mut exec, &slot, traffic, &c, 300).unwrap();
        assert_eq!(rep.requests, 300, "roomy budget + hour deadline serves everything");
        assert_eq!(rep.rejected, 0);
        assert_eq!(rep.expired, 0);
        assert_eq!(rep.deadline_misses, 0);
        assert_eq!(rep.shed_rate, 0.0);
        assert_eq!(rep.deadline_miss_rate, 0.0);
        assert!(rep.goodput_rps > 0.0);
    }

    #[test]
    fn install_snapshot_rejects_bit_flipped_segment_and_keeps_serving() {
        // satellite: a corrupt segment offered to a live slot must be
        // rejected by checksum BEFORE the swap, leaving the old generation
        // serving traffic undisturbed
        let dir = crate::testutil::TempDir::new("engine_corrupt_install");
        let path = dir.path().join("snap-gen1.cceseg");
        segment::write_segment(&snapshot(), 1, &path).unwrap();
        crate::testutil::fault::flip_section_byte(&path, "rows", 0).unwrap();

        let slot = SnapshotSlot::new(snapshot());
        let err = slot.install_snapshot(&path);
        assert!(err.is_err(), "bit-flipped section must fail verification");
        assert_eq!(slot.generation(), 0, "failed install must not bump the generation");

        // the old generation still serves a full run
        let ds = ds();
        let mut exec = CountingExecutor::new(16);
        let traffic = TrafficGen::new(&ds, 0.0, 23);
        let rep = run(&mut exec, &slot, traffic, &cfg(2, 16), 100).unwrap();
        assert_eq!(rep.requests, 100);
        assert_eq!(rep.generation, 0);
    }

    #[test]
    fn install_rejects_incompatible_snapshot() {
        let slot = SnapshotSlot::new(snapshot()); // rowwise, [11, 50]
        let mut rng = Rng::new(1);
        let robe = ServingSnapshot::bake(&Indexer::new_robe(&mut rng, &[11, 50], 30, 8, 2));
        assert!(slot.install(robe).is_err(), "method change must be rejected");
        // a rebake of the same plan is compatible and bumps the generation
        let gen = slot.install(snapshot()).unwrap();
        assert_eq!(gen, 1);
        assert_eq!(slot.generation(), 1);
        assert_eq!(slot.current().0, 1);
    }

    #[test]
    fn hot_swap_mid_run_serves_every_request() {
        let ds = ds();
        let slot = SnapshotSlot::new(snapshot());
        let stop = std::sync::atomic::AtomicBool::new(false);
        let rep = std::thread::scope(|s| {
            // swapper: keep installing rebaked generations while serving
            s.spawn(|| {
                // ORDERING: Relaxed stop flag — no data is published
                // through it, and the scope join bounds its lifetime
                while !stop.load(Ordering::Relaxed) {
                    slot.install(snapshot()).unwrap();
                    std::thread::sleep(Duration::from_micros(200));
                }
            });
            let mut exec = CountingExecutor::new(16);
            let traffic = TrafficGen::new(&ds, 0.5, 11);
            let rep = run(&mut exec, &slot, traffic, &cfg(2, 8), 400).unwrap();
            // ORDERING: Relaxed stop flag — see the load above
            stop.store(true, Ordering::Relaxed);
            rep
        });
        // no request lost or double-served across however many swaps landed
        assert_eq!(rep.requests, 400);
        assert!(slot.generation() >= 1, "swapper never installed");
        assert!(rep.generation <= slot.generation());
    }

    #[test]
    fn conservation_check_accepts_balanced_and_rejects_drift() {
        assert!(check_conservation(10, 0, 0, 10).is_ok());
        assert!(check_conservation(5, 3, 2, 10).is_ok());
        assert!(check_conservation(0, 0, 0, 0).is_ok());
        // a lost request must fail the report, in release builds too
        let err = check_conservation(5, 3, 1, 10).unwrap_err();
        assert!(err.to_string().contains("request conservation"), "{err}");
        assert!(check_conservation(11, 0, 0, 10).is_err(), "double count");
    }
}
