//! Dynamic batching for the serving engine: a bounded request queue with
//! max-batch / max-wait admission, fed by a Zipf-skewed synthetic traffic
//! generator.
//!
//! Batch formation (the standard dynamic-batching contract): a worker
//! blocks until at least one request is queued, then waits up to `max_wait`
//! for the batch to fill to `max_batch` before dispatching whatever has
//! accumulated. Under backlog every batch is full; only the tail of a burst
//! is partial — so device padding is confined to tail batches, unlike the
//! seed serve loop which padded every batch to `eval_batch`.
//!
//! Admission is a separate, orthogonal choice ([`AdmissionPolicy`]): in
//! `Block` mode a full queue blocks the producer (the PR-1 behavior — fine
//! for replay benchmarks, catastrophic under real overload, where it
//! silently stretches every latency instead of bounding any); in `Shed`
//! mode a full queue rejects the request immediately (`try_push`) and
//! requests that outlive their deadline are dropped at batch formation
//! rather than executed. Shedding keeps p99 bounded at any offered load —
//! the overload group in `perf_hot_paths` tracks exactly that.

use crate::data::synthetic::SyntheticDataset;
use crate::data::zipf::Zipf;
use crate::util::Rng;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the engine admits traffic into the bounded request queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Producers block while the queue is full. Every request is eventually
    /// served, but under sustained overload the wait is unbounded — latency
    /// grows with backlog length instead of being bounded by queue depth.
    Block,
    /// Reject-with-budget load shedding: the queue is capped at
    /// `queue_depth` and a full queue rejects new requests outright
    /// (`BatchQueue::try_push`); when `deadline` is set, each request is
    /// stamped `arrival + deadline` and workers drop already-expired
    /// requests at batch formation — counted, never executed. The latency
    /// of every request that IS served stays bounded near
    /// `queue_depth / capacity`, no matter the offered load.
    Shed {
        /// queue budget: at most this many requests wait at once
        queue_depth: usize,
        /// per-request deadline, measured from arrival; `None` sheds on
        /// queue pressure only
        deadline: Option<Duration>,
    },
}

impl AdmissionPolicy {
    /// The shed deadline, if this policy carries one.
    pub fn deadline(&self) -> Option<Duration> {
        match self {
            AdmissionPolicy::Block => None,
            AdmissionPolicy::Shed { deadline, .. } => *deadline,
        }
    }
}

/// One inference request: raw features plus its arrival stamp (the clock
/// per-request latency is measured against) and an optional deadline after
/// which serving it is useless (shed mode drops it instead of executing).
#[derive(Clone, Debug)]
pub struct Request {
    pub dense: Vec<f32>,
    pub cats: Vec<u32>,
    pub arrival: Instant,
    pub deadline: Option<Instant>,
}

/// Outcome of a non-blocking [`BatchQueue::try_push`]. The rejected item
/// rides back out so the caller can count or repurpose it without a clone.
pub enum TryPush<T> {
    Pushed,
    /// queue at capacity — the admission-control rejection
    Full(T),
    /// queue closed (shutdown) — producers should stop
    Closed(T),
}

struct QueueState<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue with batch-draining consumers. Producers block while
/// full (admission backpressure); consumers drain up to `max_batch` items
/// after an at-most-`max_wait` fill window.
pub struct BatchQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

/// Live queue-depth gauge (`serve.queue.depth`): updated under the queue
/// lock at every push/drain so a mid-run scrape sees the actual backlog.
/// One shared metric — statics in generic fns are a single item — which is
/// what we want: the engine owns one request queue per process.
fn depth_gauge() -> &'static crate::obs::Gauge {
    crate::obs_gauge!("serve.queue.depth")
}

impl<T> BatchQueue<T> {
    pub fn new(cap: usize) -> BatchQueue<T> {
        assert!(cap >= 1);
        BatchQueue {
            inner: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
        }
    }

    /// Enqueue one item, blocking while the queue is full. Returns false if
    /// the queue was closed (shutdown) instead of accepting the item.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        loop {
            if st.closed {
                return false;
            }
            if st.q.len() < self.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.q.push_back(item);
        depth_gauge().set(st.q.len() as u64);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Enqueue one item WITHOUT blocking: a full queue rejects it instead.
    /// This is the shed-mode admission edge — the producer learns about
    /// overload immediately and can count a rejection, rather than silently
    /// converting overload into unbounded queue wait the way `push` does.
    pub fn try_push(&self, item: T) -> TryPush<T> {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return TryPush::Closed(item);
        }
        if st.q.len() >= self.cap {
            return TryPush::Full(item);
        }
        st.q.push_back(item);
        depth_gauge().set(st.q.len() as u64);
        drop(st);
        self.not_empty.notify_one();
        TryPush::Pushed
    }

    /// Close the queue: producers unblock and fail, consumers drain the
    /// remainder and then get `None`.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Dequeue the next batch under the admission policy. Always returns a
    /// non-empty batch; `None` only after `close()` with the queue fully
    /// drained.
    pub fn pop_batch(&self, max_batch: usize, max_wait: Duration) -> Option<Vec<T>> {
        let max_batch = max_batch.max(1);
        let mut st = self.inner.lock().unwrap();
        loop {
            // phase 1: block until something is queued (or shutdown)
            loop {
                if !st.q.is_empty() {
                    break;
                }
                if st.closed {
                    return None;
                }
                st = self.not_empty.wait(st).unwrap();
            }
            // phase 2: give the batch up to max_wait to fill
            let deadline = Instant::now() + max_wait;
            while st.q.len() < max_batch && !st.closed {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, timeout) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
                st = guard;
                if timeout.timed_out() {
                    break;
                }
            }
            let n = st.q.len().min(max_batch);
            if n == 0 {
                // a sibling consumer drained the queue during our fill wait —
                // go back to waiting rather than dispatching an empty batch
                continue;
            }
            let out: Vec<T> = st.q.drain(..n).collect();
            depth_gauge().set(st.q.len() as u64);
            drop(st);
            self.not_full.notify_all();
            return Some(out);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Synthetic serving traffic over a dataset's test split: which sample gets
/// requested is drawn Zipf(`skew`) over popularity rank, so rank 0 is the
/// hottest request — the head-heavy id distribution serving systems must
/// stay fast under (CAFE's motivating scenario). `skew = 0` is uniform.
pub struct TrafficGen<'a> {
    ds: &'a SyntheticDataset,
    zipf: Option<Zipf>,
    rng: Rng,
    base: usize,
    len: usize,
    /// pre-drawn requests served before any live draw (see `pregenerate`)
    replay: VecDeque<Request>,
}

impl<'a> TrafficGen<'a> {
    pub fn new(ds: &'a SyntheticDataset, skew: f64, seed: u64) -> TrafficGen<'a> {
        let s = &ds.spec;
        let base = s.train_samples + s.val_samples;
        let len = s.test_samples.max(1);
        // Zipf::new needs q > 0 and q ≠ 1; nudge the singular point
        let zipf = if skew <= 1e-9 {
            None
        } else {
            let q = if (skew - 1.0).abs() <= 1e-9 { 1.0 + 1e-6 } else { skew };
            Some(Zipf::new(len as u64, q))
        };
        TrafficGen { ds, zipf, rng: Rng::new(seed ^ 0x7AFF1C), base, len, replay: VecDeque::new() }
    }

    fn draw(&mut self) -> Request {
        let rank = match &self.zipf {
            Some(z) => z.sample(&mut self.rng) as usize,
            None => self.rng.below(self.len as u64) as usize,
        };
        let mut dense = vec![0f32; self.ds.spec.n_dense];
        let mut cats = vec![0u32; self.ds.n_features()];
        self.ds.sample_into(self.base + rank, &mut dense, &mut cats);
        Request { dense, cats, arrival: Instant::now(), deadline: None }
    }

    /// Pre-draw `n` requests so `next_request` becomes a pop + arrival
    /// restamp. The overload bench needs the producer to offer traffic
    /// faster than the engine can serve it; a live `sample_into` draw per
    /// request cannot guarantee that, a `VecDeque` pop can.
    pub fn pregenerate(&mut self, n: usize) {
        self.replay = (0..n).map(|_| self.draw()).collect();
    }

    /// Draw the next request (arrival stamped now).
    pub fn next_request(&mut self) -> Request {
        match self.replay.pop_front() {
            Some(mut r) => {
                r.arrival = Instant::now();
                r
            }
            None => self.draw(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetSpec;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50],
            n_dense: 3,
            train_samples: 60,
            val_samples: 10,
            test_samples: 40,
            latent_clusters: 4,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: 1,
        })
    }

    #[test]
    fn full_batches_cut_at_max_batch() {
        let q = BatchQueue::new(64);
        for i in 0..10 {
            assert!(q.push(i));
        }
        let b = q.pop_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = q.pop_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn partial_batch_dispatched_at_deadline() {
        let q = BatchQueue::new(64);
        q.push(7u32);
        let t0 = Instant::now();
        let b = q.pop_batch(16, Duration::from_millis(5)).unwrap();
        assert_eq!(b, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(5), "returned before deadline");
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BatchQueue::new(8);
        q.push(1u32);
        q.push(2u32);
        q.close();
        assert!(!q.push(3u32), "push after close must fail");
        // closed queue dispatches the remainder without waiting max_wait
        let t0 = Instant::now();
        let b = q.pop_batch(16, Duration::from_secs(5)).unwrap();
        assert_eq!(b, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert!(q.pop_batch(16, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn blocked_producer_unblocks_on_pop() {
        let q = std::sync::Arc::new(BatchQueue::new(2));
        q.push(0u32);
        q.push(1u32);
        let pushed = std::sync::Arc::new(AtomicUsize::new(0));
        let (q2, p2) = (q.clone(), pushed.clone());
        let h = std::thread::spawn(move || {
            assert!(q2.push(2));
            // ORDERING: SeqCst — cross-thread flag asserted while the other
            // thread is live; strongest order keeps the test race-free by
            // construction rather than by argument
            p2.fetch_add(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(10));
        // ORDERING: SeqCst — see the producer-side store above
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "producer should be blocked");
        let b = q.pop_batch(2, Duration::from_millis(1)).unwrap();
        assert_eq!(b.len(), 2);
        h.join().unwrap();
        // ORDERING: SeqCst — read after join; any order would do, kept
        // consistent with the store above
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn try_push_rejects_on_full_and_closed() {
        let q = BatchQueue::new(2);
        assert!(matches!(q.try_push(1u32), TryPush::Pushed));
        assert!(matches!(q.try_push(2u32), TryPush::Pushed));
        // full: the item comes back, the queue is untouched
        match q.try_push(3u32) {
            TryPush::Full(x) => assert_eq!(x, 3),
            _ => panic!("full queue must reject"),
        }
        assert_eq!(q.len(), 2);
        // draining frees budget again
        let b = q.pop_batch(1, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![1]);
        assert!(matches!(q.try_push(3u32), TryPush::Pushed));
        q.close();
        match q.try_push(4u32) {
            TryPush::Closed(x) => assert_eq!(x, 4),
            _ => panic!("closed queue must refuse"),
        }
        // the accepted items still drain after close
        let b = q.pop_batch(8, Duration::from_millis(1)).unwrap();
        assert_eq!(b, vec![2, 3]);
    }

    /// Shutdown race: close() fires while several producers are BLOCKED in
    /// push() and consumers are mid-drain. The conservation invariant: every
    /// item whose push returned true is drained exactly once, every item
    /// whose push returned false is drained never — no loss, no duplicates,
    /// and everyone unblocks.
    #[test]
    fn close_while_producers_blocked_loses_nothing() {
        use std::sync::Arc;
        for producers in [1usize, 2, 4] {
            let q = Arc::new(BatchQueue::new(2));
            let per = 50usize;
            let (accepted, drained) = std::thread::scope(|s| {
                let handles: Vec<_> = (0..producers)
                    .map(|p| {
                        let q = q.clone();
                        s.spawn(move || {
                            let mut ok = Vec::new();
                            for i in 0..per {
                                let item = (p * per + i) as u32;
                                if q.push(item) {
                                    ok.push(item);
                                }
                            }
                            ok
                        })
                    })
                    .collect();
                // drain a few batches so producers make progress, then slam
                // the door while some are still blocked on the full queue
                let mut drained = Vec::new();
                for _ in 0..3 {
                    if let Some(b) = q.pop_batch(4, Duration::from_millis(1)) {
                        drained.extend(b);
                    }
                }
                q.close();
                while let Some(b) = q.pop_batch(16, Duration::from_millis(1)) {
                    drained.extend(b);
                }
                let mut accepted = Vec::new();
                for h in handles {
                    accepted.extend(h.join().unwrap());
                }
                (accepted, drained)
            });
            let mut a = accepted.clone();
            let mut d = drained.clone();
            a.sort_unstable();
            d.sort_unstable();
            // items are unique by construction, so equality of the sorted
            // vectors rules out loss AND duplicate dispatch at once
            assert_eq!(a, d, "accepted != drained with {producers} producers");
        }
    }

    /// The multi-consumer empty-drain path (`pop_batch`'s "sibling consumer
    /// drained the queue during our fill wait" continue): consumers with a
    /// generous fill window race over a trickle of items; each item must be
    /// dispatched to exactly one consumer and every consumer must see `None`
    /// after close instead of an empty batch or a hang.
    #[test]
    fn multi_consumer_empty_drain_dispatches_exactly_once() {
        use std::sync::Arc;
        for consumers in [2usize, 4] {
            let q = Arc::new(BatchQueue::new(64));
            let n = 200u32;
            let per_consumer = std::thread::scope(|s| {
                let handles: Vec<_> = (0..consumers)
                    .map(|_| {
                        let q = q.clone();
                        s.spawn(move || {
                            let mut got = Vec::new();
                            // large max_batch + long max_wait maximizes the
                            // window where a sibling empties the queue under us
                            while let Some(b) = q.pop_batch(64, Duration::from_millis(5)) {
                                assert!(!b.is_empty(), "empty batch dispatched");
                                got.extend(b);
                            }
                            got
                        })
                    })
                    .collect();
                for i in 0..n {
                    assert!(q.push(i));
                    if i % 16 == 0 {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
                q.close();
                handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
            });
            let mut all: Vec<u32> = per_consumer.into_iter().flatten().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "{consumers} consumers");
        }
    }

    #[test]
    fn traffic_skew_concentrates_on_head() {
        let ds = ds();
        let count_head = |skew: f64| {
            let mut tg = TrafficGen::new(&ds, skew, 9);
            let want = {
                // request for rank 0 resolves to the first test sample
                let mut d = vec![0f32; 3];
                let mut c = vec![0u32; 2];
                ds.sample_into(70, &mut d, &mut c);
                c
            };
            (0..2000).filter(|_| tg.next_request().cats == want).count()
        };
        let uniform = count_head(0.0);
        let skewed = count_head(1.2);
        assert!(skewed > uniform * 3, "skewed {skewed} vs uniform {uniform}");
    }

    #[test]
    fn traffic_requests_have_dataset_shape() {
        let ds = ds();
        for skew in [0.0, 1.0, 0.99] {
            let mut tg = TrafficGen::new(&ds, skew, 3);
            for _ in 0..50 {
                let r = tg.next_request();
                assert_eq!(r.dense.len(), 3);
                assert_eq!(r.cats.len(), 2);
                for (f, &v) in r.cats.iter().enumerate() {
                    assert!((v as usize) < ds.spec.vocabs[f]);
                }
            }
        }
    }
}
