//! Fault-tolerant snapshot watcher: poll a directory for new segment
//! generations and auto-install them into a live [`SnapshotSlot`] — the
//! serving half of the train→disk→serve loop that `cce train --snapshot-dir`
//! starts. With a watcher attached, a serving process follows the trainer's
//! generations with no explicit `install_snapshot` call and no restart.
//!
//! # Robustness contract
//!
//! A snapshot directory is a shared mutable boundary: the trainer writes to
//! it, operators copy files into it, disks corrupt bytes in it. The watcher
//! therefore treats every file as hostile until proven otherwise, and a bad
//! file must never take down — or worse, poison — a serving run:
//!
//! * **Verified installs only.** Candidates go through
//!   [`SnapshotSlot::install_snapshot`], which checksums every section
//!   before the swap. A bit flip anywhere in the payload is caught before
//!   traffic can observe it.
//! * **Bounded retry with exponential backoff.** A failed candidate (torn
//!   write still in flight, transient I/O error) is retried up to
//!   `max_retries` times with doubling backoff, then given up on until the
//!   file's `(len, mtime)` changes — a rewritten file gets a fresh budget.
//! * **Graceful skip.** Corrupt, truncated, or incompatible segments are
//!   counted ([`WatcherReport`]) and skipped; the slot keeps serving the
//!   generation it has. Incompatibility (different method kind or sample
//!   stride than the running engine was compiled for) is detected from the
//!   header and never retried — no amount of waiting fixes a wrong shape.
//! * **Monotonic generations.** Only files whose header generation exceeds
//!   the last installed generation are candidates, so replaying an old file
//!   into the directory cannot roll a live engine backwards.
//!
//! The polling core is a deterministic state machine ([`WatcherState`]):
//! `tick()` performs exactly one scan-select-install step, so tests drive
//! it directly on the main thread with zero-backoff configs and no sleeps.
//! [`SnapshotWatcher`] is the thin thread wrapper production uses.

use crate::serving::engine::SnapshotSlot;
use crate::serving::segment::{self, SegmentHeader};
use crate::tables::indexer::MethodKind;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime};

/// Watcher tuning knobs (derived from `config::ServeConfig`).
#[derive(Clone, Debug)]
pub struct WatcherConfig {
    /// directory to poll for `*.cceseg` files
    pub dir: PathBuf,
    /// poll interval between ticks
    pub poll: Duration,
    /// install/parse attempts per file before giving up on it
    pub max_retries: u32,
    /// base retry backoff; doubles per failed attempt
    pub backoff: Duration,
}

impl WatcherConfig {
    pub fn new(dir: impl Into<PathBuf>) -> WatcherConfig {
        WatcherConfig {
            dir: dir.into(),
            poll: Duration::from_millis(200),
            max_retries: 3,
            backoff: Duration::from_millis(50),
        }
    }
}

/// What a watcher observed over its lifetime (returned by
/// [`SnapshotWatcher::stop`], printed by `cce serve`).
#[derive(Clone, Debug, Default)]
pub struct WatcherReport {
    /// directory scans performed
    pub polls: u64,
    /// snapshots successfully verified and installed
    pub installs: u64,
    /// failed attempts that were rescheduled with backoff
    pub retries: u64,
    /// files abandoned after exhausting their retry budget
    pub skipped_corrupt: u64,
    /// files rejected for shape/method mismatch (never retried)
    pub skipped_incompatible: u64,
    /// header generation of the last successful install (0 = none)
    pub generation: u64,
}

/// Per-file bookkeeping. Keyed on the file's `(len, mtime)` identity: when
/// either changes the file is treated as new content and all verdicts —
/// cached generation, retry budget, given-up flag — are reset.
#[derive(Debug)]
struct FileState {
    len: u64,
    mtime: SystemTime,
    /// header generation, once parsed successfully
    generation: Option<u64>,
    attempts: u32,
    /// earliest instant the next attempt may run (backoff gate)
    next_attempt: Option<Instant>,
    /// retry budget exhausted (corrupt) or shape mismatch (incompatible)
    given_up: bool,
}

impl FileState {
    fn fresh(len: u64, mtime: SystemTime) -> FileState {
        FileState { len, mtime, generation: None, attempts: 0, next_attempt: None, given_up: false }
    }

    fn ready(&self, now: Instant) -> bool {
        !self.given_up && self.next_attempt.map_or(true, |t| now >= t)
    }
}

/// Deterministic polling core: one `tick` = one scan-select-install step.
pub struct WatcherState {
    cfg: WatcherConfig,
    files: HashMap<PathBuf, FileState>,
    /// header generation last installed through THIS watcher (or the boot
    /// load); distinct from the slot's own install counter, which also
    /// counts swaps from other sources
    installed: Option<u64>,
    report: WatcherReport,
}

impl WatcherState {
    /// `installed` seeds the generation floor: a server that booted from
    /// generation G passes `Some(G)` so the watcher does not reinstall the
    /// file it started from.
    pub fn new(cfg: WatcherConfig, installed: Option<u64>) -> WatcherState {
        let report =
            WatcherReport { generation: installed.unwrap_or(0), ..WatcherReport::default() };
        WatcherState { cfg, files: HashMap::new(), installed, report }
    }

    pub fn report(&self) -> &WatcherReport {
        &self.report
    }

    /// One poll: scan the directory, refresh per-file state, and try to
    /// install the highest-generation ready candidate newer than what is
    /// already installed. Every failure path is absorbed into the report —
    /// `tick` never returns an error and never panics on directory contents.
    pub fn tick(&mut self, slot: &SnapshotSlot) {
        self.report.polls += 1;
        // registry mirrors of the report counters, bumped at the same sites
        // (docs/OBSERVABILITY.md): a stuck retry loop is visible on a live
        // /metrics scrape instead of only in the end-of-run WatcherReport
        crate::obs_counter!("serve.watcher.polls").inc();
        let now = Instant::now();
        let seen = self.scan(now);
        // forget files that vanished (pruned by retention GC, or deleted by
        // an operator) so the map cannot grow without bound
        self.files.retain(|p, _| seen.contains(p));

        // resolve unparsed headers for ready files: O(header) per file, and
        // only re-done when the file's (len, mtime) identity changes
        let mut paths: Vec<PathBuf> = self.files.keys().cloned().collect();
        paths.sort(); // deterministic attempt order
        for p in &paths {
            let st = self.files.get_mut(p).unwrap();
            if st.generation.is_some() || !st.ready(now) {
                continue;
            }
            match segment::inspect(p, false) {
                Ok(info) => {
                    if compatible(&info.header, slot) {
                        st.generation = Some(info.header.generation);
                    } else {
                        st.given_up = true;
                        self.report.skipped_incompatible += 1;
                        crate::obs_counter!("serve.watcher.skipped_incompatible").inc();
                    }
                }
                Err(_) => self.fail_attempt(p.clone(), now),
            }
        }

        // best ready candidate strictly newer than what we installed
        let floor = self.installed;
        let best = self
            .files
            .iter()
            .filter(|(_, st)| st.ready(now))
            .filter_map(|(p, st)| st.generation.map(|g| (g, p.clone())))
            .filter(|(g, _)| floor.map_or(true, |f| *g > f))
            .max_by(|a, b| a.0.cmp(&b.0).then_with(|| b.1.cmp(&a.1)));
        let Some((generation, path)) = best else { return };

        let mut sp = crate::span!("serve.snapshot.swap");
        sp.attr("generation", generation);
        match slot.install_snapshot(&path) {
            Ok(_) => {
                self.installed = Some(generation);
                self.report.installs += 1;
                self.report.generation = generation;
                crate::obs_counter!("serve.watcher.installs").inc();
                crate::obs_gauge!("serve.watcher.generation").set(generation);
                if let Some(st) = self.files.get_mut(&path) {
                    st.attempts = 0;
                    st.next_attempt = None;
                }
            }
            // header parsed and shapes matched, so this is payload
            // corruption or transient I/O — retry with backoff
            Err(_) => self.fail_attempt(path, now),
        }
    }

    /// Enumerate `*.cceseg` files and refresh their `(len, mtime)` identity.
    /// `.tmp` siblings (in-flight atomic writes) and unreadable entries are
    /// ignored without error.
    fn scan(&mut self, _now: Instant) -> Vec<PathBuf> {
        let mut seen = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.cfg.dir) else { return seen };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.extension().map_or(true, |e| e != "cceseg") {
                continue;
            }
            let Ok(meta) = entry.metadata() else { continue };
            if !meta.is_file() {
                continue;
            }
            let len = meta.len();
            let mtime = meta.modified().unwrap_or(SystemTime::UNIX_EPOCH);
            match self.files.get_mut(&path) {
                Some(st) if st.len == len && st.mtime == mtime => {}
                Some(st) => *st = FileState::fresh(len, mtime),
                None => {
                    self.files.insert(path.clone(), FileState::fresh(len, mtime));
                }
            }
            seen.push(path);
        }
        seen
    }

    fn fail_attempt(&mut self, path: PathBuf, now: Instant) {
        let Some(st) = self.files.get_mut(&path) else { return };
        st.attempts += 1;
        if st.attempts > self.cfg.max_retries {
            st.given_up = true;
            self.report.skipped_corrupt += 1;
            crate::obs_counter!("serve.watcher.skipped_corrupt").inc();
        } else {
            self.report.retries += 1;
            crate::obs_counter!("serve.watcher.retries").inc();
            // exponential backoff: base, 2×base, 4×base, …
            let factor = 1u32 << (st.attempts - 1).min(16);
            st.next_attempt = Some(now + self.cfg.backoff.saturating_mul(factor));
        }
    }
}

/// Shape compatibility from the header alone — no payload read. Mirrors the
/// `SnapshotSlot::install` check: the running executable is compiled for a
/// fixed method kind and embedding-input stride.
fn compatible(h: &SegmentHeader, slot: &SnapshotSlot) -> bool {
    let current = slot.current().1;
    let stride = match h.kind {
        MethodKind::RowWise => h.n_features * h.stride,
        MethodKind::ElementWise => h.n_features * h.dim,
        MethodKind::Dhe => h.n_features * h.n_hash,
    };
    h.kind == current.kind() && stride == current.sample_stride()
}

/// Boot helper: load the newest generation in `dir` that passes FULL
/// checksum verification, trying candidates newest-first and skipping any
/// that fail to parse or verify. `Ok(None)` means no usable segment exists.
pub fn load_newest_verified(dir: &Path) -> Result<Option<(PathBuf, segment::LoadedSegment)>> {
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("read snapshot dir {}", dir.display()))?;
    let mut candidates = Vec::new();
    for entry in rd.flatten() {
        let path = entry.path();
        if path.extension().map_or(true, |e| e != "cceseg") {
            continue;
        }
        if let Ok(info) = segment::inspect(&path, false) {
            candidates.push((info.header.generation, path));
        }
    }
    // newest generation first; path as deterministic tiebreak
    candidates.sort_by(|a, b| b.0.cmp(&a.0).then_with(|| a.1.cmp(&b.1)));
    for (_, path) in candidates {
        if let Ok(loaded) = segment::load_segment_verified(&path) {
            return Ok(Some((path, loaded)));
        }
    }
    Ok(None)
}

/// Thread wrapper around [`WatcherState`]: ticks every `cfg.poll` until
/// stopped, sleeping in small slices so `stop()` returns promptly.
pub struct SnapshotWatcher {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<WatcherReport>,
}

impl SnapshotWatcher {
    /// Start watching. `installed` is the generation the engine booted from
    /// (see [`WatcherState::new`]).
    pub fn spawn(
        slot: Arc<SnapshotSlot>,
        cfg: WatcherConfig,
        installed: Option<u64>,
    ) -> SnapshotWatcher {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            let poll = cfg.poll;
            let mut state = WatcherState::new(cfg, installed);
            // ORDERING: Relaxed stop flag — it publishes no data (the
            // report travels through the join), so only the eventual
            // visibility of the bool matters
            while !stop2.load(Ordering::Relaxed) {
                state.tick(&slot);
                let mut slept = Duration::ZERO;
                // ORDERING: Relaxed — same stop flag, same argument
                while slept < poll && !stop2.load(Ordering::Relaxed) {
                    let slice = (poll - slept).min(Duration::from_millis(10));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
            state.report().clone()
        });
        SnapshotWatcher { stop, handle }
    }

    /// Signal the watcher thread and join it, returning what it observed.
    pub fn stop(self) -> WatcherReport {
        // ORDERING: Relaxed stop flag — the join below is the
        // synchronization point for everything the thread produced
        self.stop.store(true, Ordering::Relaxed);
        self.handle.join().expect("watcher thread panicked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::snapshot::ServingSnapshot;
    use crate::tables::indexer::Indexer;
    use crate::tables::layout::TablePlan;
    use crate::testutil::{fault, TempDir};
    use crate::util::Rng;

    fn snapshot(seed: u64) -> ServingSnapshot {
        let mut rng = Rng::new(seed);
        let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[11, 50], 8, 2, 2, 4));
        ServingSnapshot::bake(&ix)
    }

    fn zero_backoff(dir: &Path) -> WatcherConfig {
        WatcherConfig {
            dir: dir.to_path_buf(),
            poll: Duration::from_millis(1),
            max_retries: 2,
            backoff: Duration::ZERO,
        }
    }

    #[test]
    fn installs_newest_generation_and_ignores_older() {
        let dir = TempDir::new("watcher_newest");
        segment::write_segment(&snapshot(1), 3, &dir.path().join("a-gen3.cceseg")).unwrap();
        segment::write_segment(&snapshot(2), 7, &dir.path().join("a-gen7.cceseg")).unwrap();
        segment::write_segment(&snapshot(3), 5, &dir.path().join("a-gen5.cceseg")).unwrap();
        let slot = SnapshotSlot::new(snapshot(0));
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);
        w.tick(&slot);
        assert_eq!(w.report().installs, 1, "exactly one install: the newest");
        assert_eq!(w.report().generation, 7);
        assert_eq!(slot.generation(), 1, "one slot swap");
        // steady state: nothing new → no further installs
        w.tick(&slot);
        assert_eq!(w.report().installs, 1);
        assert_eq!(w.report().polls, 2);
        // an OLDER generation appearing later must not roll us back
        segment::write_segment(&snapshot(4), 6, &dir.path().join("a-gen6.cceseg")).unwrap();
        w.tick(&slot);
        assert_eq!(w.report().installs, 1, "generation 6 < installed 7");
        // a newer one is picked up
        segment::write_segment(&snapshot(5), 9, &dir.path().join("a-gen9.cceseg")).unwrap();
        w.tick(&slot);
        assert_eq!(w.report().installs, 2);
        assert_eq!(w.report().generation, 9);
    }

    #[test]
    fn corrupt_segment_is_retried_then_skipped_and_old_generation_keeps_serving() {
        let dir = TempDir::new("watcher_corrupt");
        let bad = dir.path().join("a-gen5.cceseg");
        segment::write_segment(&snapshot(1), 5, &bad).unwrap();
        fault::flip_section_byte(&bad, "rows", 11).unwrap();
        let slot = SnapshotSlot::new(snapshot(0));
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);
        // attempts 1..=max_retries fail and reschedule; the next one gives up
        for _ in 0..4 {
            w.tick(&slot);
        }
        assert_eq!(w.report().installs, 0);
        assert_eq!(w.report().retries, 2, "max_retries reschedules");
        assert_eq!(w.report().skipped_corrupt, 1, "then the file is abandoned");
        assert_eq!(slot.generation(), 0, "slot untouched by the corrupt file");
        // once given up, further ticks don't touch it again
        w.tick(&slot);
        assert_eq!(w.report().skipped_corrupt, 1);
        // a GOOD newer file still gets through — the bad one poisoned nothing
        segment::write_segment(&snapshot(2), 6, &dir.path().join("a-gen6.cceseg")).unwrap();
        w.tick(&slot);
        assert_eq!(w.report().installs, 1);
        assert_eq!(w.report().generation, 6);
        assert_eq!(slot.generation(), 1);
    }

    #[test]
    fn rewritten_file_gets_a_fresh_retry_budget() {
        let dir = TempDir::new("watcher_rewrite");
        let p = dir.path().join("a-gen5.cceseg");
        segment::write_segment(&snapshot(1), 5, &p).unwrap();
        fault::flip_section_byte(&p, "rows", 0).unwrap();
        let slot = SnapshotSlot::new(snapshot(0));
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);
        for _ in 0..4 {
            w.tick(&slot);
        }
        assert_eq!(w.report().skipped_corrupt, 1);
        assert_eq!(w.report().installs, 0);
        // the trainer rewrites the file intact (len/mtime change with the
        // content rewrite) → the give-up verdict is reset and it installs
        segment::write_segment(&snapshot(1), 5, &p).unwrap();
        w.tick(&slot);
        assert_eq!(w.report().installs, 1, "rewritten file must be reconsidered");
        assert_eq!(w.report().generation, 5);
    }

    #[test]
    fn incompatible_segment_is_skipped_immediately_without_retry() {
        let dir = TempDir::new("watcher_incompat");
        let mut rng = Rng::new(9);
        let robe = ServingSnapshot::bake(&Indexer::new_robe(&mut rng, &[11, 50], 30, 8, 2));
        segment::write_segment(&robe, 5, &dir.path().join("b-gen5.cceseg")).unwrap();
        let slot = SnapshotSlot::new(snapshot(0)); // rowwise engine
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);
        w.tick(&slot);
        w.tick(&slot);
        assert_eq!(w.report().skipped_incompatible, 1, "flagged once, never retried");
        assert_eq!(w.report().retries, 0, "shape mismatch is not retryable");
        assert_eq!(w.report().installs, 0);
        assert_eq!(slot.generation(), 0);
    }

    #[test]
    fn tmp_and_truncated_files_are_ignored_or_skipped() {
        let dir = TempDir::new("watcher_torn");
        // an in-flight atomic write: .tmp extension → not even a candidate
        std::fs::write(dir.path().join("a-gen8.cceseg.tmp"), b"partial").unwrap();
        // a torn write published by a non-atomic copier: header intact,
        // payload cut short
        let torn = dir.path().join("a-gen9.cceseg");
        segment::write_segment(&snapshot(1), 9, &torn).unwrap();
        let full = std::fs::metadata(&torn).unwrap().len();
        fault::truncate_segment(&torn, full - 32).unwrap();
        let slot = SnapshotSlot::new(snapshot(0));
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);
        for _ in 0..4 {
            w.tick(&slot);
        }
        assert_eq!(w.report().installs, 0);
        assert_eq!(w.report().skipped_corrupt, 1, "torn file abandoned after retries");
        assert_eq!(slot.generation(), 0);
    }

    #[test]
    fn generation_floor_skips_the_boot_segment() {
        let dir = TempDir::new("watcher_floor");
        segment::write_segment(&snapshot(1), 4, &dir.path().join("a-gen4.cceseg")).unwrap();
        let slot = SnapshotSlot::new(snapshot(0));
        // server claims it already booted from generation 4
        let mut w = WatcherState::new(zero_backoff(dir.path()), Some(4));
        w.tick(&slot);
        assert_eq!(w.report().installs, 0, "must not reinstall the boot generation");
        assert_eq!(w.report().generation, 4, "report starts at the boot generation");
    }

    #[test]
    fn load_newest_verified_skips_corrupt_newer_files() {
        let dir = TempDir::new("watcher_boot");
        segment::write_segment(&snapshot(1), 2, &dir.path().join("a-gen2.cceseg")).unwrap();
        let newer = dir.path().join("a-gen5.cceseg");
        segment::write_segment(&snapshot(2), 5, &newer).unwrap();
        fault::flip_section_byte(&newer, "rows", 3).unwrap();
        let (path, loaded) = load_newest_verified(dir.path()).unwrap().unwrap();
        assert_eq!(loaded.generation, 2, "corrupt gen 5 skipped, gen 2 booted");
        assert!(path.ends_with("a-gen2.cceseg"));
        // empty dir → Ok(None)
        let empty = TempDir::new("watcher_boot_empty");
        assert!(load_newest_verified(empty.path()).unwrap().is_none());
    }

    /// Acceptance: a corrupt segment dropped into the watched directory
    /// mid-run must not fail a single request — the engine completes the
    /// whole run on the prior generation.
    #[test]
    fn corrupt_drop_in_never_poisons_a_live_run() {
        use crate::data::synthetic::{DatasetSpec, SyntheticDataset};
        use crate::serving::batcher::{AdmissionPolicy, TrafficGen};
        use crate::serving::engine::{self, CountingExecutor, EngineConfig};

        let ds = SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50],
            n_dense: 3,
            train_samples: 40,
            val_samples: 8,
            test_samples: 32,
            latent_clusters: 4,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: 1,
        });
        let dir = TempDir::new("watcher_poison_run");
        let slot = Arc::new(SnapshotSlot::new(snapshot(0)));
        let mut w = WatcherState::new(zero_backoff(dir.path()), None);

        let rep = std::thread::scope(|s| {
            let slot2 = slot.clone();
            let handle = s.spawn(move || {
                let mut exec = CountingExecutor::new(16);
                let traffic = TrafficGen::new(&ds, 0.99, 31);
                let cfg = EngineConfig {
                    workers: 2,
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                    queue_depth: 64,
                    admission: AdmissionPolicy::Block,
                    pace: None,
                };
                engine::run(&mut exec, &slot2, traffic, &cfg, 600).unwrap()
            });
            // drop the corrupt segment in while the engine serves, and keep
            // the watcher polling until the run finishes
            let bad = dir.path().join("a-gen3.cceseg");
            segment::write_segment(&snapshot(7), 3, &bad).unwrap();
            fault::flip_section_byte(&bad, "rows", 5).unwrap();
            while !handle.is_finished() {
                w.tick(&slot);
                std::thread::sleep(Duration::from_micros(200));
            }
            handle.join().unwrap()
        });
        assert_eq!(rep.requests, 600, "zero failed/lost requests");
        assert_eq!(rep.generation, 0, "served entirely on the prior generation");
        assert_eq!(slot.generation(), 0, "corrupt file never installed");
        assert_eq!(w.report().installs, 0);
        assert!(w.report().skipped_corrupt <= 1);
    }

    #[test]
    fn spawned_watcher_installs_and_stops_cleanly() {
        let dir = TempDir::new("watcher_thread");
        let slot = Arc::new(SnapshotSlot::new(snapshot(0)));
        let w = SnapshotWatcher::spawn(slot.clone(), zero_backoff(dir.path()), None);
        segment::write_segment(&snapshot(1), 1, &dir.path().join("a-gen1.cceseg")).unwrap();
        // wait (bounded) for the poll loop to pick it up
        let t0 = Instant::now();
        while slot.generation() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(2));
        }
        let rep = w.stop();
        assert_eq!(slot.generation(), 1, "spawned watcher never installed");
        assert_eq!(rep.installs, 1);
        assert!(rep.polls >= 1);
    }
}
