//! Baked, read-only serving snapshot of a trained `(state, Indexer)` pair.
//!
//! The training `Indexer` answers every lookup through an `IndexMap` enum
//! match (hash vs learned vs identity) because clustering events rewrite
//! maps mid-run. At serving time the maps are frozen, so `bake` materializes
//! them once into flat contiguous arrays and the hot path becomes a
//! branch-free gather:
//!
//!   * row-wise: `rows[feat_off[f] + v * (t*c) ..]` holds the `t*c` GLOBAL
//!     pool rows of id `v` — subtable bases are folded in at bake time and
//!     one id's rows are adjacent (one cache line for t=2, c=4).
//!   * ROBE: per-(id, column) window *starts* are materialized; the serve
//!     path only does the `(start + e) % region` run expansion.
//!   * DHE: the full `[vocab, n_hash]` feature table is baked when it fits
//!     under [`DHE_BAKE_MAX_ELEMS`]; above that the per-feature hashers are
//!     kept and evaluated live (bit-identical either way).
//!
//! The bulk gather tables live behind [`SnapshotTables`]: either owned heap
//! vectors (fresh `bake`) or borrowed slices of a memory-mapped segment file
//! (`serving::segment::load_segment`), so a serving process can cold-start
//! in milliseconds without copying multi-GB tables. Geometry (vocabs,
//! per-feature offsets, strides) is always owned — it is tiny and recomputed
//! on load.
//!
//! Every `fill_*` here is bit-identical to the live `Indexer` equivalent —
//! pinned by `tests/proptests.rs::prop_snapshot_*` and the segment
//! round-trip proptests — so a snapshot can be swapped under
//! `coordinator::serve` with zero behavior change.

use crate::hashing::DheHasher;
use crate::tables::indexer::{Indexer, MethodKind};
use crate::tables::layout::SubtableId;
use crate::util::mmap::{self, MappedFile};
use std::ops::Range;
use std::sync::Arc;

/// Above this many total baked f32s, DHE falls back to live hashing (the
/// terabyte-sim preset would otherwise bake multi-GB tables; see ROADMAP
/// "sharded snapshots").
pub const DHE_BAKE_MAX_ELEMS: usize = 1 << 26;

/// The bulk gather tables, either heap-owned (baked in this process) or
/// zero-copy views into a mapped segment file. The enum is the ONLY place
/// the two storage modes differ; geometry and the `fill_*` hot paths are
/// shared.
#[derive(Clone)]
pub(crate) enum SnapshotTables {
    Owned {
        rows: Vec<u32>,
        robe_starts: Vec<u32>,
        robe_base: Vec<i32>,
        robe_region: Vec<u32>,
        dhe_table: Vec<f32>,
    },
    /// Byte ranges into `file` (64-byte aligned by the segment format, so
    /// the typed reinterpretation in the accessors is always valid).
    Mapped {
        file: Arc<MappedFile>,
        rows: Range<usize>,
        robe_starts: Range<usize>,
        robe_base: Range<usize>,
        robe_region: Range<usize>,
        dhe_table: Range<usize>,
    },
}

/// Read-only index-generation state for one frozen model.
#[derive(Clone)]
pub struct ServingSnapshot {
    kind: MethodKind,
    n_features: usize,
    vocabs: Vec<usize>,
    /// row-wise: entry count per id in the rows table (`t*c`)
    stride: usize,
    feat_off: Vec<usize>,
    /// ROBE geometry (column count, chunk length, embedding dim)
    c: usize,
    dc: u32,
    dim: usize,
    robe_off: Vec<usize>,
    /// DHE geometry + live-fallback hashers (empty when the table is baked)
    n_hash: usize,
    dhe_off: Vec<usize>,
    dhe_live: Vec<DheHasher>,
    tables: SnapshotTables,
}

/// Running byte/element offsets of each feature's block in a flat
/// `[f][v][width]` table.
fn prefix_offsets(vocabs: &[usize], width: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(vocabs.len());
    let mut acc = 0usize;
    for &v in vocabs {
        out.push(acc);
        acc += v * width;
    }
    out
}

impl ServingSnapshot {
    /// Bake a live indexer's current maps into gather tables.
    pub fn bake(ix: &Indexer) -> ServingSnapshot {
        Self::bake_with_dhe_cap(ix, DHE_BAKE_MAX_ELEMS)
    }

    /// `bake` with an explicit DHE bake budget — public so tests can force
    /// the live-fallback path without a terabyte-scale vocab.
    pub fn bake_with_dhe_cap(ix: &Indexer, dhe_max_elems: usize) -> ServingSnapshot {
        match ix.kind {
            MethodKind::RowWise => Self::bake_rowwise(ix),
            MethodKind::ElementWise => Self::bake_robe(ix),
            MethodKind::Dhe => Self::bake_dhe(ix, dhe_max_elems),
        }
    }

    fn bake_rowwise(ix: &Indexer) -> ServingSnapshot {
        // Guard the serve-time u32 → i32 cast here, before any allocation:
        // a pool this large would silently wrap row ids in `fill_rowwise`.
        assert!(
            ix.plan.total_rows < i32::MAX as usize,
            "pool has {} rows; row ids must fit in i32 for the device gather",
            ix.plan.total_rows
        );
        let (t_n, c_n) = (ix.plan.t, ix.plan.c);
        let stride = t_n * c_n;
        let vocabs = ix.plan.vocabs.clone();
        let total: usize = vocabs.iter().map(|&v| v * stride).sum();
        let mut rows = vec![0u32; total];
        let feat_off = prefix_offsets(&vocabs, stride);
        for f in 0..vocabs.len() {
            let off = feat_off[f];
            // interleave the feature's t*c subtable maps so one id's rows
            // are contiguous in the gather table
            for t in 0..t_n {
                for j in 0..c_n {
                    let table =
                        ix.materialize_global(SubtableId { feature: f, term: t, column: j });
                    let slot = t * c_n + j;
                    for (v, &g) in table.iter().enumerate() {
                        rows[off + v * stride + slot] = g;
                    }
                }
            }
        }
        ServingSnapshot {
            kind: MethodKind::RowWise,
            n_features: vocabs.len(),
            feat_off,
            vocabs,
            stride,
            c: 0,
            dc: 0,
            dim: 0,
            robe_off: Vec::new(),
            n_hash: 0,
            dhe_off: Vec::new(),
            dhe_live: Vec::new(),
            tables: SnapshotTables::Owned {
                rows,
                robe_starts: Vec::new(),
                robe_base: Vec::new(),
                robe_region: Vec::new(),
                dhe_table: Vec::new(),
            },
        }
    }

    fn bake_robe(ix: &Indexer) -> ServingSnapshot {
        let vocabs = ix.plan.vocabs.clone();
        let dim = ix.dim();
        let (mut c, mut dc) = (0usize, 0u32);
        let mut robe_starts = Vec::new();
        let mut robe_base = Vec::new();
        let mut robe_region = Vec::new();
        for f in 0..vocabs.len() {
            let w = ix.robe_windows(f);
            if f == 0 {
                c = w.n_columns();
                dc = w.dc;
            }
            robe_base.push(ix.robe_region_base(f) as i32);
            robe_region.push(w.region);
            for v in 0..vocabs[f] as u32 {
                for j in 0..c {
                    robe_starts.push(w.start(j, v));
                }
            }
        }
        ServingSnapshot {
            kind: MethodKind::ElementWise,
            n_features: vocabs.len(),
            robe_off: prefix_offsets(&vocabs, c),
            vocabs,
            stride: 0,
            feat_off: Vec::new(),
            c,
            dc,
            dim,
            n_hash: 0,
            dhe_off: Vec::new(),
            dhe_live: Vec::new(),
            tables: SnapshotTables::Owned {
                rows: Vec::new(),
                robe_starts,
                robe_base,
                robe_region,
                dhe_table: Vec::new(),
            },
        }
    }

    fn bake_dhe(ix: &Indexer, dhe_max_elems: usize) -> ServingSnapshot {
        let vocabs = ix.plan.vocabs.clone();
        let n_hash = ix.n_hash;
        let total: usize = vocabs.iter().map(|&v| v * n_hash).sum();
        let (mut dhe_table, mut dhe_live) = (Vec::new(), Vec::new());
        if total > dhe_max_elems {
            dhe_live = ix.dhe_hashers().to_vec();
        } else {
            dhe_table = vec![0f32; total];
            let mut off = 0usize;
            for (f, h) in ix.dhe_hashers().iter().enumerate() {
                for v in 0..vocabs[f] {
                    h.fill(v as u32, &mut dhe_table[off + v * n_hash..][..n_hash]);
                }
                off += vocabs[f] * n_hash;
            }
        }
        ServingSnapshot {
            kind: MethodKind::Dhe,
            n_features: vocabs.len(),
            dhe_off: prefix_offsets(&vocabs, n_hash),
            vocabs,
            stride: 0,
            feat_off: Vec::new(),
            c: 0,
            dc: 0,
            dim: 0,
            robe_off: Vec::new(),
            n_hash,
            dhe_live,
            tables: SnapshotTables::Owned {
                rows: Vec::new(),
                robe_starts: Vec::new(),
                robe_base: Vec::new(),
                robe_region: Vec::new(),
                dhe_table,
            },
        }
    }

    /// Assemble a snapshot around already-materialized tables — the segment
    /// loader's entry point. Geometry offsets are recomputed, not trusted
    /// from the file.
    #[allow(clippy::too_many_arguments)] // one arg per header geometry field
    pub(crate) fn from_parts(
        kind: MethodKind,
        vocabs: Vec<usize>,
        stride: usize,
        c: usize,
        dc: u32,
        dim: usize,
        n_hash: usize,
        dhe_live: Vec<DheHasher>,
        tables: SnapshotTables,
    ) -> ServingSnapshot {
        ServingSnapshot {
            kind,
            n_features: vocabs.len(),
            feat_off: prefix_offsets(&vocabs, stride),
            robe_off: prefix_offsets(&vocabs, c),
            dhe_off: prefix_offsets(&vocabs, n_hash),
            vocabs,
            stride,
            c,
            dc,
            dim,
            n_hash,
            dhe_live,
            tables,
        }
    }

    // ---- table accessors: the only code that sees the storage mode ----

    #[inline]
    pub(crate) fn rows(&self) -> &[u32] {
        match &self.tables {
            SnapshotTables::Owned { rows, .. } => rows,
            SnapshotTables::Mapped { file, rows, .. } => mmap::as_u32s(&file.bytes()[rows.clone()]),
        }
    }

    #[inline]
    pub(crate) fn robe_starts(&self) -> &[u32] {
        match &self.tables {
            SnapshotTables::Owned { robe_starts, .. } => robe_starts,
            SnapshotTables::Mapped { file, robe_starts, .. } => {
                mmap::as_u32s(&file.bytes()[robe_starts.clone()])
            }
        }
    }

    #[inline]
    pub(crate) fn robe_base(&self) -> &[i32] {
        match &self.tables {
            SnapshotTables::Owned { robe_base, .. } => robe_base,
            SnapshotTables::Mapped { file, robe_base, .. } => {
                mmap::as_i32s(&file.bytes()[robe_base.clone()])
            }
        }
    }

    #[inline]
    pub(crate) fn robe_region(&self) -> &[u32] {
        match &self.tables {
            SnapshotTables::Owned { robe_region, .. } => robe_region,
            SnapshotTables::Mapped { file, robe_region, .. } => {
                mmap::as_u32s(&file.bytes()[robe_region.clone()])
            }
        }
    }

    #[inline]
    pub(crate) fn dhe_table(&self) -> &[f32] {
        match &self.tables {
            SnapshotTables::Owned { dhe_table, .. } => dhe_table,
            SnapshotTables::Mapped { file, dhe_table, .. } => {
                mmap::as_f32s(&file.bytes()[dhe_table.clone()])
            }
        }
    }

    // ---- geometry accessors (segment writer + engine) ----

    pub fn kind(&self) -> MethodKind {
        self.kind
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Whether the tables are zero-copy views of a mapped segment file.
    pub fn is_mapped(&self) -> bool {
        matches!(self.tables, SnapshotTables::Mapped { .. })
    }

    pub(crate) fn vocabs(&self) -> &[usize] {
        &self.vocabs
    }

    pub(crate) fn stride(&self) -> usize {
        self.stride
    }

    pub(crate) fn robe_geometry(&self) -> (usize, u32, usize) {
        (self.c, self.dc, self.dim)
    }

    pub(crate) fn n_hash(&self) -> usize {
        self.n_hash
    }

    pub(crate) fn dhe_live_hashers(&self) -> &[DheHasher] {
        &self.dhe_live
    }

    /// Embedding-input elements per sample (`emb_elems / batch`).
    pub fn sample_stride(&self) -> usize {
        match self.kind {
            MethodKind::RowWise => self.n_features * self.stride,
            MethodKind::ElementWise => self.n_features * self.dim,
            MethodKind::Dhe => self.n_features * self.n_hash,
        }
    }

    /// Host memory of the baked tables and geometry (Appendix E accounting).
    /// For a mapped snapshot this counts the file-backed pages the tables
    /// occupy once touched — the serving working set is the same either way.
    pub fn host_bytes(&self) -> usize {
        self.rows().len() * 4
            + self.robe_starts().len() * 4
            + self.robe_base().len() * 4
            + self.robe_region().len() * 4
            + self.dhe_table().len() * 4
            + self.vocabs.len() * 8
            + (self.feat_off.len() + self.robe_off.len() + self.dhe_off.len()) * 8
            + self.dhe_live.len() * self.n_hash * 8 // live fallback: seed tables
    }

    /// Row indices for a batch, bit-identical to `Indexer::fill_rowwise`.
    pub fn fill_rowwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.stride);
        let rows = self.rows();
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                debug_assert!(v < self.vocabs[f], "value {v} out of vocab");
                let block = &rows[self.feat_off[f] + v * self.stride..][..self.stride];
                for &r in block {
                    // cast cannot wrap: bake_rowwise asserts total rows < i32::MAX
                    out[o] = r as i32;
                    o += 1;
                }
            }
        }
    }

    /// Element indices for ROBE, bit-identical to `Indexer::fill_elementwise`.
    pub fn fill_elementwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.dim);
        let all_starts = self.robe_starts();
        let all_base = self.robe_base();
        let all_region = self.robe_region();
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                let starts = &all_starts[self.robe_off[f] + v * self.c..][..self.c];
                let (base, region) = (all_base[f], all_region[f]);
                for &s in starts {
                    for e in 0..self.dc {
                        out[o] = base + ((s + e) % region) as i32;
                        o += 1;
                    }
                }
            }
        }
    }

    /// DHE hash features, bit-identical to `Indexer::fill_dhe`.
    pub fn fill_dhe(&self, cats: &[u32], batch: usize, out: &mut [f32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.n_hash);
        let table = self.dhe_table();
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                let dst = &mut out[(b * f_n + f) * self.n_hash..][..self.n_hash];
                if table.is_empty() {
                    self.dhe_live[f].fill(v as u32, dst);
                } else {
                    let src = self.dhe_off[f] + v * self.n_hash;
                    dst.copy_from_slice(&table[src..src + self.n_hash]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::layout::TablePlan;
    use crate::util::Rng;

    fn cats_for(vocabs: &[usize], batch: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..batch * vocabs.len())
            .map(|i| rng.below(vocabs[i % vocabs.len()] as u64) as u32)
            .collect()
    }

    #[test]
    fn rowwise_bake_matches_live_with_mixed_maps() {
        let plan = TablePlan::new(&[5, 40, 300], 8, 2, 2, 4);
        let mut rng = Rng::new(0);
        let mut ix = Indexer::new_rowwise(&mut rng, plan);
        // simulate a clustering event: learn one subtable, re-randomize another
        ix.set_learned(
            SubtableId { feature: 1, term: 0, column: 1 },
            (0..40).map(|v| (v * 5 % 8) as u32).collect(),
        );
        ix.set_random(SubtableId { feature: 2, term: 1, column: 0 }, &mut rng);
        let snap = ServingSnapshot::bake(&ix);
        let batch = 7;
        let cats = cats_for(&ix.plan.vocabs, batch, 1);
        let stride = ix.plan.t * ix.plan.c;
        let mut live = vec![0i32; batch * 3 * stride];
        let mut baked = vec![0i32; batch * 3 * stride];
        ix.fill_rowwise(&cats, batch, &mut live);
        snap.fill_rowwise(&cats, batch, &mut baked);
        assert_eq!(live, baked);
        assert_eq!(snap.sample_stride(), 3 * stride);
        assert!(!snap.is_mapped());
        assert!(snap.host_bytes() > 0);
    }

    #[test]
    fn rebake_after_clustering_tracks_new_maps() {
        let plan = TablePlan::new(&[50], 8, 2, 2, 4);
        let mut rng = Rng::new(2);
        let mut ix = Indexer::new_rowwise(&mut rng, plan);
        let before = ServingSnapshot::bake(&ix);
        ix.set_learned(
            SubtableId { feature: 0, term: 0, column: 0 },
            (0..50).map(|v| (v % 8) as u32).collect(),
        );
        let after = ServingSnapshot::bake(&ix);
        // cover the whole vocab so SOME id must map differently post-learning
        let cats: Vec<u32> = (0..50).collect();
        let mut a = vec![0i32; 50 * 2 * 2];
        let mut b = vec![0i32; 50 * 2 * 2];
        before.fill_rowwise(&cats, 50, &mut a);
        after.fill_rowwise(&cats, 50, &mut b);
        assert_ne!(a, b, "stale snapshot should differ from rebaked one");
        let mut live = vec![0i32; 50 * 2 * 2];
        ix.fill_rowwise(&cats, 50, &mut live);
        assert_eq!(live, b);
    }

    #[test]
    fn robe_bake_matches_live() {
        let mut rng = Rng::new(4);
        let ix = Indexer::new_robe(&mut rng, &[30, 100], 50, 8, 2);
        let snap = ServingSnapshot::bake(&ix);
        let cats = cats_for(&[30, 100], 9, 5);
        let mut live = vec![0i32; 9 * 2 * 8];
        let mut baked = vec![0i32; 9 * 2 * 8];
        ix.fill_elementwise(&cats, 9, &mut live);
        snap.fill_elementwise(&cats, 9, &mut baked);
        assert_eq!(live, baked);
    }

    #[test]
    fn dhe_bake_matches_live_in_both_modes() {
        let mut rng = Rng::new(6);
        let ix = Indexer::new_dhe(&mut rng, &[10, 200], 8);
        let snap = ServingSnapshot::bake(&ix);
        assert!(!snap.dhe_table().is_empty(), "small vocab should bake");
        let cats = cats_for(&[10, 200], 5, 7);
        let mut live = vec![0f32; 5 * 2 * 8];
        let mut baked = vec![0f32; 5 * 2 * 8];
        ix.fill_dhe(&cats, 5, &mut live);
        snap.fill_dhe(&cats, 5, &mut baked);
        assert_eq!(live, baked);
        // force the live-fallback path (bake budget 0) and check parity again
        let fallback = ServingSnapshot::bake_with_dhe_cap(&ix, 0);
        assert!(fallback.dhe_table().is_empty());
        let mut fb = vec![0f32; 5 * 2 * 8];
        fallback.fill_dhe(&cats, 5, &mut fb);
        assert_eq!(live, fb);
    }

    #[test]
    fn host_bytes_counts_geometry_not_just_bulk_tables() {
        let mut rng = Rng::new(8);
        let ix = Indexer::new_robe(&mut rng, &[30, 100], 50, 8, 2);
        let snap = ServingSnapshot::bake(&ix);
        let bulk = snap.robe_starts().len() * 4;
        // ROBE per-feature base/region vectors and offset tables must count
        assert!(
            snap.host_bytes() >= bulk + 2 * 2 * 4 + 2 * 8,
            "host_bytes {} omits geometry (bulk {})",
            snap.host_bytes(),
            bulk
        );
    }
}
