//! Baked, read-only serving snapshot of a trained `(state, Indexer)` pair.
//!
//! The training `Indexer` answers every lookup through an `IndexMap` enum
//! match (hash vs learned vs identity) because clustering events rewrite
//! maps mid-run. At serving time the maps are frozen, so `bake` materializes
//! them once into flat contiguous arrays and the hot path becomes a
//! branch-free gather:
//!
//!   * row-wise: `rows[feat_off[f] + v * (t*c) ..]` holds the `t*c` GLOBAL
//!     pool rows of id `v` — subtable bases are folded in at bake time and
//!     one id's rows are adjacent (one cache line for t=2, c=4).
//!   * ROBE: per-(id, column) window *starts* are materialized; the serve
//!     path only does the `(start + e) % region` run expansion.
//!   * DHE: the full `[vocab, n_hash]` feature table is baked when it fits
//!     under [`DHE_BAKE_MAX_ELEMS`]; above that the per-feature hashers are
//!     kept and evaluated live (bit-identical either way).
//!
//! Every `fill_*` here is bit-identical to the live `Indexer` equivalent —
//! pinned by `tests/proptests.rs::prop_snapshot_*` — so a snapshot can be
//! swapped under `coordinator::serve` with zero behavior change.

use crate::hashing::DheHasher;
use crate::tables::indexer::{Indexer, MethodKind};
use crate::tables::layout::SubtableId;

/// Above this many total baked f32s, DHE falls back to live hashing (the
/// terabyte-sim preset would otherwise bake multi-GB tables; see ROADMAP
/// "sharded snapshots").
pub const DHE_BAKE_MAX_ELEMS: usize = 1 << 26;

/// Read-only index-generation state for one frozen model.
#[derive(Clone)]
pub struct ServingSnapshot {
    kind: MethodKind,
    n_features: usize,
    vocabs: Vec<usize>,
    /// row-wise: global rows `[f][v][t*c]`, entry count per id
    stride: usize,
    rows: Vec<u32>,
    feat_off: Vec<usize>,
    /// ROBE: window starts `[f][v][c]` + per-feature region geometry
    c: usize,
    dc: u32,
    dim: usize,
    robe_starts: Vec<u32>,
    robe_off: Vec<usize>,
    robe_base: Vec<i32>,
    robe_region: Vec<u32>,
    /// DHE: baked `[f][v][n_hash]` features, or live hashers when too big
    n_hash: usize,
    dhe_table: Vec<f32>,
    dhe_off: Vec<usize>,
    dhe_live: Vec<DheHasher>,
}

impl ServingSnapshot {
    /// Bake a live indexer's current maps into gather tables.
    pub fn bake(ix: &Indexer) -> ServingSnapshot {
        let mut snap = ServingSnapshot {
            kind: ix.kind,
            n_features: ix.plan.n_features(),
            vocabs: ix.plan.vocabs.clone(),
            stride: 0,
            rows: Vec::new(),
            feat_off: Vec::new(),
            c: 0,
            dc: 0,
            dim: 0,
            robe_starts: Vec::new(),
            robe_off: Vec::new(),
            robe_base: Vec::new(),
            robe_region: Vec::new(),
            n_hash: 0,
            dhe_table: Vec::new(),
            dhe_off: Vec::new(),
            dhe_live: Vec::new(),
        };
        match ix.kind {
            MethodKind::RowWise => snap.bake_rowwise(ix),
            MethodKind::ElementWise => snap.bake_robe(ix),
            MethodKind::Dhe => snap.bake_dhe(ix),
        }
        snap
    }

    fn bake_rowwise(&mut self, ix: &Indexer) {
        let (t_n, c_n) = (ix.plan.t, ix.plan.c);
        self.stride = t_n * c_n;
        let total: usize = self.vocabs.iter().map(|&v| v * self.stride).sum();
        self.rows = vec![0u32; total];
        let mut off = 0usize;
        for f in 0..self.n_features {
            self.feat_off.push(off);
            // interleave the feature's t*c subtable maps so one id's rows
            // are contiguous in the gather table
            for t in 0..t_n {
                for j in 0..c_n {
                    let table =
                        ix.materialize_global(SubtableId { feature: f, term: t, column: j });
                    let slot = t * c_n + j;
                    for (v, &g) in table.iter().enumerate() {
                        self.rows[off + v * self.stride + slot] = g;
                    }
                }
            }
            off += self.vocabs[f] * self.stride;
        }
    }

    fn bake_robe(&mut self, ix: &Indexer) {
        self.dim = ix.dim();
        let mut off = 0usize;
        for f in 0..self.n_features {
            let w = ix.robe_windows(f);
            if f == 0 {
                self.c = w.n_columns();
                self.dc = w.dc;
            }
            self.robe_off.push(off);
            self.robe_base.push(ix.robe_region_base(f) as i32);
            self.robe_region.push(w.region);
            for v in 0..self.vocabs[f] as u32 {
                for j in 0..self.c {
                    self.robe_starts.push(w.start(j, v));
                }
            }
            off += self.vocabs[f] * self.c;
        }
    }

    fn bake_dhe(&mut self, ix: &Indexer) {
        self.n_hash = ix.n_hash;
        let total: usize = self.vocabs.iter().map(|&v| v * self.n_hash).sum();
        if total > DHE_BAKE_MAX_ELEMS {
            self.dhe_live = ix.dhe_hashers().to_vec();
            return;
        }
        self.dhe_table = vec![0f32; total];
        let mut off = 0usize;
        for (f, h) in ix.dhe_hashers().iter().enumerate() {
            self.dhe_off.push(off);
            for v in 0..self.vocabs[f] {
                h.fill(v as u32, &mut self.dhe_table[off + v * self.n_hash..][..self.n_hash]);
            }
            off += self.vocabs[f] * self.n_hash;
        }
    }

    pub fn kind(&self) -> MethodKind {
        self.kind
    }

    pub fn n_features(&self) -> usize {
        self.n_features
    }

    /// Embedding-input elements per sample (`emb_elems / batch`).
    pub fn sample_stride(&self) -> usize {
        match self.kind {
            MethodKind::RowWise => self.n_features * self.stride,
            MethodKind::ElementWise => self.n_features * self.dim,
            MethodKind::Dhe => self.n_features * self.n_hash,
        }
    }

    /// Host memory of the baked tables (Appendix E accounting).
    pub fn host_bytes(&self) -> usize {
        self.rows.len() * 4
            + self.robe_starts.len() * 4
            + self.dhe_table.len() * 4
            + self.dhe_live.len() * self.n_hash * 8 // live fallback: seed tables
    }

    /// Row indices for a batch, bit-identical to `Indexer::fill_rowwise`.
    pub fn fill_rowwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.stride);
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                debug_assert!(v < self.vocabs[f], "value {v} out of vocab");
                let block = &self.rows[self.feat_off[f] + v * self.stride..][..self.stride];
                for &r in block {
                    out[o] = r as i32;
                    o += 1;
                }
            }
        }
    }

    /// Element indices for ROBE, bit-identical to `Indexer::fill_elementwise`.
    pub fn fill_elementwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.dim);
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                let starts = &self.robe_starts[self.robe_off[f] + v * self.c..][..self.c];
                let (base, region) = (self.robe_base[f], self.robe_region[f]);
                for &s in starts {
                    for e in 0..self.dc {
                        out[o] = base + ((s + e) % region) as i32;
                        o += 1;
                    }
                }
            }
        }
    }

    /// DHE hash features, bit-identical to `Indexer::fill_dhe`.
    pub fn fill_dhe(&self, cats: &[u32], batch: usize, out: &mut [f32]) {
        let f_n = self.n_features;
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * self.n_hash);
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f] as usize;
                let dst = &mut out[(b * f_n + f) * self.n_hash..][..self.n_hash];
                if self.dhe_table.is_empty() {
                    self.dhe_live[f].fill(v as u32, dst);
                } else {
                    let src = self.dhe_off[f] + v * self.n_hash;
                    dst.copy_from_slice(&self.dhe_table[src..src + self.n_hash]);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::layout::TablePlan;
    use crate::util::Rng;

    fn cats_for(vocabs: &[usize], batch: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..batch * vocabs.len())
            .map(|i| rng.below(vocabs[i % vocabs.len()] as u64) as u32)
            .collect()
    }

    #[test]
    fn rowwise_bake_matches_live_with_mixed_maps() {
        let plan = TablePlan::new(&[5, 40, 300], 8, 2, 2, 4);
        let mut rng = Rng::new(0);
        let mut ix = Indexer::new_rowwise(&mut rng, plan);
        // simulate a clustering event: learn one subtable, re-randomize another
        ix.set_learned(
            SubtableId { feature: 1, term: 0, column: 1 },
            (0..40).map(|v| (v * 5 % 8) as u32).collect(),
        );
        ix.set_random(SubtableId { feature: 2, term: 1, column: 0 }, &mut rng);
        let snap = ServingSnapshot::bake(&ix);
        let batch = 7;
        let cats = cats_for(&ix.plan.vocabs, batch, 1);
        let stride = ix.plan.t * ix.plan.c;
        let mut live = vec![0i32; batch * 3 * stride];
        let mut baked = vec![0i32; batch * 3 * stride];
        ix.fill_rowwise(&cats, batch, &mut live);
        snap.fill_rowwise(&cats, batch, &mut baked);
        assert_eq!(live, baked);
        assert_eq!(snap.sample_stride(), 3 * stride);
        assert!(snap.host_bytes() > 0);
    }

    #[test]
    fn rebake_after_clustering_tracks_new_maps() {
        let plan = TablePlan::new(&[50], 8, 2, 2, 4);
        let mut rng = Rng::new(2);
        let mut ix = Indexer::new_rowwise(&mut rng, plan);
        let before = ServingSnapshot::bake(&ix);
        ix.set_learned(
            SubtableId { feature: 0, term: 0, column: 0 },
            (0..50).map(|v| (v % 8) as u32).collect(),
        );
        let after = ServingSnapshot::bake(&ix);
        // cover the whole vocab so SOME id must map differently post-learning
        let cats: Vec<u32> = (0..50).collect();
        let mut a = vec![0i32; 50 * 2 * 2];
        let mut b = vec![0i32; 50 * 2 * 2];
        before.fill_rowwise(&cats, 50, &mut a);
        after.fill_rowwise(&cats, 50, &mut b);
        assert_ne!(a, b, "stale snapshot should differ from rebaked one");
        let mut live = vec![0i32; 50 * 2 * 2];
        ix.fill_rowwise(&cats, 50, &mut live);
        assert_eq!(live, b);
    }

    #[test]
    fn robe_bake_matches_live() {
        let mut rng = Rng::new(4);
        let ix = Indexer::new_robe(&mut rng, &[30, 100], 50, 8, 2);
        let snap = ServingSnapshot::bake(&ix);
        let cats = cats_for(&[30, 100], 9, 5);
        let mut live = vec![0i32; 9 * 2 * 8];
        let mut baked = vec![0i32; 9 * 2 * 8];
        ix.fill_elementwise(&cats, 9, &mut live);
        snap.fill_elementwise(&cats, 9, &mut baked);
        assert_eq!(live, baked);
    }

    #[test]
    fn dhe_bake_matches_live_in_both_modes() {
        let mut rng = Rng::new(6);
        let ix = Indexer::new_dhe(&mut rng, &[10, 200], 8);
        let snap = ServingSnapshot::bake(&ix);
        assert!(!snap.dhe_table.is_empty(), "small vocab should bake");
        let cats = cats_for(&[10, 200], 5, 7);
        let mut live = vec![0f32; 5 * 2 * 8];
        let mut baked = vec![0f32; 5 * 2 * 8];
        ix.fill_dhe(&cats, 5, &mut live);
        snap.fill_dhe(&cats, 5, &mut baked);
        assert_eq!(live, baked);
        // force the live-fallback path and check parity again
        let mut fallback = snap.clone();
        fallback.dhe_table = Vec::new();
        fallback.dhe_off = Vec::new();
        fallback.dhe_live = ix.dhe_hashers().to_vec();
        let mut fb = vec![0f32; 5 * 2 * 8];
        fallback.fill_dhe(&cats, 5, &mut fb);
        assert_eq!(live, fb);
    }
}
