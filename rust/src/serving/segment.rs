//! Versioned on-disk segment format for [`ServingSnapshot`] — write once
//! after a bake, load in milliseconds via `mmap`, hot-swap under load.
//!
//! # Layout (version 1, little-endian only)
//!
//! ```text
//!   offset  size  field
//!   ------  ----  -----------------------------------------------------
//!        0     8  magic "CCESEG01"
//!        8     4  format version (u32, = 1)
//!       12     4  method kind (u32: 0 row-wise, 1 element-wise, 2 DHE)
//!       16     8  generation (bake counter; hot-swap ordering tag)
//!       24     8  n_features
//!       32     8  stride        (row-wise: t*c entries per id)
//!       40     8  c             (ROBE: columns per id)
//!       48     8  dc            (ROBE: chunk length)
//!       56     8  dim           (ROBE: embedding dim = c*dc)
//!       64     8  n_hash        (DHE: hash features per id)
//!       72     8  dhe_live flag (1 = hashers persisted, no baked table)
//!       80     8  file_len      (total bytes; cheap truncation check)
//!       88   168  section table: 7 × (offset u64, len u64, fnv1a-64 u64)
//!      256     8  fnv1a-64 of bytes [0, 256) (header checksum)
//!      320     -  sections, each 64-byte aligned, in table order:
//!                 vocabs (u64) · rows (u32) · robe_starts (u32) ·
//!                 robe_base (i32) · robe_region (u32) · dhe_table (f32) ·
//!                 dhe_seeds (u64)
//! ```
//!
//! Sections a method does not use are present with length 0, so one reader
//! handles all three `MethodKind`s. Per-feature offset tables are NOT
//! persisted — they are prefix sums of `vocabs` and are recomputed on load,
//! which keeps the file format free of redundant (and corruptible) state.
//!
//! # Verification policy
//!
//! `load_segment` validates the header (magic, version, header checksum,
//! section bounds/alignment, geometry-implied section lengths) but does NOT
//! hash the bulk sections — that would touch every page and turn a
//! millisecond cold start back into an O(table) scan. `load_segment_verified`
//! additionally checks every section checksum; `cce snapshot inspect
//! --verify` and the corruption tests use it. Writes go to a `.tmp` sibling
//! and are published by `rename(2)`, so a concurrently-loading server never
//! sees a half-written file.

use crate::hashing::DheHasher;
use crate::serving::snapshot::{ServingSnapshot, SnapshotTables};
use crate::tables::indexer::MethodKind;
use crate::util::mmap::{as_u64s, MappedFile};
use anyhow::{bail, ensure, Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

pub const MAGIC: [u8; 8] = *b"CCESEG01";
pub const VERSION: u32 = 1;

/// Section indices (also the on-disk order).
const SEC_VOCABS: usize = 0;
const SEC_ROWS: usize = 1;
const SEC_ROBE_STARTS: usize = 2;
const SEC_ROBE_BASE: usize = 3;
const SEC_ROBE_REGION: usize = 4;
const SEC_DHE_TABLE: usize = 5;
const SEC_DHE_SEEDS: usize = 6;
const N_SECTIONS: usize = 7;

pub const SECTION_NAMES: [&str; N_SECTIONS] =
    ["vocabs", "rows", "robe_starts", "robe_base", "robe_region", "dhe_table", "dhe_seeds"];

/// Fixed header size: 88 fixed bytes + 7×24 section table + 8 checksum.
pub const HEADER_BYTES: usize = 88 + N_SECTIONS * 24 + 8;

/// Section payload alignment — matches a cache line and divides the page
/// size, so typed reinterpretation of mapped sections is always aligned.
const SECTION_ALIGN: u64 = 64;

fn align_up(off: u64) -> u64 {
    off.div_ceil(SECTION_ALIGN) * SECTION_ALIGN
}

fn ensure_little_endian() -> Result<()> {
    ensure!(
        cfg!(target_endian = "little"),
        "segment files are little-endian; big-endian hosts are unsupported"
    );
    Ok(())
}

/// FNV-1a 64-bit over raw bytes — the segment's checksum primitive.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// View a typed slice's memory as raw bytes (for writing + checksums).
fn bytes_of<T>(s: &[T]) -> &[u8] {
    // SAFETY: size_of_val is exactly the slice's byte extent, u8 has no
    // alignment requirement and accepts all bit patterns (callers only pass
    // plain number slices — no padding bytes), and the borrow keeps the
    // memory immutable for the returned lifetime.
    unsafe { std::slice::from_raw_parts(s.as_ptr() as *const u8, std::mem::size_of_val(s)) }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SectionDesc {
    pub offset: u64,
    pub len: u64,
    pub checksum: u64,
}

/// Parsed + validated segment header.
#[derive(Clone, Debug)]
pub struct SegmentHeader {
    pub kind: MethodKind,
    pub generation: u64,
    pub n_features: usize,
    pub stride: usize,
    pub c: usize,
    pub dc: u32,
    pub dim: usize,
    pub n_hash: usize,
    pub dhe_live: bool,
    pub file_len: u64,
    pub sections: [SectionDesc; N_SECTIONS],
}

fn kind_code(kind: MethodKind) -> u32 {
    match kind {
        MethodKind::RowWise => 0,
        MethodKind::ElementWise => 1,
        MethodKind::Dhe => 2,
    }
}

/// Serialize a snapshot to `path` atomically (`.tmp` + rename). Returns the
/// file size in bytes. `generation` is the bake counter the hot-swap loop
/// uses to order snapshots.
pub fn write_segment(snap: &ServingSnapshot, generation: u64, path: &Path) -> Result<u64> {
    ensure_little_endian()?;
    let vocabs: Vec<u64> = snap.vocabs().iter().map(|&v| v as u64).collect();
    let seeds: Vec<u64> =
        snap.dhe_live_hashers().iter().flat_map(|h| h.seeds().iter().copied()).collect();
    let sections: [&[u8]; N_SECTIONS] = [
        bytes_of(&vocabs),
        bytes_of(snap.rows()),
        bytes_of(snap.robe_starts()),
        bytes_of(snap.robe_base()),
        bytes_of(snap.robe_region()),
        bytes_of(snap.dhe_table()),
        bytes_of(&seeds),
    ];

    let mut descs = [SectionDesc::default(); N_SECTIONS];
    let mut off = align_up(HEADER_BYTES as u64);
    for (d, s) in descs.iter_mut().zip(&sections) {
        *d = SectionDesc { offset: off, len: s.len() as u64, checksum: fnv1a(s) };
        off = align_up(off + d.len);
    }
    let last = &descs[N_SECTIONS - 1];
    let file_len = last.offset + last.len;

    let mut header = Vec::with_capacity(HEADER_BYTES);
    header.extend_from_slice(&MAGIC);
    header.extend_from_slice(&VERSION.to_le_bytes());
    header.extend_from_slice(&kind_code(snap.kind()).to_le_bytes());
    let (c, dc, dim) = snap.robe_geometry();
    let dhe_live = u64::from(!snap.dhe_live_hashers().is_empty());
    for v in [
        generation,
        snap.n_features() as u64,
        snap.stride() as u64,
        c as u64,
        dc as u64,
        dim as u64,
        snap.n_hash() as u64,
        dhe_live,
        file_len,
    ] {
        header.extend_from_slice(&v.to_le_bytes());
    }
    for d in &descs {
        header.extend_from_slice(&d.offset.to_le_bytes());
        header.extend_from_slice(&d.len.to_le_bytes());
        header.extend_from_slice(&d.checksum.to_le_bytes());
    }
    let ck = fnv1a(&header);
    header.extend_from_slice(&ck.to_le_bytes());
    debug_assert_eq!(header.len(), HEADER_BYTES);

    let name = path.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
    ensure!(!name.is_empty(), "segment path {} has no file name", path.display());
    let tmp = path.with_file_name(format!("{name}.tmp"));
    {
        let file = File::create(&tmp)
            .with_context(|| format!("create segment tmp {}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        w.write_all(&header)?;
        let zeros = [0u8; SECTION_ALIGN as usize];
        let mut pos = header.len() as u64;
        for (d, s) in descs.iter().zip(&sections) {
            w.write_all(&zeros[..(d.offset - pos) as usize])?;
            w.write_all(s)?;
            pos = d.offset + d.len;
        }
        w.flush()?;
        w.get_ref().sync_all()?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish segment {}", path.display()))?;
    Ok(file_len)
}

/// Parse and validate a header from the first bytes of a segment file.
/// Cheap by design: no bulk section is touched.
pub fn parse_header(bytes: &[u8]) -> Result<SegmentHeader> {
    ensure_little_endian()?;
    ensure!(
        bytes.len() >= HEADER_BYTES,
        "segment truncated: {} bytes, header alone is {HEADER_BYTES}",
        bytes.len()
    );
    ensure!(bytes[..8] == MAGIC, "bad magic: not a CCE segment file");
    let rd32 = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let rd64 = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let version = rd32(8);
    ensure!(version == VERSION, "segment version {version} unsupported (want {VERSION})");
    let stored = rd64(HEADER_BYTES - 8);
    let actual = fnv1a(&bytes[..HEADER_BYTES - 8]);
    ensure!(stored == actual, "header checksum mismatch: stored {stored:#x}, computed {actual:#x}");
    let kind = match rd32(12) {
        0 => MethodKind::RowWise,
        1 => MethodKind::ElementWise,
        2 => MethodKind::Dhe,
        k => bail!("unknown method kind {k}"),
    };
    let file_len = rd64(80);
    ensure!(
        file_len == bytes.len() as u64,
        "segment truncated: file is {} bytes, header says {file_len}",
        bytes.len()
    );
    let mut sections = [SectionDesc::default(); N_SECTIONS];
    for (i, d) in sections.iter_mut().enumerate() {
        let o = 88 + i * 24;
        *d = SectionDesc { offset: rd64(o), len: rd64(o + 8), checksum: rd64(o + 16) };
        ensure!(
            d.offset % SECTION_ALIGN == 0,
            "section {} misaligned at offset {}",
            SECTION_NAMES[i],
            d.offset
        );
        ensure!(
            d.offset >= HEADER_BYTES as u64 && d.offset.saturating_add(d.len) <= file_len,
            "section {} [{}, {}) out of bounds (file {file_len})",
            SECTION_NAMES[i],
            d.offset,
            d.offset.saturating_add(d.len)
        );
    }
    Ok(SegmentHeader {
        kind,
        generation: rd64(16),
        n_features: rd64(24) as usize,
        stride: rd64(32) as usize,
        c: rd64(40) as usize,
        dc: rd64(48) as u32,
        dim: rd64(56) as usize,
        n_hash: rd64(64) as usize,
        dhe_live: rd64(72) != 0,
        file_len,
        sections,
    })
}

fn section_bytes<'a>(bytes: &'a [u8], d: &SectionDesc) -> &'a [u8] {
    &bytes[d.offset as usize..(d.offset + d.len) as usize]
}

/// A snapshot loaded (zero-copy where possible) from a segment file.
pub struct LoadedSegment {
    pub snapshot: ServingSnapshot,
    pub generation: u64,
    pub file_bytes: u64,
    /// true when the kernel mapping fast path was used (vs the read fallback)
    pub mapped: bool,
}

/// Load a segment with quick verification only (header + geometry). This is
/// the serving cold-start path: O(header), independent of table size.
pub fn load_segment(path: &Path) -> Result<LoadedSegment> {
    load_inner(path, false)
}

/// Load a segment and additionally verify every section checksum — O(file),
/// for `cce snapshot inspect --verify` and corruption tests.
pub fn load_segment_verified(path: &Path) -> Result<LoadedSegment> {
    load_inner(path, true)
}

fn load_inner(path: &Path, verify_checksums: bool) -> Result<LoadedSegment> {
    let file = Arc::new(MappedFile::open(path)?);
    let h = parse_header(file.bytes())
        .with_context(|| format!("load segment {}", path.display()))?;
    if verify_checksums {
        for (i, d) in h.sections.iter().enumerate() {
            let got = fnv1a(section_bytes(file.bytes(), d));
            ensure!(
                got == d.checksum,
                "checksum mismatch in section {} of {} (stored {:#x}, computed {got:#x})",
                SECTION_NAMES[i],
                path.display(),
                d.checksum
            );
        }
    }

    let dv = &h.sections[SEC_VOCABS];
    ensure!(
        dv.len as usize == h.n_features * 8,
        "vocabs section is {} bytes, expected {} for {} features",
        dv.len,
        h.n_features * 8,
        h.n_features
    );
    let vocabs: Vec<usize> =
        as_u64s(section_bytes(file.bytes(), dv)).iter().map(|&v| v as usize).collect();
    let sum_v: usize = vocabs.iter().sum();

    // geometry-implied section lengths: a wrong length means index math in
    // fill_* would read out of section bounds, so reject up front
    let expect = |idx: usize, want: usize| -> Result<()> {
        ensure!(
            h.sections[idx].len as usize == want,
            "section {} is {} bytes, geometry implies {want}",
            SECTION_NAMES[idx],
            h.sections[idx].len
        );
        Ok(())
    };
    match h.kind {
        MethodKind::RowWise => {
            expect(SEC_ROWS, sum_v * h.stride * 4)?;
            for idx in [SEC_ROBE_STARTS, SEC_ROBE_BASE, SEC_ROBE_REGION, SEC_DHE_TABLE] {
                expect(idx, 0)?;
            }
            expect(SEC_DHE_SEEDS, 0)?;
        }
        MethodKind::ElementWise => {
            expect(SEC_ROWS, 0)?;
            expect(SEC_ROBE_STARTS, sum_v * h.c * 4)?;
            expect(SEC_ROBE_BASE, h.n_features * 4)?;
            expect(SEC_ROBE_REGION, h.n_features * 4)?;
            expect(SEC_DHE_TABLE, 0)?;
            expect(SEC_DHE_SEEDS, 0)?;
        }
        MethodKind::Dhe => {
            for idx in [SEC_ROWS, SEC_ROBE_STARTS, SEC_ROBE_BASE, SEC_ROBE_REGION] {
                expect(idx, 0)?;
            }
            if h.dhe_live {
                expect(SEC_DHE_TABLE, 0)?;
                expect(SEC_DHE_SEEDS, h.n_features * h.n_hash * 8)?;
            } else {
                expect(SEC_DHE_TABLE, sum_v * h.n_hash * 4)?;
                expect(SEC_DHE_SEEDS, 0)?;
            }
        }
    }

    let dhe_live = if h.dhe_live {
        as_u64s(section_bytes(file.bytes(), &h.sections[SEC_DHE_SEEDS]))
            .chunks(h.n_hash.max(1))
            .map(|c| DheHasher::from_seeds(c.to_vec()))
            .collect()
    } else {
        Vec::new()
    };

    let range = |idx: usize| {
        let d = &h.sections[idx];
        d.offset as usize..(d.offset + d.len) as usize
    };
    let tables = SnapshotTables::Mapped {
        rows: range(SEC_ROWS),
        robe_starts: range(SEC_ROBE_STARTS),
        robe_base: range(SEC_ROBE_BASE),
        robe_region: range(SEC_ROBE_REGION),
        dhe_table: range(SEC_DHE_TABLE),
        file: file.clone(),
    };
    let (mapped, file_bytes) = (file.is_mmap(), file.len() as u64);
    let snapshot = ServingSnapshot::from_parts(
        h.kind, vocabs, h.stride, h.c, h.dc, h.dim, h.n_hash, dhe_live, tables,
    );
    Ok(LoadedSegment { snapshot, generation: h.generation, file_bytes, mapped })
}

/// Per-section report for `cce snapshot inspect`.
pub struct SectionReport {
    pub name: &'static str,
    pub offset: u64,
    pub bytes: u64,
    /// `None` unless checksum verification was requested
    pub checksum_ok: Option<bool>,
}

pub struct SegmentInfo {
    pub header: SegmentHeader,
    pub file_bytes: u64,
    pub sections: Vec<SectionReport>,
}

/// Read a segment's header + section table without building a snapshot.
pub fn inspect(path: &Path, verify: bool) -> Result<SegmentInfo> {
    let file = MappedFile::open(path)?;
    let header = parse_header(file.bytes())
        .with_context(|| format!("inspect segment {}", path.display()))?;
    let sections = header
        .sections
        .iter()
        .enumerate()
        .map(|(i, d)| SectionReport {
            name: SECTION_NAMES[i],
            offset: d.offset,
            bytes: d.len,
            checksum_ok: verify.then(|| fnv1a(section_bytes(file.bytes(), d)) == d.checksum),
        })
        .collect();
    Ok(SegmentInfo { header, file_bytes: file.len() as u64, sections })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::indexer::Indexer;
    use crate::tables::layout::TablePlan;
    use crate::util::Rng;

    fn tmp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cce_segment_{}_{tag}.cceseg", std::process::id()))
    }

    fn rowwise_snapshot(seed: u64) -> ServingSnapshot {
        let mut rng = Rng::new(seed);
        let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[5, 40, 300], 8, 2, 2, 4));
        ServingSnapshot::bake(&ix)
    }

    fn cats_for(vocabs: &[usize], batch: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..batch * vocabs.len())
            .map(|i| rng.below(vocabs[i % vocabs.len()] as u64) as u32)
            .collect()
    }

    #[test]
    fn roundtrip_rowwise_bit_identical() {
        let p = tmp_path("rt_rowwise");
        let mut rng = Rng::new(0);
        let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[5, 40, 300], 8, 2, 2, 4));
        let snap = ServingSnapshot::bake(&ix);
        let bytes = write_segment(&snap, 3, &p).unwrap();
        let loaded = load_segment_verified(&p).unwrap();
        assert_eq!(loaded.generation, 3);
        assert_eq!(loaded.file_bytes, bytes);
        assert!(loaded.snapshot.is_mapped());
        let cats = cats_for(&ix.plan.vocabs, 7, 1);
        let stride = snap.sample_stride();
        let mut a = vec![0i32; 7 * stride];
        let mut b = vec![0i32; 7 * stride];
        snap.fill_rowwise(&cats, 7, &mut a);
        loaded.snapshot.fill_rowwise(&cats, 7, &mut b);
        assert_eq!(a, b);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn roundtrip_robe_and_dhe_live() {
        let mut rng = Rng::new(4);
        let robe = ServingSnapshot::bake(&Indexer::new_robe(&mut rng, &[30, 100], 50, 8, 2));
        let p1 = tmp_path("rt_robe");
        write_segment(&robe, 1, &p1).unwrap();
        let l1 = load_segment_verified(&p1).unwrap();
        let cats = cats_for(&[30, 100], 9, 5);
        let mut a = vec![0i32; 9 * robe.sample_stride()];
        let mut b = a.clone();
        robe.fill_elementwise(&cats, 9, &mut a);
        l1.snapshot.fill_elementwise(&cats, 9, &mut b);
        assert_eq!(a, b);
        std::fs::remove_file(&p1).ok();

        // DHE with the live-fallback path: seeds round-trip, not the table
        let ix = Indexer::new_dhe(&mut rng, &[10, 200], 8);
        let dhe = ServingSnapshot::bake_with_dhe_cap(&ix, 0);
        let p2 = tmp_path("rt_dhe_live");
        write_segment(&dhe, 2, &p2).unwrap();
        let l2 = load_segment_verified(&p2).unwrap();
        assert!(l2.snapshot.dhe_table().is_empty(), "live fallback must persist seeds");
        let cats = cats_for(&[10, 200], 5, 7);
        let mut x = vec![0f32; 5 * dhe.sample_stride()];
        let mut y = x.clone();
        dhe.fill_dhe(&cats, 5, &mut x);
        l2.snapshot.fill_dhe(&cats, 5, &mut y);
        assert_eq!(x, y);
        std::fs::remove_file(&p2).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        let p = tmp_path("truncated");
        write_segment(&rowwise_snapshot(1), 0, &p).unwrap();
        let full = std::fs::metadata(&p).unwrap().len();
        // cut into the sections
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(full - 1).unwrap();
        drop(f);
        let err = format!("{:#}", load_segment(&p).unwrap_err());
        assert!(err.contains("truncated"), "unexpected error: {err}");
        // cut into the header itself
        let f = std::fs::OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(100).unwrap();
        drop(f);
        let err = format!("{:#}", load_segment(&p).unwrap_err());
        assert!(err.contains("truncated"), "unexpected error: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let p = tmp_path("magic");
        write_segment(&rowwise_snapshot(2), 0, &p).unwrap();
        let good = std::fs::read(&p).unwrap();
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        let err = format!("{:#}", load_segment(&p).unwrap_err());
        assert!(err.contains("magic"), "unexpected error: {err}");

        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&p, &bad).unwrap();
        let err = format!("{:#}", load_segment(&p).unwrap_err());
        assert!(err.contains("version 99"), "unexpected error: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_header_and_section_corruption() {
        let p = tmp_path("corrupt");
        write_segment(&rowwise_snapshot(3), 7, &p).unwrap();
        let good = std::fs::read(&p).unwrap();

        // flip a bit in the generation field: quick load must catch it
        let mut bad = good.clone();
        bad[16] ^= 0x01;
        std::fs::write(&p, &bad).unwrap();
        let err = format!("{:#}", load_segment(&p).unwrap_err());
        assert!(err.contains("header checksum"), "unexpected error: {err}");

        // flip a byte inside the rows section: quick load stays fast (and
        // accepts), full verification must reject
        let h = parse_header(&good).unwrap();
        let rows_off = h.sections[SEC_ROWS].offset as usize;
        let mut bad = good.clone();
        bad[rows_off] ^= 0xFF;
        std::fs::write(&p, &bad).unwrap();
        assert!(load_segment(&p).is_ok(), "quick load does not hash sections");
        let err = format!("{:#}", load_segment_verified(&p).unwrap_err());
        assert!(err.contains("checksum mismatch in section rows"), "unexpected error: {err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn inspect_reports_sections_and_generation() {
        let p = tmp_path("inspect");
        write_segment(&rowwise_snapshot(5), 42, &p).unwrap();
        let info = inspect(&p, true).unwrap();
        assert_eq!(info.header.generation, 42);
        assert_eq!(info.sections.len(), N_SECTIONS);
        assert!(info.sections.iter().all(|s| s.checksum_ok == Some(true)));
        let rows = info.sections.iter().find(|s| s.name == "rows").unwrap();
        assert!(rows.bytes > 0 && rows.offset % SECTION_ALIGN == 0);
        let quick = inspect(&p, false).unwrap();
        assert!(quick.sections.iter().all(|s| s.checksum_ok.is_none()));
        std::fs::remove_file(&p).ok();
    }
}
