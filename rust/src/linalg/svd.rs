//! One-sided Jacobi SVD (Hestenes): `A = U Σ Vᵀ`.
//!
//! Needed for (i) the "smart noise" variant of dense CCE, which samples
//! `g = V Σ⁻¹ g'` to get the improved `(1 − 1/d₁)^{ik}` rate (paper
//! Appendix B / Figure 6), and (ii) computing ρ = σ_min² / ‖X‖_F² in the
//! Theorem 3.1 bound.
//!
//! One-sided Jacobi is simple, numerically robust, and accurate to machine
//! precision for the moderate sizes the experiments use.

use crate::linalg::Matrix;

pub struct Svd {
    /// m × r (orthonormal columns)
    pub u: Matrix,
    /// singular values, descending, length r = min(m, n)
    pub s: Vec<f64>,
    /// n × r (orthonormal columns); A ≈ U diag(S) Vᵀ
    pub v: Matrix,
}

/// Compute the thin SVD of `a` (m ≥ n required; transpose first otherwise).
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "svd needs tall input, got {m}x{n}");
    // Work on W = A (copied); rotate columns until pairwise orthogonal.
    let mut w = a.clone();
    let mut v = Matrix::eye(n);
    let max_sweeps = 60;
    let eps = 1e-14;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // 2x2 Gram entries
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..m {
                    let xp = w[(i, p)];
                    let xq = w[(i, q)];
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation annihilating the (p, q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = w[(i, p)];
                    let xq = w[(i, q)];
                    w[(i, p)] = c * xp - s * xq;
                    w[(i, q)] = s * xp + c * xq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q)] = s * vp + c * vq;
                }
            }
        }
        if off < eps {
            break;
        }
    }
    // singular values = column norms of W; U = W normalized
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a2, &b| norms[b].total_cmp(&norms[a2]));
    let mut u = Matrix::zeros(m, n);
    let mut vv = Matrix::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (jj, &j) in order.iter().enumerate() {
        let nj = norms[j];
        s.push(nj);
        for i in 0..m {
            u[(i, jj)] = if nj > 0.0 { w[(i, j)] / nj } else { 0.0 };
        }
        for i in 0..n {
            vv[(i, jj)] = v[(i, j)];
        }
    }
    Svd { u, s, v: vv }
}

impl Svd {
    /// ρ = σ_min² / Σσ² — the rate constant of Theorem 3.1.
    pub fn rho(&self) -> f64 {
        let total: f64 = self.s.iter().map(|&x| x * x).sum();
        let min = self.s.last().copied().unwrap_or(0.0);
        if total > 0.0 {
            min * min / total
        } else {
            0.0
        }
    }

    /// Reconstruct `U diag(S) Vᵀ` (tests).
    pub fn reconstruct(&self) -> Matrix {
        let mut us = self.u.clone();
        for i in 0..us.rows {
            for j in 0..us.cols {
                us[(i, j)] *= self.s[j];
            }
        }
        us.matmul(&self.v.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn reconstructs_random_matrix() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 30, 12);
        let d = svd(&a);
        assert!(d.reconstruct().sub(&a).fro() < 1e-9 * a.fro());
    }

    #[test]
    fn singular_values_sorted_and_nonnegative() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 25, 10);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 40, 8);
        let d = svd(&a);
        assert!(d.u.t_matmul(&d.u).sub(&Matrix::eye(8)).fro() < 1e-9);
        assert!(d.v.t_matmul(&d.v).sub(&Matrix::eye(8)).fro() < 1e-9);
    }

    #[test]
    fn known_singular_values_of_diagonal() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 4.0], &[0.0, 0.0]]);
        let d = svd(&a);
        assert!((d.s[0] - 4.0).abs() < 1e-12);
        assert!((d.s[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn rank_deficient_has_zero_sigma() {
        let mut rng = Rng::new(3);
        let b = Matrix::randn(&mut rng, 20, 3);
        let c = Matrix::randn(&mut rng, 3, 6);
        let a = b.matmul(&c); // rank ≤ 3
        let d = svd(&a);
        assert!(d.s[3] < 1e-9 * d.s[0], "σ = {:?}", d.s);
    }

    #[test]
    fn frobenius_equals_sigma_norm() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 15, 7);
        let d = svd(&a);
        let fro_s: f64 = d.s.iter().map(|&x| x * x).sum::<f64>().sqrt();
        assert!((fro_s - a.fro()).abs() < 1e-9);
    }

    #[test]
    fn rho_matches_definition() {
        let a = Matrix::from_rows(&[&[2.0, 0.0], &[0.0, 1.0]]);
        let d = svd(&a);
        assert!((d.rho() - 1.0 / 5.0).abs() < 1e-12);
    }
}
