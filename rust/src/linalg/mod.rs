//! Dense linear algebra built from scratch (no BLAS/LAPACK offline):
//! row-major `Matrix`, blocked parallel matmul, Householder-QR least
//! squares, and one-sided Jacobi SVD. Sized for the paper's least-squares
//! experiments (d₁ ≤ a few thousand).

mod matrix;
mod qr;
mod svd;

pub use matrix::Matrix;
pub use qr::{lstsq, qr_decompose};
pub use svd::{svd, Svd};
