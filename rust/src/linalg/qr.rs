//! Householder QR and least squares.
//!
//! `lstsq(A, B)` solves `min_X ‖A X − B‖_F` for full-column-rank tall `A`
//! — the inner step of both CCE least-squares algorithms (`M_i = argmin
//! ‖X H_i M − Y‖`).

use crate::linalg::Matrix;

/// Compact QR: returns (Q, R) with `Q: m×n` orthonormal columns and
/// `R: n×n` upper-triangular, for m ≥ n.
pub fn qr_decompose(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr needs tall matrix, got {m}x{n}");
    let mut r = a.clone();
    // Householder vectors stored per reflection
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    for k in 0..n {
        // build the reflector for column k below the diagonal
        let mut norm2 = 0.0;
        for i in k..m {
            norm2 += r[(i, k)] * r[(i, k)];
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - k];
        if norm == 0.0 {
            vs.push(v);
            continue;
        }
        let alpha = if r[(k, k)] >= 0.0 { -norm } else { norm };
        for i in k..m {
            v[i - k] = r[(i, k)];
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            vs.push(v);
            continue;
        }
        // apply I − 2vvᵀ/‖v‖² to R[k.., k..]
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * r[(i, j)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                r[(i, j)] -= f * v[i - k];
            }
        }
        vs.push(v);
    }
    // extract R (upper n×n), rebuild Q by applying reflectors to I
    let mut rr = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rr[(i, j)] = r[(i, j)];
        }
    }
    let mut q = Matrix::zeros(m, n);
    for i in 0..n {
        q[(i, i)] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q[(i, j)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                q[(i, j)] -= f * v[i - k];
            }
        }
    }
    (q, rr)
}

/// Least squares `min_X ‖A X − B‖_F` via QR. Rank-deficient columns of A
/// (zero diagonal in R) get zero rows in X (minimum-norm-ish fallback,
/// sufficient for the CCE algorithms where H occasionally has zero
/// columns, e.g. M'ᵢ = 0 blocks).
pub fn lstsq(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows);
    let (q, r) = qr_decompose(a);
    let qtb = q.t_matmul(b); // n × p
    let n = a.cols;
    let p = b.cols;
    let mut x = Matrix::zeros(n, p);
    // back substitution, guarding tiny pivots
    let rmax = (0..n).map(|i| r[(i, i)].abs()).fold(0.0f64, f64::max);
    let tol = rmax * 1e-12;
    for j in 0..p {
        for i in (0..n).rev() {
            let mut s = qtb[(i, j)];
            for k2 in (i + 1)..n {
                s -= r[(i, k2)] * x[(k2, j)];
            }
            x[(i, j)] = if r[(i, i)].abs() <= tol { 0.0 } else { s / r[(i, i)] };
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 30, 8);
        let (q, r) = qr_decompose(&a);
        let qr = q.matmul(&r);
        assert!(qr.sub(&a).fro() < 1e-10 * a.fro());
    }

    #[test]
    fn q_has_orthonormal_columns() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 25, 6);
        let (q, _) = qr_decompose(&a);
        let qtq = q.t_matmul(&q);
        assert!(qtq.sub(&Matrix::eye(6)).fro() < 1e-10);
    }

    #[test]
    fn lstsq_exact_for_consistent_system() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 40, 7);
        let x_true = Matrix::randn(&mut rng, 7, 3);
        let b = a.matmul(&x_true);
        let x = lstsq(&a, &b);
        assert!(x.sub(&x_true).fro() < 1e-9);
    }

    #[test]
    fn lstsq_residual_is_orthogonal_to_columns() {
        let mut rng = Rng::new(3);
        let a = Matrix::randn(&mut rng, 50, 5);
        let b = Matrix::randn(&mut rng, 50, 2);
        let x = lstsq(&a, &b);
        let resid = a.matmul(&x).sub(&b);
        let proj = a.t_matmul(&resid); // Aᵀr must be 0 at the optimum
        assert!(proj.fro() < 1e-9, "Aᵀr = {}", proj.fro());
    }

    #[test]
    fn lstsq_beats_any_perturbation() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(&mut rng, 30, 4);
        let b = Matrix::randn(&mut rng, 30, 1);
        let x = lstsq(&a, &b);
        let best = a.matmul(&x).sub(&b).fro2();
        for _ in 0..10 {
            let dx = Matrix::randn(&mut rng, 4, 1).scale(0.1);
            let worse = a.matmul(&x.add(&dx)).sub(&b).fro2();
            assert!(worse >= best - 1e-12);
        }
    }

    #[test]
    fn lstsq_handles_zero_columns() {
        let mut rng = Rng::new(5);
        let a0 = Matrix::randn(&mut rng, 20, 3);
        let a = a0.hcat(&Matrix::zeros(20, 2)); // rank-deficient
        let b = Matrix::randn(&mut rng, 20, 1);
        let x = lstsq(&a, &b);
        assert!(x.data.iter().all(|v| v.is_finite()));
        // solution must match the reduced system's optimum
        let x0 = lstsq(&a0, &b);
        let r_full = a.matmul(&x).sub(&b).fro2();
        let r_red = a0.matmul(&x0).sub(&b).fro2();
        assert!((r_full - r_red).abs() < 1e-9);
    }
}
