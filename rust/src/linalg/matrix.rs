//! Row-major f64 matrix with the operations the CCE least-squares
//! algorithms need. f64 (not f32) because the convergence experiments
//! measure losses down to 1e-12 of the optimum (Figure 8).

use crate::util::threadpool::{self, SharedSlice};
use crate::util::Rng;
use std::ops::{Index, IndexMut};

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut m = Matrix::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn randn(rng: &mut Rng, rows: usize, cols: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for v in m.data.iter_mut() {
            *v = rng.normal();
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Blocked, parallel `self · other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul {}x{} · {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        let out_s = SharedSlice::new(&mut out.data);
        let threads = if m * n * k > 1 << 18 { threadpool::default_threads() } else { 1 };
        threadpool::scope_chunks(m, threads, |_, rs, re| {
            // SAFETY: each worker claims only its own row range
            // [rs*n, re*n) — scope_chunks row chunks are disjoint and
            // re <= m, so re*n <= m*n == out_s.len(). (Previously every
            // chunk materialized an aliasing whole-buffer &mut [f32];
            // the writes were disjoint but the references overlapped.)
            let rows = unsafe { out_s.range_mut(rs * n, (re - rs) * n) };
            // i-k-j loop order: streams `other` rows, vectorizes over j
            for i in rs..re {
                let orow = &mut rows[(i - rs) * n..(i - rs + 1) * n];
                for kk in 0..k {
                    let a = self.data[i * k + kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        });
        drop(out_s); // end the borrow of `out.data` (the scope has joined)
        out
    }

    /// `selfᵀ · other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let (m, k, n) = (self.cols, self.rows, other.cols);
        let mut out = Matrix::zeros(m, n);
        for kk in 0..k {
            let arow = &self.data[kk * m..(kk + 1) * m];
            let brow = &other.data[kk * n..(kk + 1) * n];
            for i in 0..m {
                let a = arow[i];
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(&other.data) {
            *o -= b;
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut out = self.clone();
        for (o, &b) in out.data.iter_mut().zip(&other.data) {
            *o += b;
        }
        out
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for o in out.data.iter_mut() {
            *o *= s;
        }
        out
    }

    /// Squared Frobenius norm.
    pub fn fro2(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum()
    }

    pub fn fro(&self) -> f64 {
        self.fro2().sqrt()
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows);
        let mut out = Matrix::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Column slice `self[:, lo..hi]`.
    pub fn cols_range(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Matrix::zeros(self.rows, hi - lo);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[lo..hi]);
        }
        out
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::randn(&mut rng, 20, 30);
        let c = a.matmul(&Matrix::eye(30));
        for (x, y) in a.data.iter().zip(&c.data) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(&mut rng, 17, 9);
        let b = Matrix::randn(&mut rng, 17, 5);
        let c1 = a.t_matmul(&b);
        let c2 = a.transpose().matmul(&b);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(&mut rng, 130, 90); // large enough to parallelize
        let b = Matrix::randn(&mut rng, 90, 70);
        let c = a.matmul(&b);
        // serial reference
        let mut want = Matrix::zeros(130, 70);
        for i in 0..130 {
            for j in 0..70 {
                let mut s = 0.0;
                for k in 0..90 {
                    s += a[(i, k)] * b[(k, j)];
                }
                want[(i, j)] = s;
            }
        }
        for (x, y) in c.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-9);
        }
    }

    #[test]
    fn fro_and_ops() {
        let a = Matrix::from_rows(&[&[3.0, 4.0]]);
        assert!((a.fro() - 5.0).abs() < 1e-12);
        let b = a.scale(2.0);
        assert_eq!(b.data, vec![6.0, 8.0]);
        assert_eq!(b.sub(&a).data, vec![3.0, 4.0]);
        assert_eq!(b.add(&a).data, vec![9.0, 12.0]);
    }

    #[test]
    fn hcat_and_cols_range() {
        let a = Matrix::from_rows(&[&[1.0], &[2.0]]);
        let b = Matrix::from_rows(&[&[3.0, 4.0], &[5.0, 6.0]]);
        let c = a.hcat(&b);
        assert_eq!(c.row(1), &[2.0, 5.0, 6.0]);
        assert_eq!(c.cols_range(1, 3), b);
    }
}
