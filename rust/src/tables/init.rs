//! State-vector initialization from a manifest layout.
//!
//! Initialization lives on the Rust side (not baked into HLO) so the
//! coordinator can re-initialize regions during CCE clustering events:
//! `M_i ← centroids`, `M'_i ← 0`, and everything else untouched.

use crate::runtime::manifest::{FieldDesc, InitSpec};
use crate::util::Rng;

/// Allocate and initialize a fresh state vector for a layout.
pub fn init_state(fields: &[FieldDesc], state_size: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0f32; state_size];
    for f in fields {
        let dst = &mut out[f.offset..f.offset + f.size];
        match f.init {
            InitSpec::Zeros => {}
            InitSpec::Normal(std) => rng.fill_normal(dst, std),
            InitSpec::Uniform(limit) => rng.fill_uniform(dst, limit),
        }
    }
    out
}

/// Re-initialize a single field in place (used at clustering events).
pub fn reinit_field(state: &mut [f32], f: &FieldDesc, rng: &mut Rng) {
    let dst = &mut state[f.offset..f.offset + f.size];
    match f.init {
        InitSpec::Zeros => dst.fill(0.0),
        InitSpec::Normal(std) => rng.fill_normal(dst, std),
        InitSpec::Uniform(limit) => rng.fill_uniform(dst, limit),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fields() -> Vec<FieldDesc> {
        vec![
            FieldDesc {
                name: "pool".into(),
                shape: vec![10, 4],
                offset: 0,
                size: 40,
                init: InitSpec::Normal(0.5),
                group: "pool".into(),
            },
            FieldDesc {
                name: "b".into(),
                shape: vec![8],
                offset: 40,
                size: 8,
                init: InitSpec::Zeros,
                group: "dense".into(),
            },
            FieldDesc {
                name: "w".into(),
                shape: vec![4, 4],
                offset: 48,
                size: 16,
                init: InitSpec::Uniform(0.1),
                group: "dense".into(),
            },
        ]
    }

    #[test]
    fn init_respects_specs() {
        let mut rng = Rng::new(0);
        let s = init_state(&fields(), 64, &mut rng);
        assert_eq!(s.len(), 64);
        assert!(s[0..40].iter().any(|&x| x != 0.0));
        assert!(s[40..48].iter().all(|&x| x == 0.0));
        assert!(s[48..64].iter().all(|&x| x.abs() <= 0.1));
        assert!(s[48..64].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let a = init_state(&fields(), 64, &mut Rng::new(9));
        let b = init_state(&fields(), 64, &mut Rng::new(9));
        let c = init_state(&fields(), 64, &mut Rng::new(10));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn reinit_zeroes_field() {
        let fs = fields();
        let mut rng = Rng::new(1);
        let mut s = init_state(&fs, 64, &mut rng);
        s[40..48].copy_from_slice(&[1.0; 8]);
        reinit_field(&mut s, &fs[1], &mut rng);
        assert!(s[40..48].iter().all(|&x| x == 0.0));
    }
}
