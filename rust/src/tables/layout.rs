//! Pool-row layout for row-wise methods — the EXACT mirror of
//! `python/compile/specs.py::rows_for` and the packing order documented
//! there: subtables are laid out feature-major, then term, then column,
//! each with `min(vocab_f, cap)` rows of width `d/c`.
//!
//! The Rust side owns all offset arithmetic; the lowered HLO only ever sees
//! global row ids into one `[R, d/c]` pool.

/// Identifies one (feature, term, column) subtable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubtableId {
    pub feature: usize,
    pub term: usize,
    pub column: usize,
}

/// Row layout of the parameter pool for a row-wise artifact.
#[derive(Clone, Debug)]
pub struct TablePlan {
    pub vocabs: Vec<usize>,
    pub cap: usize,
    pub t: usize,
    pub c: usize,
    pub dc: usize,
    /// per-feature subtable row count: `min(vocab, cap)`
    pub k: Vec<usize>,
    /// base row of feature f's first subtable
    feature_base: Vec<usize>,
    pub total_rows: usize,
}

impl TablePlan {
    pub fn new(vocabs: &[usize], cap: usize, t: usize, c: usize, dc: usize) -> TablePlan {
        assert!(t >= 1 && c >= 1 && dc >= 1);
        let k: Vec<usize> = vocabs.iter().map(|&v| v.min(cap)).collect();
        let mut feature_base = Vec::with_capacity(vocabs.len());
        let mut acc = 0usize;
        for &kf in &k {
            feature_base.push(acc);
            acc += t * c * kf;
        }
        TablePlan { vocabs: vocabs.to_vec(), cap, t, c, dc, k, feature_base, total_rows: acc }
    }

    /// Base (first global row) of a subtable.
    #[inline]
    pub fn subtable_base(&self, id: SubtableId) -> usize {
        debug_assert!(id.term < self.t && id.column < self.c);
        self.feature_base[id.feature] + (id.term * self.c + id.column) * self.k[id.feature]
    }

    /// Rows in a subtable (same for every (t, j) of a feature).
    #[inline]
    pub fn subtable_rows(&self, feature: usize) -> usize {
        self.k[feature]
    }

    /// Global row for (feature, term, column, local row).
    #[inline]
    pub fn global_row(&self, id: SubtableId, local: u32) -> u32 {
        debug_assert!((local as usize) < self.k[id.feature]);
        (self.subtable_base(id) + local as usize) as u32
    }

    pub fn n_features(&self) -> usize {
        self.vocabs.len()
    }

    /// Total embedding parameters (pool_rows × dc) — Table 1 accounting.
    pub fn params(&self) -> usize {
        self.total_rows * self.dc
    }

    /// Parameters a FULL table would need (the compression numerator):
    /// `sum(vocab) × d` where `d = c × dc`.
    pub fn full_params(&self) -> usize {
        self.vocabs.iter().sum::<usize>() * self.c * self.dc
    }

    /// Paper measure 1 (Figure 4a): total vocab / total compressed rows,
    /// both sides counted in d-dim row units.
    pub fn compression_total(&self) -> f64 {
        let full_rows: usize = self.vocabs.iter().sum();
        let comp_rows = self.total_rows as f64 / (self.t * self.c) as f64;
        full_rows as f64 / comp_rows
    }

    /// Paper measure 2 (the intro's "11,000×"): largest vocab / its rows.
    pub fn compression_largest(&self) -> f64 {
        let (f, &v) = self
            .vocabs
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .expect("no features");
        v as f64 / self.k[f] as f64
    }

    /// All subtable ids in pool order.
    pub fn subtables(&self) -> impl Iterator<Item = SubtableId> + '_ {
        (0..self.n_features()).flat_map(move |f| {
            (0..self.t).flat_map(move |t| {
                (0..self.c).map(move |j| SubtableId { feature: f, term: t, column: j })
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_rows_for() {
        // specs.rows_for([10, 100], cap=50, t=2, c=4) == 2*4*(10+50)
        let p = TablePlan::new(&[10, 100], 50, 2, 4, 4);
        assert_eq!(p.total_rows, 2 * 4 * (10 + 50));
        assert_eq!(p.k, vec![10, 50]);
    }

    #[test]
    fn subtable_layout_is_feature_term_column() {
        let p = TablePlan::new(&[10, 100], 50, 2, 3, 4);
        // feature 0: base 0; its 6 subtables of 10 rows each
        assert_eq!(p.subtable_base(SubtableId { feature: 0, term: 0, column: 0 }), 0);
        assert_eq!(p.subtable_base(SubtableId { feature: 0, term: 0, column: 1 }), 10);
        assert_eq!(p.subtable_base(SubtableId { feature: 0, term: 1, column: 0 }), 30);
        // feature 1 starts after 2*3*10 rows
        assert_eq!(p.subtable_base(SubtableId { feature: 1, term: 0, column: 0 }), 60);
        assert_eq!(p.subtable_base(SubtableId { feature: 1, term: 1, column: 2 }), 60 + 5 * 50);
        assert_eq!(p.total_rows, 60 + 6 * 50);
    }

    #[test]
    fn subtables_cover_pool_exactly() {
        let p = TablePlan::new(&[7, 20, 33], 25, 2, 4, 2);
        let mut next = 0usize;
        for id in p.subtables() {
            assert_eq!(p.subtable_base(id), next, "{id:?}");
            next += p.subtable_rows(id.feature);
        }
        assert_eq!(next, p.total_rows);
    }

    #[test]
    fn global_rows_in_range() {
        let p = TablePlan::new(&[7, 20], 10, 2, 2, 4);
        for id in p.subtables() {
            for local in 0..p.subtable_rows(id.feature) as u32 {
                assert!((p.global_row(id, local) as usize) < p.total_rows);
            }
        }
    }

    #[test]
    fn compression_measures() {
        // vocabs 10, 100, 10^6 capped at 500 rows (paper's Reproducibility example)
        let p = TablePlan::new(&[10, 100, 1_000_000], 500, 1, 1, 16);
        assert!((p.compression_total() - 1_000_110.0 / 610.0).abs() < 1e-9);
        assert!((p.compression_largest() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn full_table_plan_is_identity_sized() {
        let p = TablePlan::new(&[10, 100], usize::MAX, 1, 1, 16);
        assert_eq!(p.total_rows, 110);
        assert_eq!(p.params(), 110 * 16);
        assert!((p.compression_total() - 1.0).abs() < 1e-12);
    }
}
