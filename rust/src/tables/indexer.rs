//! Per-method embedding-index generation — the L3 hot path.
//!
//! Every step, the coordinator turns a batch of raw categorical ids
//! `[B, F]` into whatever the lowered graph consumes:
//!   * row-wise methods → global row ids `i32[B, F, T, c]`
//!   * ROBE             → element ids `i32[B, F, d]`
//!   * DHE              → hash features `f32[B, F, n_hash]`
//!
//! For CCE this is where the system contribution lives: the `IndexMap`s of
//! term 0 get *replaced by learned cluster assignments* at every clustering
//! event while term 1 gets a fresh random hash (Algorithm 3 lines 14–16).

use crate::hashing::{DheHasher, IndexMap, RobeWindows};
use crate::tables::layout::{SubtableId, TablePlan};
use crate::util::Rng;

/// Which graph family the indexer feeds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MethodKind {
    RowWise,
    ElementWise,
    Dhe,
}

impl MethodKind {
    pub fn parse(s: &str) -> anyhow::Result<MethodKind> {
        Ok(match s {
            "rowwise" => MethodKind::RowWise,
            "elementwise" => MethodKind::ElementWise,
            "dhe" => MethodKind::Dhe,
            other => anyhow::bail!("unknown method kind {other:?}"),
        })
    }
}

/// Index state for one model. The maps are indexed `[feature][term][column]`.
#[derive(Clone)]
pub struct Indexer {
    pub kind: MethodKind,
    pub plan: TablePlan,
    /// row-wise: one map per (f, t, j)
    maps: Vec<IndexMap>,
    /// identity maps (full tables) bypass hashing entirely
    identity: Vec<bool>,
    /// elementwise (ROBE): windows + region base per feature
    robe: Vec<RobeWindows>,
    robe_base: Vec<usize>,
    dim: usize,
    /// DHE hashers per feature
    dhe: Vec<DheHasher>,
    pub n_hash: usize,
}

impl Indexer {
    /// Row-wise indexer with all-random maps (training start).
    ///
    /// Features whose vocab fits under the cap (`vocab <= cap`) get
    /// *identity* maps — a full table, exactly the paper's setup where only
    /// large tables are compressed.
    pub fn new_rowwise(rng: &mut Rng, plan: TablePlan) -> Indexer {
        let mut maps = Vec::new();
        let mut identity = Vec::new();
        for id in plan.subtables() {
            let k = plan.subtable_rows(id.feature) as u32;
            let ident = plan.vocabs[id.feature] <= plan.k[id.feature];
            identity.push(ident);
            maps.push(if ident {
                // placeholder; identity maps short-circuit in `map_row`
                IndexMap::Learned((0..k).collect())
            } else {
                IndexMap::random(&mut rng.fork(maps.len() as u64), k)
            });
        }
        Indexer {
            kind: MethodKind::RowWise,
            plan,
            maps,
            identity,
            robe: Vec::new(),
            robe_base: Vec::new(),
            dim: 0,
            dhe: Vec::new(),
            n_hash: 0,
        }
    }

    /// ROBE indexer: per-feature flat regions of `min(vocab, cap) * dim`
    /// elements, c windows of d/c elements each.
    pub fn new_robe(rng: &mut Rng, vocabs: &[usize], cap: usize, dim: usize, c: usize) -> Indexer {
        assert_eq!(dim % c, 0);
        let dc = dim / c;
        let mut robe = Vec::new();
        let mut robe_base = Vec::new();
        let mut acc = 0usize;
        for (f, &v) in vocabs.iter().enumerate() {
            let region = (v.min(cap) * dim) as u32;
            robe.push(RobeWindows::new(&mut rng.fork(f as u64), region, c as u32, dc as u32));
            robe_base.push(acc);
            acc += region as usize;
        }
        // plan is only used for vocab bookkeeping in the elementwise case
        let plan = TablePlan::new(vocabs, cap, 1, c, dc);
        Indexer {
            kind: MethodKind::ElementWise,
            plan,
            maps: Vec::new(),
            identity: Vec::new(),
            robe,
            robe_base,
            dim,
            dhe: Vec::new(),
            n_hash: 0,
        }
    }

    /// DHE indexer: per-feature hash-feature generators.
    pub fn new_dhe(rng: &mut Rng, vocabs: &[usize], n_hash: usize) -> Indexer {
        let dhe = (0..vocabs.len())
            .map(|f| DheHasher::new(&mut rng.fork(f as u64), n_hash))
            .collect();
        let plan = TablePlan::new(vocabs, 1, 1, 1, 1);
        Indexer {
            kind: MethodKind::Dhe,
            plan,
            maps: Vec::new(),
            identity: Vec::new(),
            robe: Vec::new(),
            robe_base: Vec::new(),
            dim: 0,
            dhe,
            n_hash,
        }
    }

    #[inline]
    fn map_index(&self, id: SubtableId) -> usize {
        (id.feature * self.plan.t + id.term) * self.plan.c + id.column
    }

    /// Local row for an id in one subtable.
    #[inline]
    pub fn local_row(&self, id: SubtableId, value: u32) -> u32 {
        let mi = self.map_index(id);
        if self.identity[mi] {
            value
        } else {
            self.maps[mi].map(value)
        }
    }

    /// Global pool row for an id in one subtable.
    #[inline]
    pub fn global_row(&self, id: SubtableId, value: u32) -> u32 {
        self.plan.global_row(id, self.local_row(id, value))
    }

    /// Fill row indices for a batch: `cats` is `[B, F]` raw values,
    /// `out` is `[B, F, T, c]` i32.
    pub fn fill_rowwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.plan.n_features();
        let (t_n, c_n) = (self.plan.t, self.plan.c);
        assert_eq!(cats.len(), batch * f_n);
        assert_eq!(out.len(), batch * f_n * t_n * c_n);
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f];
                debug_assert!((v as usize) < self.plan.vocabs[f], "value {v} out of vocab");
                for t in 0..t_n {
                    for j in 0..c_n {
                        let id = SubtableId { feature: f, term: t, column: j };
                        out[o] = self.global_row(id, v) as i32;
                        o += 1;
                    }
                }
            }
        }
    }

    /// Fill element indices for ROBE: `out` is `[B, F, d]` i32.
    pub fn fill_elementwise(&self, cats: &[u32], batch: usize, out: &mut [i32]) {
        let f_n = self.plan.n_features();
        assert_eq!(out.len(), batch * f_n * self.dim);
        let mut tmp = vec![0u32; self.dim];
        let mut o = 0usize;
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f];
                self.robe[f].fill(v, &mut tmp);
                let base = self.robe_base[f] as i32;
                for &e in &tmp {
                    out[o] = base + e as i32;
                    o += 1;
                }
            }
        }
    }

    /// Fill DHE hash features: `out` is `[B, F, n_hash]` f32.
    pub fn fill_dhe(&self, cats: &[u32], batch: usize, out: &mut [f32]) {
        let f_n = self.plan.n_features();
        assert_eq!(out.len(), batch * f_n * self.n_hash);
        for b in 0..batch {
            for f in 0..f_n {
                let v = cats[b * f_n + f];
                let off = (b * f_n + f) * self.n_hash;
                self.dhe[f].fill(v, &mut out[off..off + self.n_hash]);
            }
        }
    }

    /// ROBE total pool elements.
    pub fn robe_pool_elems(&self) -> usize {
        self.robe_base.last().map(|&b| b).unwrap_or(0)
            + self.robe.last().map(|w| w.region as usize).unwrap_or(0)
    }

    // -- CCE clustering hooks ------------------------------------------------

    /// Replace one subtable's map with learned assignments (Algorithm 3
    /// line 14). `assignments[v]` must be a local row `< k_f`.
    pub fn set_learned(&mut self, id: SubtableId, assignments: Vec<u32>) {
        assert_eq!(assignments.len(), self.plan.vocabs[id.feature]);
        let k = self.plan.subtable_rows(id.feature) as u32;
        assert!(assignments.iter().all(|&a| a < k), "assignment out of range");
        let mi = self.map_index(id);
        self.identity[mi] = false;
        self.maps[mi] = IndexMap::Learned(assignments);
    }

    /// Replace one subtable's map with a fresh random hash (line 16).
    pub fn set_random(&mut self, id: SubtableId, rng: &mut Rng) {
        let k = self.plan.subtable_rows(id.feature) as u32;
        let mi = self.map_index(id);
        self.identity[mi] = false;
        self.maps[mi] = IndexMap::random(rng, k);
    }

    /// Is this subtable's map an identity (full-table) map?
    pub fn is_identity(&self, id: SubtableId) -> bool {
        self.identity[self.map_index(id)]
    }

    pub fn is_learned(&self, id: SubtableId) -> bool {
        let mi = self.map_index(id);
        !self.identity[mi] && self.maps[mi].is_learned()
    }

    /// Materialized assignment table for entropy metrics (Appendix H).
    pub fn materialize(&self, id: SubtableId) -> Vec<u32> {
        let mi = self.map_index(id);
        self.maps[mi].materialize(self.plan.vocabs[id.feature])
    }

    // -- serving-snapshot materialization hooks ------------------------------

    /// Materialized *global* row table for one subtable: entry `v` is exactly
    /// what `global_row(id, v)` returns. `serving::snapshot` bakes these into
    /// flat gather arrays so the serve hot path never touches `IndexMap`,
    /// and `coordinator::cluster` builds its flat-gather materialization
    /// from the same tables.
    pub fn materialize_global(&self, id: SubtableId) -> Vec<u32> {
        let mut out = vec![0u32; self.plan.vocabs[id.feature]];
        self.materialize_global_into(id, &mut out);
        out
    }

    /// `materialize_global` into a caller-owned buffer (`out.len()` must be
    /// the feature's vocab). The map-kind dispatch happens ONCE out here
    /// instead of per lookup, so each arm is a branch-free fill — this is
    /// the clustering event's materialization hot path (§Perf log, opt
    /// L3-2), where the buffer is a per-thread arena reused across jobs.
    pub fn materialize_global_into(&self, id: SubtableId, out: &mut [u32]) {
        assert_eq!(out.len(), self.plan.vocabs[id.feature]);
        let base = self.plan.subtable_base(id) as u32;
        let mi = self.map_index(id);
        if self.identity[mi] {
            for (v, o) in out.iter_mut().enumerate() {
                *o = base + v as u32;
            }
            return;
        }
        match &self.maps[mi] {
            IndexMap::Learned(t) => {
                // a short map would silently leave stale arena data in the
                // tail where the old per-lookup path panicked — keep that
                // failure mode
                debug_assert_eq!(t.len(), out.len(), "learned map shorter than vocab");
                for (o, &local) in out.iter_mut().zip(t.iter()) {
                    *o = base + local;
                }
            }
            IndexMap::Hash(h) => {
                for (v, o) in out.iter_mut().enumerate() {
                    *o = base + h.hash(v as u32);
                }
            }
        }
    }

    /// ROBE window generator for one feature (elementwise indexers only).
    pub fn robe_windows(&self, feature: usize) -> &RobeWindows {
        &self.robe[feature]
    }

    /// Base element of one feature's ROBE region in the flat pool.
    pub fn robe_region_base(&self, feature: usize) -> usize {
        self.robe_base[feature]
    }

    /// Embedding dimension of an elementwise (ROBE) indexer.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Per-feature DHE hash-feature generators (DHE indexers only).
    pub fn dhe_hashers(&self) -> &[DheHasher] {
        &self.dhe
    }

    /// Host memory for all index maps (Appendix E accounting).
    pub fn host_bytes(&self) -> usize {
        self.maps
            .iter()
            .enumerate()
            .map(|(mi, m)| m.host_bytes(self.plan.vocabs[mi / (self.plan.t * self.plan.c)]))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan() -> TablePlan {
        TablePlan::new(&[5, 40], 8, 2, 2, 4)
    }

    #[test]
    fn small_vocab_gets_identity_map() {
        let mut rng = Rng::new(0);
        let ix = Indexer::new_rowwise(&mut rng, plan());
        let id = SubtableId { feature: 0, term: 0, column: 0 };
        assert!(ix.is_identity(id));
        for v in 0..5u32 {
            assert_eq!(ix.local_row(id, v), v);
        }
        let big = SubtableId { feature: 1, term: 0, column: 0 };
        assert!(!ix.is_identity(big));
    }

    #[test]
    fn fill_rowwise_produces_in_range_rows() {
        let mut rng = Rng::new(1);
        let ix = Indexer::new_rowwise(&mut rng, plan());
        let cats = [0u32, 10, 4, 39, 2, 0];
        let mut out = vec![0i32; 3 * 2 * 2 * 2];
        ix.fill_rowwise(&cats, 3, &mut out);
        let total = ix.plan.total_rows as i32;
        assert!(out.iter().all(|&r| (0..total).contains(&r)));
    }

    #[test]
    fn rowwise_rows_land_in_their_subtable() {
        let mut rng = Rng::new(2);
        let ix = Indexer::new_rowwise(&mut rng, plan());
        for f in 0..2 {
            for t in 0..2 {
                for j in 0..2 {
                    let id = SubtableId { feature: f, term: t, column: j };
                    let base = ix.plan.subtable_base(id);
                    let rows = ix.plan.subtable_rows(f);
                    for v in 0..ix.plan.vocabs[f] as u32 {
                        let g = ix.global_row(id, v) as usize;
                        assert!(g >= base && g < base + rows);
                    }
                }
            }
        }
    }

    #[test]
    fn learned_assignments_take_effect() {
        let mut rng = Rng::new(3);
        let mut ix = Indexer::new_rowwise(&mut rng, plan());
        let id = SubtableId { feature: 1, term: 0, column: 1 };
        let assignments: Vec<u32> = (0..40).map(|v| (v * 7 % 8) as u32).collect();
        ix.set_learned(id, assignments.clone());
        assert!(ix.is_learned(id));
        for v in 0..40u32 {
            assert_eq!(ix.local_row(id, v), assignments[v as usize]);
        }
        // other subtables unchanged semantics-wise
        let other = SubtableId { feature: 1, term: 1, column: 1 };
        assert!(!ix.is_learned(other));
    }

    #[test]
    #[should_panic(expected = "assignment out of range")]
    fn learned_assignments_validated() {
        let mut rng = Rng::new(4);
        let mut ix = Indexer::new_rowwise(&mut rng, plan());
        ix.set_learned(SubtableId { feature: 1, term: 0, column: 0 }, vec![99; 40]);
    }

    #[test]
    fn robe_elements_in_pool() {
        let mut rng = Rng::new(5);
        let ix = Indexer::new_robe(&mut rng, &[30, 100], 50, 8, 2);
        let total = ix.robe_pool_elems() as i32;
        assert_eq!(total, (30 * 8 + 50 * 8) as i32);
        let cats = [3u32, 77, 29, 0];
        let mut out = vec![0i32; 2 * 2 * 8];
        ix.fill_elementwise(&cats, 2, &mut out);
        assert!(out.iter().all(|&e| (0..total).contains(&e)));
        // feature 1 elements land in feature 1's region
        assert!(out[8..16].iter().all(|&e| e >= 30 * 8));
    }

    #[test]
    fn dhe_features_filled() {
        let mut rng = Rng::new(6);
        let ix = Indexer::new_dhe(&mut rng, &[10, 10], 8);
        let cats = [1u32, 2, 3, 4];
        let mut out = vec![0f32; 2 * 2 * 8];
        ix.fill_dhe(&cats, 2, &mut out);
        assert!(out.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        assert!(out.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn materialize_global_matches_global_row() {
        let mut rng = Rng::new(8);
        let mut ix = Indexer::new_rowwise(&mut rng, plan());
        ix.set_learned(
            SubtableId { feature: 1, term: 1, column: 0 },
            (0..40).map(|v| (v * 3 % 8) as u32).collect(),
        );
        for id in ix.plan.subtables() {
            let table = ix.materialize_global(id);
            assert_eq!(table.len(), ix.plan.vocabs[id.feature]);
            for (v, &g) in table.iter().enumerate() {
                assert_eq!(g, ix.global_row(id, v as u32), "{id:?} v={v}");
            }
        }
    }

    #[test]
    fn host_bytes_grows_with_learning() {
        let mut rng = Rng::new(7);
        let mut ix = Indexer::new_rowwise(&mut rng, plan());
        let before = ix.host_bytes();
        ix.set_learned(SubtableId { feature: 1, term: 0, column: 0 }, vec![0; 40]);
        assert!(ix.host_bytes() > before);
    }
}
