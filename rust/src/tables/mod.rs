//! Embedding-table state management: pool layout, per-method indexers,
//! state initialization, and parameter accounting.

pub mod indexer;
pub mod init;
pub mod layout;

pub use indexer::{Indexer, MethodKind};
pub use layout::{SubtableId, TablePlan};
