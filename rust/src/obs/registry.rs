//! Process-global metrics registry: named counters, gauges, and fixed
//! log-linear-bucket histograms (docs/OBSERVABILITY.md).
//!
//! Recording is lock-free after the first resolution of a handle: every
//! metric owns `N_SHARDS` cache-line-separated atomic cells and a thread
//! records into the shard picked by its process-unique thread index
//! (round-robin at first use), so concurrent recorders on different
//! threads rarely contend on a cell. A scrape merges the shards into one
//! deterministic snapshot — metrics iterate in name order (`BTreeMap`)
//! and shard sums are plain integer additions, so two scrapes of a quiet
//! process render byte-identical text.
//!
//! Counters and gauges are always on (they are the source of truth the
//! serving/train reports cross-check against). Histograms and spans are
//! gated by [`enabled`] so `perf_hot_paths --smoke`'s `obs_overhead`
//! group can measure the instrumented-vs-disabled cost honestly.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Shard count per metric. A power of two so the thread-index mask is a
/// single AND; 16 covers every worker count the engine benches use.
pub const N_SHARDS: usize = 16;

/// Log-linear histogram layout: 2^SUB_BITS linear sub-buckets per
/// power-of-two octave. With SUB_BITS=2 the relative bucket width is
/// ≤25% everywhere — enough resolution for p50/p95/p99 over latencies.
const SUB_BITS: u32 = 2;
const SUB: usize = 1 << SUB_BITS;
/// 4 exact buckets for 0..4, then 4 sub-buckets for each of the 62
/// remaining octaves of a u64.
pub const N_BUCKETS: usize = SUB + (64 - SUB_BITS as usize) * SUB;

/// Bucket index for a recorded value; monotone in `v` (proptested).
pub fn bucket_index(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros();
    let octave = (msb - SUB_BITS) as usize;
    let sub = ((v >> (msb - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    SUB + octave * SUB + sub
}

/// Inclusive lower bound of bucket `i` (the exporter's `le` boundaries
/// are `bucket_lower(i + 1) - 1`, i.e. the largest value mapping to `i`).
pub fn bucket_lower(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let octave = (i - SUB) / SUB;
    let sub = ((i - SUB) % SUB) as u64;
    (SUB as u64 + sub) << octave
}

/// Pad each shard's cells to a cache line so two threads recording into
/// neighbouring shards do not false-share.
#[repr(align(64))]
struct ShardCell {
    v: AtomicU64,
}

impl ShardCell {
    fn new() -> ShardCell {
        ShardCell { v: AtomicU64::new(0) }
    }
}

fn shard_cells() -> Vec<ShardCell> {
    (0..N_SHARDS).map(|_| ShardCell::new()).collect()
}

/// Global recording switch for the *timed* instrumentation (spans,
/// histograms, trace ring). Counters and gauges ignore it.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    // ORDERING: Relaxed — the flag only modulates whether future samples
    // are recorded; no data is published through it, and a racing
    // recorder seeing the stale value records (or skips) one extra
    // sample, which is statistically irrelevant.
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    // ORDERING: Relaxed — see set_enabled; a one-sample-stale read is fine.
    ENABLED.load(Ordering::Relaxed)
}

/// Process-unique shard index for the calling thread: handed out
/// round-robin at first use so up to N_SHARDS concurrent recorders land
/// on distinct cells.
fn thread_shard() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SHARD: usize =
            // ORDERING: Relaxed — the counter only needs each thread to
            // draw a distinct ticket; no other memory is published with it.
            NEXT.fetch_add(1, Ordering::Relaxed) & (N_SHARDS - 1);
    }
    SHARD.with(|s| *s)
}

/// Monotone counter: per-shard atomic adds, merged by summing on scrape.
pub struct CounterInner {
    shards: Vec<ShardCell>,
}

#[derive(Clone)]
pub struct Counter(Arc<CounterInner>);

impl Counter {
    pub fn add(&self, n: u64) {
        // ORDERING: Relaxed — counters are statistical accumulators; the
        // scrape tolerates seeing an increment late, and every reader
        // that needs exactness (the conservation cross-check) reads
        // after the recording threads have been joined, so the join's
        // happens-before edge publishes the final values.
        self.0.shards[thread_shard()].v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    /// Sum of all shards at this instant.
    pub fn value(&self) -> u64 {
        self.0
            .shards
            .iter()
            // ORDERING: Relaxed — see add; per-shard sums are independent
            // monotone values, no inter-cell ordering is needed.
            .map(|c| c.v.load(Ordering::Relaxed))
            .sum()
    }
}

/// Last-write-wins gauge (queue depth, current generation): a single
/// atomic cell — sharding a set-semantics value would need timestamps.
pub struct GaugeInner {
    v: AtomicU64,
}

#[derive(Clone)]
pub struct Gauge(Arc<GaugeInner>);

impl Gauge {
    pub fn set(&self, v: u64) {
        // ORDERING: Relaxed — a gauge is a point-in-time sample; readers
        // only need *some* recent value, not an ordering with other memory.
        self.0.v.store(v, Ordering::Relaxed);
    }

    pub fn value(&self) -> u64 {
        // ORDERING: Relaxed — see set.
        self.0.v.load(Ordering::Relaxed)
    }
}

/// Log-linear histogram: per-shard bucket counts plus per-shard
/// count/sum cells, merged by addition on scrape.
pub struct HistogramInner {
    /// `buckets[shard * N_BUCKETS + bucket]`
    buckets: Vec<ShardCell>,
    count: Vec<ShardCell>,
    sum: Vec<ShardCell>,
}

#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    /// Record one sample (no-op while `obs` is disabled).
    pub fn record(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.record_always(v);
    }

    /// Record regardless of the enabled switch (tests, merge proptests).
    pub fn record_always(&self, v: u64) {
        self.record_in_shard(thread_shard(), v);
    }

    /// Record into an explicit shard — exercised by the shard-merge
    /// property test; production recording always goes through
    /// `thread_shard()`.
    pub fn record_in_shard(&self, shard: usize, v: u64) {
        let b = bucket_index(v);
        let h = &self.0;
        // ORDERING: Relaxed (all three) — histogram cells are independent
        // statistical accumulators like Counter::add: a scrape may see a
        // sample's bucket increment before its count/sum increments (or
        // vice versa), which skews one in-flight sample at most; exact
        // readers only run after joining the recording threads.
        h.buckets[shard * N_BUCKETS + b].v.fetch_add(1, Ordering::Relaxed);
        h.count[shard].v.fetch_add(1, Ordering::Relaxed);
        h.sum[shard].v.fetch_add(v, Ordering::Relaxed);
    }

    /// Merge all shards into one snapshot.
    pub fn snapshot(&self) -> HistSnapshot {
        let h = &self.0;
        let mut buckets = vec![0u64; N_BUCKETS];
        for s in 0..N_SHARDS {
            for (b, out) in buckets.iter_mut().enumerate() {
                // ORDERING: Relaxed — see record_in_shard.
                *out += h.buckets[s * N_BUCKETS + b].v.load(Ordering::Relaxed);
            }
        }
        // ORDERING: Relaxed — see record_in_shard.
        let count = h.count.iter().map(|c| c.v.load(Ordering::Relaxed)).sum();
        // ORDERING: Relaxed — see record_in_shard.
        let sum = h.sum.iter().map(|c| c.v.load(Ordering::Relaxed)).sum();
        HistSnapshot { buckets, count, sum }
    }
}

/// A merged histogram view: deterministic given the underlying cells.
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    /// Bucket-resolution quantile: lower bound of the first bucket whose
    /// cumulative count reaches `q * count`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_lower(i);
            }
        }
        bucket_lower(N_BUCKETS - 1)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// One scraped metric; `Registry::scrape` returns them in name order.
pub enum MetricValue {
    Counter(u64),
    Gauge(u64),
    Histogram(HistSnapshot),
}

pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    fn new() -> Registry {
        Registry { metrics: Mutex::new(BTreeMap::new()) }
    }

    /// Resolve (or create) the counter `name`. Resolution takes the
    /// registry lock — hot paths resolve once via `obs_counter!` and
    /// record through the returned handle lock-free.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter(Arc::new(CounterInner { shards: shard_cells() }))))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name:?} already registered with another kind"),
        }
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge(Arc::new(GaugeInner { v: AtomicU64::new(0) }))))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name:?} already registered with another kind"),
        }
    }

    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| {
            Metric::Histogram(Histogram(Arc::new(HistogramInner {
                buckets: (0..N_SHARDS * N_BUCKETS).map(|_| ShardCell::new()).collect(),
                count: shard_cells(),
                sum: shard_cells(),
            })))
        }) {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name:?} already registered with another kind"),
        }
    }

    /// Deterministic merged snapshot of every registered metric, in name
    /// order. Holding the lock only guards the map structure — cell reads
    /// are the usual Relaxed shard merges.
    pub fn scrape(&self) -> Vec<(String, MetricValue)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .map(|(name, metric)| {
                let v = match metric {
                    Metric::Counter(c) => MetricValue::Counter(c.value()),
                    Metric::Gauge(g) => MetricValue::Gauge(g.value()),
                    Metric::Histogram(h) => MetricValue::Histogram(h.snapshot()),
                };
                (name.clone(), v)
            })
            .collect()
    }

    /// Counter values only, for delta-based cross-checks.
    pub fn counter_values(&self) -> BTreeMap<String, u64> {
        self.scrape()
            .into_iter()
            .filter_map(|(n, v)| match v {
                MetricValue::Counter(c) => Some((n, c)),
                _ => None,
            })
            .collect()
    }
}

/// The process-global registry every `obs_counter!`/`span!` site records
/// into and every exporter scrapes.
pub fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

/// Resolve a counter once per call site and cache the handle in a
/// function-local static: recording is then a single sharded fetch_add.
#[macro_export]
macro_rules! obs_counter {
    ($name:expr) => {{
        static H: std::sync::OnceLock<$crate::obs::Counter> = std::sync::OnceLock::new();
        H.get_or_init(|| $crate::obs::registry().counter($name))
    }};
}

/// Call-site-cached gauge handle (see `obs_counter!`).
#[macro_export]
macro_rules! obs_gauge {
    ($name:expr) => {{
        static H: std::sync::OnceLock<$crate::obs::Gauge> = std::sync::OnceLock::new();
        H.get_or_init(|| $crate::obs::registry().gauge($name))
    }};
}

/// Call-site-cached histogram handle (see `obs_counter!`).
#[macro_export]
macro_rules! obs_hist {
    ($name:expr) => {{
        static H: std::sync::OnceLock<$crate::obs::Histogram> = std::sync::OnceLock::new();
        H.get_or_init(|| $crate::obs::registry().histogram($name))
    }};
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bucket_index_is_monotone_and_lower_bounds_agree() {
        // proptest over random pairs: v <= w implies bucket(v) <= bucket(w),
        // and every value lands in the bucket whose lower bound brackets it.
        let mut rng = Rng::new(7);
        let mut vals: Vec<u64> = (0..4000)
            .map(|i| {
                let shift = (rng.next_u64() % 64) as u32;
                (rng.next_u64() >> shift).wrapping_add(i % 3)
            })
            .collect();
        vals.extend([0, 1, 2, 3, 4, 5, 7, 8, u64::MAX - 1, u64::MAX]);
        vals.sort_unstable();
        let mut prev = 0usize;
        for &v in &vals {
            let b = bucket_index(v);
            assert!(b >= prev, "bucket order inverted at {v}: {b} < {prev}");
            assert!(b < N_BUCKETS, "bucket {b} out of range for {v}");
            assert!(bucket_lower(b) <= v, "lower bound {} > value {v}", bucket_lower(b));
            if b + 1 < N_BUCKETS {
                assert!(v < bucket_lower(b + 1), "value {v} at or past next bucket {}", bucket_lower(b + 1));
            }
            prev = b;
        }
        // boundaries map to themselves: bucket_lower(bucket_index(lo)) == lo
        for i in 0..N_BUCKETS {
            let lo = bucket_lower(i);
            assert_eq!(bucket_index(lo), i, "boundary {lo} not in its own bucket");
        }
    }

    #[test]
    fn shard_merge_equals_single_shard_recording() {
        // The same sample multiset recorded round-robin across all shards
        // and recorded into shard 0 alone must merge to identical snapshots.
        let mut rng = Rng::new(11);
        let samples: Vec<u64> = (0..2000).map(|_| rng.next_u64() >> (rng.next_u64() % 60)).collect();
        let sharded = registry().histogram("test.merge.sharded");
        let single = registry().histogram("test.merge.single");
        for (i, &v) in samples.iter().enumerate() {
            sharded.record_in_shard(i % N_SHARDS, v);
            single.record_in_shard(0, v);
        }
        assert_eq!(sharded.snapshot(), single.snapshot());
    }

    #[test]
    fn quantiles_track_bucket_resolution() {
        let h = registry().histogram("test.quantile");
        for v in 1..=1000u64 {
            h.record_always(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        let p50 = s.quantile(0.5);
        // bucket resolution is <=25%: the reported p50 must be the lower
        // bound of the bucket containing 500
        assert_eq!(p50, bucket_lower(bucket_index(500)));
        assert!(s.quantile(0.99) >= p50);
        assert!(s.mean() > 0.0);
    }

    #[test]
    fn counters_and_gauges_roundtrip() {
        let c = registry().counter("test.counter");
        c.add(3);
        c.inc();
        assert!(c.value() >= 4, "counter lost increments");
        let g = registry().gauge("test.gauge");
        g.set(17);
        assert_eq!(g.value(), 17);
        // same-name resolution returns a handle over the same cells
        let c2 = registry().counter("test.counter");
        let before = c2.value();
        c.inc();
        assert_eq!(c2.value(), before + 1);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn kind_mismatch_panics() {
        registry().counter("test.kind.clash");
        registry().gauge("test.kind.clash");
    }
}
