//! Registry exporters: Prometheus text over a hand-rolled
//! `std::net::TcpListener` HTTP endpoint, and a periodic JSONL stats
//! emitter (one registry snapshot per line).
//!
//! Metric names are dotted (`serve.requests.offered`); the Prometheus
//! renderer maps them to `cce_serve_requests_offered` (dots → `_`,
//! `cce_` prefix). Histograms render cumulative `_bucket{le="..."}`
//! lines for non-empty buckets plus `+Inf`, `_sum`, `_count` — the
//! standard text exposition, hand-rolled because no HTTP/metrics crates
//! exist offline (docs/OBSERVABILITY.md).

use crate::obs::registry::{bucket_lower, registry, HistSnapshot, MetricValue, N_BUCKETS};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 4);
    out.push_str("cce_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn render_hist(out: &mut String, pn: &str, h: &HistSnapshot) {
    out.push_str(&format!("# TYPE {pn} histogram\n"));
    let mut cum = 0u64;
    for (i, &c) in h.buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        // upper bound of bucket i = last value that maps into it
        let le = if i + 1 < N_BUCKETS { (bucket_lower(i + 1) - 1).to_string() } else { "+Inf".to_string() };
        out.push_str(&format!("{pn}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{pn}_bucket{{le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!("{pn}_sum {}\n", h.sum));
    out.push_str(&format!("{pn}_count {}\n", h.count));
}

/// Render the whole registry in Prometheus text exposition format;
/// deterministic (name-ordered) for a given set of cell values.
pub fn render_prometheus() -> String {
    let mut out = String::new();
    for (name, v) in registry().scrape() {
        let pn = prom_name(&name);
        match v {
            MetricValue::Counter(c) => {
                out.push_str(&format!("# TYPE {pn} counter\n{pn} {c}\n"));
            }
            MetricValue::Gauge(g) => {
                out.push_str(&format!("# TYPE {pn} gauge\n{pn} {g}\n"));
            }
            MetricValue::Histogram(h) => render_hist(&mut out, &pn, &h),
        }
    }
    out
}

/// One registry snapshot as a flat JSON object: counters and gauges by
/// dotted name; histograms contribute `<name>.count`, `<name>.sum`, and
/// bucket-resolution `<name>.p50` / `<name>.p99`.
pub fn stats_snapshot(t_ms: u64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("t_ms".to_string(), Json::Num(t_ms as f64));
    for (name, v) in registry().scrape() {
        match v {
            MetricValue::Counter(c) => {
                m.insert(name, Json::Num(c as f64));
            }
            MetricValue::Gauge(g) => {
                m.insert(name, Json::Num(g as f64));
            }
            MetricValue::Histogram(h) => {
                m.insert(format!("{name}.count"), Json::Num(h.count as f64));
                m.insert(format!("{name}.sum"), Json::Num(h.sum as f64));
                m.insert(format!("{name}.p50"), Json::Num(h.quantile(0.5) as f64));
                m.insert(format!("{name}.p99"), Json::Num(h.quantile(0.99) as f64));
            }
        }
    }
    Json::Obj(m)
}

/// Minimal HTTP/1.1 server for `GET /metrics`. One accept loop thread,
/// one short-lived response per connection — a scrape endpoint, not a
/// web server.
pub struct MetricsServer {
    pub addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

fn respond(mut stream: TcpStream) {
    stream.set_read_timeout(Some(Duration::from_millis(500))).ok();
    let mut buf = [0u8; 1024];
    let n = stream.read(&mut buf).unwrap_or(0);
    let req = String::from_utf8_lossy(&buf[..n]);
    let line = req.lines().next().unwrap_or("");
    let ok = line.starts_with("GET /metrics") || line.starts_with("GET / ");
    let (status, body) = if ok {
        ("200 OK", render_prometheus())
    } else {
        ("404 Not Found", "only GET /metrics is served\n".to_string())
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes()).ok();
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks a free port — the bound address is in
    /// `self.addr`) and serve scrapes until `stop()`.
    pub fn start(addr: &str) -> Result<MetricsServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding metrics endpoint {addr}"))?;
        let bound = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("cce-metrics".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    // ORDERING: Relaxed — the flag is a plain shutdown
                    // signal; stop() wakes the accept loop with its own
                    // connection after setting it, so the loop always
                    // observes the store on that wake-up pass.
                    if stop2.load(Ordering::Relaxed) {
                        break;
                    }
                    if let Ok(stream) = conn {
                        respond(stream);
                    }
                }
            })?;
        log::info!("metrics endpoint listening on http://{bound}/metrics");
        Ok(MetricsServer { addr: bound, stop, handle: Some(handle) })
    }

    /// Signal the accept loop and join it.
    pub fn stop(mut self) {
        // ORDERING: Relaxed — see the accept loop; the wake-up connection
        // below is what guarantees the loop re-checks the flag.
        self.stop.store(true, Ordering::Relaxed);
        // unblock the accept() by connecting once
        TcpStream::connect(self.addr).ok();
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

/// Periodic JSONL stats emitter: one `stats_snapshot` line per interval,
/// plus a final line on stop so short runs still produce output.
pub struct StatsEmitter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl StatsEmitter {
    pub fn start(path: PathBuf, interval: Duration) -> Result<StatsEmitter> {
        let mut file = std::fs::File::create(&path)
            .with_context(|| format!("creating stats stream {}", path.display()))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let t0 = Instant::now();
        let handle = std::thread::Builder::new()
            .name("cce-stats".to_string())
            .spawn(move || {
                let tick = Duration::from_millis(20).min(interval);
                let mut next = t0 + interval;
                loop {
                    // ORDERING: Relaxed — plain shutdown flag; the final
                    // snapshot below is written after the load observes
                    // it, and the writer thread is joined before the
                    // caller reads the file.
                    let stopping = stop2.load(Ordering::Relaxed);
                    if !stopping && Instant::now() < next {
                        std::thread::sleep(tick);
                        continue;
                    }
                    let line = stats_snapshot(t0.elapsed().as_millis() as u64).to_string();
                    if let Err(e) = writeln!(file, "{line}") {
                        log::warn!("stats emitter: write failed: {e}");
                        return;
                    }
                    if stopping {
                        return;
                    }
                    next += interval;
                }
            })?;
        log::info!("stats emitter writing to {} every {} ms", path.display(), interval.as_millis());
        Ok(StatsEmitter { stop, handle: Some(handle) })
    }

    /// Flush a final snapshot and join the emitter thread.
    pub fn stop(mut self) {
        // ORDERING: Relaxed — see the emitter loop.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    #[test]
    fn prometheus_rendering_is_wellformed() {
        let c = registry().counter("test.prom.counter");
        c.add(5);
        registry().gauge("test.prom.gauge").set(9);
        let h = registry().histogram("test.prom.hist");
        h.record_always(100);
        h.record_always(1_000_000);
        let text = render_prometheus();
        assert!(text.contains("# TYPE cce_test_prom_counter counter"));
        assert!(text.contains("cce_test_prom_gauge 9"));
        assert!(text.contains("cce_test_prom_hist_count"));
        assert!(text.contains("cce_test_prom_hist_bucket{le=\"+Inf\"}"));
        // every non-comment line is `name[{labels}] integer`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, val) = line.rsplit_once(' ').expect("metric line without value");
            val.parse::<u64>().unwrap_or_else(|_| panic!("non-integer value in {line:?}"));
        }
    }

    #[test]
    fn metrics_server_serves_scrapes_on_an_ephemeral_port() {
        registry().counter("test.http.counter").add(3);
        let srv = MetricsServer::start("127.0.0.1:0").unwrap();
        let mut stream = TcpStream::connect(srv.addr).unwrap();
        stream.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        stream.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200"), "bad response: {resp:.60}");
        assert!(resp.contains("cce_test_http_counter"), "scrape missing counter");

        let mut bad = TcpStream::connect(srv.addr).unwrap();
        bad.write_all(b"GET /nope HTTP/1.1\r\n\r\n").unwrap();
        let mut resp404 = String::new();
        bad.read_to_string(&mut resp404).unwrap();
        assert!(resp404.starts_with("HTTP/1.1 404"));
        srv.stop();
    }

    #[test]
    fn stats_emitter_writes_parseable_jsonl() {
        registry().counter("test.stats.counter").add(2);
        let dir = TempDir::new("obs_stats");
        let path = dir.path().join("stats.jsonl");
        let em = StatsEmitter::start(path.clone(), Duration::from_millis(10)).unwrap();
        std::thread::sleep(Duration::from_millis(35));
        em.stop();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(!lines.is_empty(), "emitter wrote no snapshots");
        for line in &lines {
            let j = Json::parse(line).expect("stats line is not valid JSON");
            assert!(j.f64_field("t_ms").is_ok(), "line without t_ms: {line}");
            assert!(j.get("test.stats.counter").is_some(), "counter missing from snapshot");
        }
    }
}
