//! RAII tracing spans: `span!("cluster.compute")` returns a guard whose
//! drop records the elapsed nanoseconds into the histogram
//! `span.cluster.compute.ns` and, when a trace ring is enabled
//! (`--trace-out`), appends a Chrome-trace complete event with any
//! attributes attached via [`SpanGuard::attr`].
//!
//! Spans are gated by `obs::enabled()`: a disabled span takes no
//! timestamps and records nothing, which is what the `obs_overhead`
//! bench group toggles to price the instrumentation.

use crate::obs::registry::{enabled, Histogram};
use crate::obs::trace;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Histogram handles per span name, resolved once. Span names are
/// `&'static str` from the `span!` macro, so the cache is bounded by the
/// number of instrumented call sites.
fn span_hist(name: &'static str) -> Histogram {
    static CACHE: OnceLock<Mutex<Vec<(&'static str, Histogram)>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(Vec::new()));
    let mut c = cache.lock().unwrap();
    if let Some((_, h)) = c.iter().find(|(n, _)| *n == name) {
        return h.clone();
    }
    let h = crate::obs::registry().histogram(&format!("span.{name}.ns"));
    c.push((name, h.clone()));
    h
}

/// Live span: times the enclosing scope. Attributes land in the trace
/// event's `args` (the per-event staleness/byte counters ride here).
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
    attrs: Vec<(&'static str, u64)>,
}

impl SpanGuard {
    pub fn enter(name: &'static str) -> SpanGuard {
        let start = if enabled() { Some(Instant::now()) } else { None };
        SpanGuard { name, start, attrs: Vec::new() }
    }

    /// Attach a numeric attribute to the trace event (no-op when the
    /// span is disabled).
    pub fn attr(&mut self, key: &'static str, value: u64) {
        if self.start.is_some() {
            self.attrs.push((key, value));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur = start.elapsed();
        span_hist(self.name).record(dur.as_nanos() as u64);
        if trace::trace_on() {
            trace::record(self.name, start, dur, std::mem::take(&mut self.attrs));
        }
    }
}

/// Open a timed span for the current scope:
/// `let _sp = span!("train.step");` or bind mutably to attach attributes.
#[macro_export]
macro_rules! span {
    ($name:literal) => {
        $crate::obs::SpanGuard::enter($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::registry;
    use std::sync::Mutex;

    /// Serializes the tests that flip the global enabled switch so they
    /// cannot race each other's recordings.
    static ENABLE_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn span_records_into_its_histogram() {
        let _g = ENABLE_LOCK.lock().unwrap();
        registry::set_enabled(true);
        {
            let mut sp = SpanGuard::enter("test.span");
            sp.attr("k", 3);
        }
        let h = crate::obs::registry().histogram("span.test.span.ns");
        assert!(h.snapshot().count >= 1, "span drop did not record a sample");
    }

    #[test]
    fn disabled_span_records_nothing() {
        let _g = ENABLE_LOCK.lock().unwrap();
        registry::set_enabled(false);
        drop(SpanGuard::enter("test.span.disabled"));
        registry::set_enabled(true);
        let h = crate::obs::registry().histogram("span.test.span.disabled.ns");
        assert_eq!(h.snapshot().count, 0, "disabled span recorded a sample");
    }
}
