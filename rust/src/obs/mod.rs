//! Live telemetry: metrics registry, tracing spans, and exporters.
//!
//! See docs/OBSERVABILITY.md for the metric naming scheme, the span
//! taxonomy, endpoint formats, and the overhead budget (the
//! `obs_overhead` group in `benches/perf_hot_paths.rs` gates the
//! instrumented-vs-disabled engine throughput at ≤3%).
//!
//! Layering:
//! * [`registry`] — process-global named counters / gauges /
//!   log-linear histograms, recorded through per-thread atomic shards
//!   and merged deterministically on scrape.
//! * [`span`] — `span!("name")` RAII guards feeding `span.<name>.ns`
//!   histograms and, when enabled, the Chrome-trace ring in [`trace`].
//! * [`exporter`] — `GET /metrics` Prometheus text endpoint
//!   (`--metrics-addr`) and the periodic JSONL stats stream
//!   (`--stats-out`).
//!
//! Counters and gauges are always on; spans / histograms / the trace
//! ring honor [`set_enabled`] so their cost can be switched off and
//! measured.

pub mod exporter;
pub mod registry;
pub mod span;
pub mod trace;

pub use exporter::{render_prometheus, stats_snapshot, MetricsServer, StatsEmitter};
pub use registry::{
    enabled, registry, set_enabled, Counter, Gauge, HistSnapshot, Histogram, Registry,
};
pub use span::SpanGuard;
