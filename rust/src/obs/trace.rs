//! Bounded in-memory trace ring dumped as Chrome trace JSON.
//!
//! When `--trace-out` enables the ring, every finished span appends one
//! complete event (`ph: "X"`) with microsecond timestamps relative to a
//! process epoch; [`dump`] writes the Perfetto-loadable
//! `{"traceEvents": [...]}` document. The ring is bounded — once full it
//! drops the OLDEST events (the tail of a run is what a stall
//! investigation needs) and counts the drops so the dump can say so.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Default ring capacity: ~64k events ≈ a few MB, hours of span traffic
/// at serve rates once batching amortizes spans per batch.
pub const DEFAULT_RING_CAP: usize = 65_536;

struct TraceEvent {
    name: &'static str,
    tid: usize,
    ts_us: u64,
    dur_us: u64,
    args: Vec<(&'static str, u64)>,
}

struct TraceState {
    cap: usize,
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

static RING: OnceLock<Mutex<TraceState>> = OnceLock::new();
/// Fast-path switch so a disabled process never touches the ring mutex.
static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// Monotonic process epoch all trace timestamps are relative to.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Small dense thread id for the `tid` lane (thread::current().id() is
/// opaque); assigned at a thread's first trace event.
fn trace_tid() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(1);
    thread_local! {
        // ORDERING: Relaxed — tickets only need to be distinct, nothing
        // else is published through the counter.
        static TID: usize = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

pub fn trace_on() -> bool {
    // ORDERING: Relaxed — the flag gates whether future events are
    // appended; a recorder seeing it one event late merely records or
    // skips one span, and dump() reads the ring under its mutex anyway.
    TRACE_ON.load(Ordering::Relaxed)
}

/// Enable the ring (idempotent; the first call pins the capacity and the
/// process epoch so early spans get small timestamps).
pub fn enable(cap: usize) {
    epoch();
    RING.get_or_init(|| {
        Mutex::new(TraceState { cap: cap.max(16), events: VecDeque::new(), dropped: 0 })
    });
    // ORDERING: Relaxed — see trace_on.
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// Append one complete event (called from `SpanGuard::drop`).
pub fn record(name: &'static str, start: Instant, dur: Duration, args: Vec<(&'static str, u64)>) {
    let Some(ring) = RING.get() else { return };
    let ts_us = start.saturating_duration_since(epoch()).as_micros() as u64;
    let ev = TraceEvent {
        name,
        tid: trace_tid(),
        ts_us,
        dur_us: dur.as_micros() as u64,
        args,
    };
    let mut st = ring.lock().unwrap();
    if st.events.len() >= st.cap {
        st.events.pop_front();
        st.dropped += 1;
    }
    st.events.push_back(ev);
}

/// Render the ring as a Chrome trace document and write it to `path`.
/// Events are sorted by timestamp (Perfetto accepts any order; sorted
/// output makes the file diffable).
pub fn dump(path: &Path) -> Result<usize> {
    let Some(ring) = RING.get() else {
        anyhow::bail!("trace ring was never enabled (--trace-out without obs::trace::enable)");
    };
    let (mut events, dropped) = {
        let st = ring.lock().unwrap();
        let evs: Vec<Json> = st
            .events
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::from(e.name));
                m.insert("ph".to_string(), Json::from("X"));
                m.insert("ts".to_string(), Json::Num(e.ts_us as f64));
                m.insert("dur".to_string(), Json::Num(e.dur_us.max(1) as f64));
                m.insert("pid".to_string(), Json::Num(1.0));
                m.insert("tid".to_string(), Json::Num(e.tid as f64));
                let mut args = BTreeMap::new();
                for (k, v) in &e.args {
                    args.insert(k.to_string(), Json::Num(*v as f64));
                }
                m.insert("args".to_string(), Json::Obj(args));
                (e.ts_us, Json::Obj(m))
            })
            .map(|(_, j)| j)
            .collect();
        (evs, st.dropped)
    };
    events.sort_by(|a, b| {
        let ts = |j: &Json| j.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        ts(a).total_cmp(&ts(b))
    });
    let n = events.len();
    let mut doc = BTreeMap::new();
    doc.insert("traceEvents".to_string(), Json::Arr(events));
    doc.insert("displayTimeUnit".to_string(), Json::from("ms"));
    if dropped > 0 {
        let mut meta = BTreeMap::new();
        meta.insert("dropped_events".to_string(), Json::Num(dropped as f64));
        doc.insert("otherData".to_string(), Json::Obj(meta));
    }
    std::fs::write(path, Json::Obj(doc).to_string())
        .with_context(|| format!("writing trace to {}", path.display()))?;
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    #[test]
    fn ring_records_and_dumps_chrome_trace() {
        enable(64);
        record("test.trace.a", Instant::now(), Duration::from_micros(5), vec![("bytes", 7)]);
        record("test.trace.b", Instant::now(), Duration::from_micros(3), Vec::new());
        let dir = TempDir::new("obs_trace");
        let path = dir.path().join("trace.json");
        let n = dump(&path).unwrap();
        assert!(n >= 2);
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.len() >= 2);
        let ours: Vec<&Json> = evs
            .iter()
            .filter(|e| e.str_field("name").unwrap().starts_with("test.trace."))
            .collect();
        assert!(ours.len() >= 2, "recorded events missing from the dump");
        for e in &ours {
            assert_eq!(e.str_field("ph").unwrap(), "X");
            assert!(e.f64_field("ts").is_ok() && e.f64_field("dur").is_ok());
        }
        let a = ours.iter().find(|e| e.str_field("name").unwrap() == "test.trace.a").unwrap();
        assert_eq!(a.get("args").unwrap().usize_field("bytes").unwrap(), 7);
    }

    #[test]
    fn ring_is_bounded_and_drops_oldest() {
        enable(64); // idempotent: first enable in the process pins the cap
        let ring = RING.get().unwrap();
        let cap = ring.lock().unwrap().cap;
        for _ in 0..cap + 10 {
            record("test.trace.fill", Instant::now(), Duration::from_micros(1), Vec::new());
        }
        let st = ring.lock().unwrap();
        assert!(st.events.len() <= cap, "ring exceeded its capacity");
        assert!(st.dropped >= 10, "overflow did not count drops");
    }
}
