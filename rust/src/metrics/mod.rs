//! Evaluation metrics: BCE, AUC, assignment-entropy (table-collapse
//! detection, Appendix H), and the extrapolation used for Table 1's
//! compression-range estimates.

pub mod entropy;
pub mod extrapolate;

/// Mean binary cross-entropy from probabilities (clamped for stability).
pub fn bce(probs: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    assert!(!probs.is_empty());
    let mut acc = 0f64;
    for (&p, &y) in probs.iter().zip(labels) {
        let p = (p as f64).clamp(1e-7, 1.0 - 1e-7);
        acc -= if y > 0.5 { p.ln() } else { (1.0 - p).ln() };
    }
    acc / probs.len() as f64
}

/// Streaming BCE/AUC accumulator, fed batch by batch during eval.
#[derive(Default, Clone)]
pub struct EvalAccumulator {
    scores: Vec<(f32, bool)>,
    bce_sum: f64,
}

impl EvalAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, probs: &[f32], labels: &[f32]) {
        self.bce_sum += bce(probs, labels) * probs.len() as f64;
        self.scores
            .extend(probs.iter().zip(labels).map(|(&p, &y)| (p, y > 0.5)));
    }

    pub fn n(&self) -> usize {
        self.scores.len()
    }

    pub fn bce(&self) -> f64 {
        self.bce_sum / self.scores.len() as f64
    }

    pub fn auc(&self) -> f64 {
        auc(&self.scores)
    }
}

/// Exact AUC (probability that a random positive scores above a random
/// negative, ties counted ½) via rank statistics — O(n log n).
pub fn auc(scores: &[(f32, bool)]) -> f64 {
    let n_pos = scores.iter().filter(|(_, y)| *y).count();
    let n_neg = scores.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5; // undefined; conventional fallback
    }
    let mut sorted: Vec<&(f32, bool)> = scores.iter().collect();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
    // average ranks over tie groups
    let mut rank_sum_pos = 0f64;
    let mut i = 0usize;
    while i < sorted.len() {
        let mut j = i;
        while j < sorted.len() && sorted[j].0 == sorted[i].0 {
            j += 1;
        }
        let avg_rank = (i + 1 + j) as f64 / 2.0; // ranks are 1-based
        for item in &sorted[i..j] {
            if item.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j;
    }
    (rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bce_perfect_predictions_near_zero() {
        let b = bce(&[0.9999999, 0.0000001], &[1.0, 0.0]);
        assert!(b < 1e-5, "{b}");
    }

    #[test]
    fn bce_uniform_is_ln2() {
        let b = bce(&[0.5; 10], &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!((b - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn bce_clamps_extremes() {
        let b = bce(&[0.0, 1.0], &[1.0, 0.0]); // maximally wrong
        assert!(b.is_finite());
    }

    #[test]
    fn auc_perfect_ranking() {
        let s = [(0.1f32, false), (0.2, false), (0.8, true), (0.9, true)];
        assert_eq!(auc(&s), 1.0);
    }

    #[test]
    fn auc_inverted_ranking() {
        let s = [(0.9f32, false), (0.8, false), (0.1, true), (0.2, true)];
        assert_eq!(auc(&s), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        let mut scores = Vec::new();
        let mut rng = crate::util::Rng::new(0);
        for _ in 0..20_000 {
            scores.push((rng.uniform() as f32, rng.bernoulli(0.3)));
        }
        let a = auc(&scores);
        assert!((a - 0.5).abs() < 0.02, "{a}");
    }

    #[test]
    fn auc_ties_count_half() {
        let s = [(0.5f32, true), (0.5, false)];
        assert_eq!(auc(&s), 0.5);
    }

    #[test]
    fn auc_matches_brute_force() {
        let mut rng = crate::util::Rng::new(1);
        let scores: Vec<(f32, bool)> = (0..200)
            .map(|_| (((rng.below(20) as f32) / 20.0), rng.bernoulli(0.4)))
            .collect();
        // brute force pair counting
        let mut num = 0f64;
        let mut den = 0f64;
        for &(sp, yp) in &scores {
            if !yp {
                continue;
            }
            for &(sn, yn) in &scores {
                if yn {
                    continue;
                }
                den += 1.0;
                if sp > sn {
                    num += 1.0;
                } else if sp == sn {
                    num += 0.5;
                }
            }
        }
        assert!((auc(&scores) - num / den).abs() < 1e-12);
    }

    #[test]
    fn accumulator_combines_batches() {
        let mut acc = EvalAccumulator::new();
        acc.push(&[0.9, 0.1], &[1.0, 0.0]);
        acc.push(&[0.8, 0.2], &[1.0, 0.0]);
        assert_eq!(acc.n(), 4);
        assert_eq!(acc.auc(), 1.0);
        let direct = bce(&[0.9, 0.1, 0.8, 0.2], &[1.0, 0.0, 1.0, 0.0]);
        assert!((acc.bce() - direct).abs() < 1e-12);
    }
}
