//! Assignment-entropy metrics H₁/H₂ for table-collapse detection
//! (Appendix H). Given the index-pointer tables `h_j: [vocab] → [k]`, H₁ is
//! the minimum per-column entropy and H₂ the minimum pairwise entropy;
//! collapsed clusterings (all values in one cluster, or one column a
//! permutation of another) show up as entropies far below `log k`.

/// Shannon entropy (nats) of the empirical distribution of `values`.
pub fn empirical_entropy(values: &[u64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut counts: std::collections::HashMap<u64, u64> = Default::default();
    for &v in values {
        *counts.entry(v).or_insert(0) += 1;
    }
    let n = values.len() as f64;
    counts
        .values()
        .map(|&c| {
            let p = c as f64 / n;
            -p * p.ln()
        })
        .sum()
}

/// H₁: minimum single-column entropy over the c index-pointer tables.
/// `tables[j][v]` is the cluster of value v in column j.
pub fn h1(tables: &[Vec<u32>]) -> f64 {
    tables
        .iter()
        .map(|t| empirical_entropy(&t.iter().map(|&x| x as u64).collect::<Vec<_>>()))
        .fold(f64::INFINITY, f64::min)
}

/// H₂: minimum pairwise entropy, where the pair (j₁, j₂) is encoded as
/// `h_{j1}(v) + max(h_{j1}) · h_{j2}(v)` (Appendix H's construction).
pub fn h2(tables: &[Vec<u32>]) -> f64 {
    let c = tables.len();
    assert!(c >= 2, "H2 needs at least two columns");
    let mut best = f64::INFINITY;
    for j1 in 0..c {
        let m = *tables[j1].iter().max().unwrap_or(&0) as u64 + 1;
        for j2 in 0..c {
            if j1 == j2 {
                continue;
            }
            let paired: Vec<u64> = tables[j1]
                .iter()
                .zip(&tables[j2])
                .map(|(&a, &b)| a as u64 + m * b as u64)
                .collect();
            best = best.min(empirical_entropy(&paired));
        }
    }
    best
}

/// The ceiling `log k` that a healthy uniform clustering approaches.
pub fn max_h1(k: usize) -> f64 {
    (k as f64).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entropy_of_uniform() {
        let vals: Vec<u64> = (0..1000).map(|i| i % 8).collect();
        assert!((empirical_entropy(&vals) - 8f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn entropy_of_constant_is_zero() {
        assert_eq!(empirical_entropy(&[5; 100]), 0.0);
    }

    #[test]
    fn h1_detects_column_collapse() {
        // column 0 collapsed to one cluster, column 1 healthy
        let collapsed = vec![0u32; 64];
        let healthy: Vec<u32> = (0..64).map(|i| i % 8).collect();
        let h = h1(&[collapsed, healthy]);
        assert_eq!(h, 0.0);
    }

    #[test]
    fn h2_detects_pairwise_collapse() {
        // column 1 is a permutation of column 0 → pair entropy == single
        // entropy, far below 2 log k
        let a: Vec<u32> = (0..640).map(|i| i % 8).collect();
        let b: Vec<u32> = a.iter().map(|&x| (x + 3) % 8).collect();
        let h_pair = h2(&[a.clone(), b]);
        assert!((h_pair - 8f64.ln()).abs() < 1e-9, "collapsed pair: {h_pair}");
        // independent columns approach 2 log k
        let c: Vec<u32> = (0..640).map(|i| (i / 8) % 8).collect();
        let h_ind = h2(&[a, c]);
        assert!((h_ind - (64f64).ln()).abs() < 1e-9, "independent: {h_ind}");
    }

    #[test]
    fn healthy_hash_near_log_k() {
        use crate::hashing::IndexMap;
        use crate::util::Rng;
        let mut rng = Rng::new(0);
        let k = 16u32;
        let tables: Vec<Vec<u32>> = (0..4)
            .map(|_| {
                let m = IndexMap::random(&mut rng, k);
                (0..4096u32).map(|v| m.map(v)).collect()
            })
            .collect();
        let h = h1(&tables);
        assert!(h > max_h1(16) * 0.95, "H1={h} vs {}", max_h1(16));
        let h2v = h2(&tables);
        assert!(h2v > (16f64 * 16.0).ln() * 0.9, "H2={h2v}");
    }
}
