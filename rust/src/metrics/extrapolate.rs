//! Compression-factor estimation (Table 1's "Reproducibility" procedure).
//!
//! Given (params, bce) observations per method, find the parameter count
//! where the method's curve crosses the baseline BCE. Methods that never
//! reach baseline inside the tested range get an extrapolated RANGE:
//! the optimistic bound from a linear fit of the last two points, the
//! conservative one from a quadratic fit of the last three (the paper's
//! exact rule, since the loss curves are convex in log-params).

/// One measured sweep point.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    pub params: f64,
    pub bce: f64,
}

/// Result of the crossing estimate, in parameter units.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Crossing {
    /// baseline is reached inside the measured range at ~this param count
    Measured(f64),
    /// extrapolated: (optimistic linear, conservative quadratic)
    Extrapolated { linear: f64, quadratic: f64 },
    /// the method is worse than baseline everywhere and diverging
    Unreachable,
}

/// Estimate the params needed to reach `baseline` BCE. Points must be
/// sorted by ascending params; bce is assumed (weakly) decreasing.
pub fn params_to_reach(points: &[SweepPoint], baseline: f64) -> Crossing {
    assert!(points.len() >= 2, "need at least two sweep points");
    // measured crossing: first segment that straddles the baseline
    for w in points.windows(2) {
        let (a, b) = (w[0], w[1]);
        if a.bce >= baseline && b.bce <= baseline {
            // log-linear interpolation within the segment
            let t = if (a.bce - b.bce).abs() < 1e-15 {
                0.0
            } else {
                (a.bce - baseline) / (a.bce - b.bce)
            };
            let lp = a.params.ln() + t * (b.params.ln() - a.params.ln());
            return Crossing::Measured(lp.exp());
        }
    }
    if points[0].bce <= baseline {
        // already below baseline at the smallest budget
        return Crossing::Measured(points[0].params);
    }
    // extrapolate in (x = ln params, y = bce) space
    let xy: Vec<(f64, f64)> = points.iter().map(|p| (p.params.ln(), p.bce)).collect();
    let n = xy.len();
    let (x1, y1) = xy[n - 2];
    let (x2, y2) = xy[n - 1];
    if y2 >= y1 {
        return Crossing::Unreachable; // curve is flat or rising
    }
    let slope = (y2 - y1) / (x2 - x1);
    let linear = (x2 + (baseline - y2) / slope).exp();
    // quadratic through the last three points
    let quadratic = if n >= 3 {
        let (x0, y0) = xy[n - 3];
        quad_crossing(x0, y0, x1, y1, x2, y2, baseline).map(f64::exp)
    } else {
        None
    };
    Crossing::Extrapolated { linear, quadratic: quadratic.unwrap_or(f64::INFINITY) }
}

/// Solve the parabola through three points for y = target, returning the
/// root ≥ x2 (the curve is convex-decreasing, so the crossing beyond the
/// data — if any — is the smaller-derivative branch). None if the parabola
/// bottoms out above the target (paper's "only intersects at a higher
/// parameter count" case maps to a larger, possibly infinite value).
fn quad_crossing(
    x0: f64, y0: f64, x1: f64, y1: f64, x2: f64, y2: f64, target: f64,
) -> Option<f64> {
    // Lagrange to standard form y = ax² + bx + c
    let d0 = (x0 - x1) * (x0 - x2);
    let d1 = (x1 - x0) * (x1 - x2);
    let d2 = (x2 - x0) * (x2 - x1);
    let a = y0 / d0 + y1 / d1 + y2 / d2;
    let b = -y0 * (x1 + x2) / d0 - y1 * (x0 + x2) / d1 - y2 * (x0 + x1) / d2;
    let c = y0 * x1 * x2 / d0 + y1 * x0 * x2 / d1 + y2 * x0 * x1 / d2;
    let cc = c - target;
    if a.abs() < 1e-12 * (b.abs() + 1.0) {
        // collinear points: the parabola degenerates to the line bx + c
        let r = -cc / b;
        return (r >= x2 - 1e-9 && r.is_finite()).then_some(r);
    }
    let disc = b * b - 4.0 * a * cc;
    if disc < 0.0 {
        return None;
    }
    let r1 = (-b + disc.sqrt()) / (2.0 * a);
    let r2 = (-b - disc.sqrt()) / (2.0 * a);
    [r1, r2]
        .into_iter()
        .filter(|r| *r >= x2 - 1e-9 && r.is_finite())
        .min_by(|p, q| p.total_cmp(q))
}

/// Compression factor = full-table params / params-to-reach-baseline.
pub fn compression_factor(full_params: f64, crossing: Crossing) -> (f64, Option<f64>) {
    match crossing {
        Crossing::Measured(p) => (full_params / p, None),
        Crossing::Extrapolated { linear, quadratic } => {
            (full_params / linear, Some(full_params / quadratic))
        }
        Crossing::Unreachable => (0.0, Some(0.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(v: &[(f64, f64)]) -> Vec<SweepPoint> {
        v.iter().map(|&(params, bce)| SweepPoint { params, bce }).collect()
    }

    #[test]
    fn measured_crossing_interpolates() {
        let p = pts(&[(100.0, 0.50), (1000.0, 0.40)]);
        match params_to_reach(&p, 0.45) {
            Crossing::Measured(x) => {
                assert!((x.ln() - (100f64.ln() + 1000f64.ln()) / 2.0).abs() < 1e-9)
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn already_below_baseline() {
        let p = pts(&[(100.0, 0.40), (1000.0, 0.39)]);
        assert_eq!(params_to_reach(&p, 0.45), Crossing::Measured(100.0));
    }

    #[test]
    fn linear_extrapolation_exact_on_linear_data() {
        // bce = 0.6 − 0.05·ln(params/100)/ln(10): crosses 0.45 at params=100·10³
        let p = pts(&[
            (100.0, 0.60),
            (1_000.0, 0.55),
            (10_000.0, 0.50),
        ]);
        match params_to_reach(&p, 0.45) {
            Crossing::Extrapolated { linear, quadratic } => {
                assert!((linear - 100_000.0).abs() / 100_000.0 < 1e-6, "{linear}");
                // data is exactly linear → quadratic agrees
                assert!((quadratic - 100_000.0).abs() / 100_000.0 < 1e-6, "{quadratic}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn convex_curve_gives_quadratic_above_linear() {
        // convex (flattening): quadratic crossing must need MORE params
        let p = pts(&[(100.0, 0.60), (1_000.0, 0.52), (10_000.0, 0.48)]);
        match params_to_reach(&p, 0.45) {
            Crossing::Extrapolated { linear, quadratic } => {
                assert!(quadratic > linear, "lin {linear} quad {quadratic}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rising_tail_unreachable() {
        let p = pts(&[(100.0, 0.50), (1_000.0, 0.49), (10_000.0, 0.495)]);
        assert_eq!(params_to_reach(&p, 0.45), Crossing::Unreachable);
    }

    #[test]
    fn compression_factor_ranges() {
        let (hi, lo) = compression_factor(
            1e7,
            Crossing::Extrapolated { linear: 1e4, quadratic: 2e4 },
        );
        assert_eq!(hi, 1e3);
        assert_eq!(lo, Some(500.0));
        let (m, none) = compression_factor(1e7, Crossing::Measured(1e3));
        assert_eq!(m, 1e4);
        assert!(none.is_none());
    }
}
