//! The training loop: pipelined batches → index generation → chained
//! device steps, with CCE clustering events, periodic validation, early
//! stopping, and best-checkpoint tracking.
//!
//! This is the paper's Algorithm 3 embedded in a DLRM training run: the
//! `ct`/`cf` schedule (Figure 9's strategy space) decides *when* the
//! clustering events fire; `coordinator::cluster` decides *what* they do.
//!
//! Clustering events run in one of two modes:
//!
//!   * **synchronous** (default, deterministic): the step loop stalls
//!     while `compute_cluster` + `apply_cluster` run back-to-back against
//!     the pool field (`pull_field` → cluster → `set_field`; with
//!     per-group device buffers the dense layers never cross the wire —
//!     an event costs pool-buffer bytes, accounted in
//!     `TrainOutcome::event_bytes_*`).
//!   * **overlapped** (`cluster_overlap`): the pool snapshot + an
//!     `Indexer` clone go to a persistent `BackgroundWorker`; training
//!     continues on the old maps, and at the first step boundary where
//!     the job is done the new maps/centroids are applied against the
//!     CURRENT pool. The steps trained on stale maps are recorded per
//!     event in `TrainOutcome::cluster_stale_steps`; only the snapshot
//!     and apply moments stall the loop. Outputs depend on job timing,
//!     so this mode trades the synchronous path's bit-reproducibility
//!     for stall-free events.

use crate::config::TrainConfig;
use crate::coordinator::cluster::{
    apply_cluster, compute_cluster, ClusterComputed, ClusterConfig, ClusterOutcome,
};
use crate::coordinator::eval::evaluate;
use crate::coordinator::pipeline::BatchPipeline;
use crate::data::batch::Split;
use crate::data::synthetic::SyntheticDataset;
use crate::runtime::manifest::FieldDesc;
use crate::runtime::session::{DlrmSession, EmbInput};
use crate::runtime::ArtifactStore;
use crate::tables::indexer::{Indexer, MethodKind};
use crate::tables::init::init_state;
use crate::tables::layout::TablePlan;
use crate::util::threadpool::{BackgroundWorker, JobHandle};
use crate::util::Rng;
use anyhow::{bail, Context, Result};
use std::time::Instant;

/// A servable model checkpoint: the host state vector paired with its
/// contemporaneous index maps. Clustering events rewrite both, and they
/// are only valid together — this is the unit `cce serve` bakes into a
/// `ServingSnapshot` (ROADMAP "trained-weight serving path").
#[derive(Clone)]
pub struct Checkpoint {
    pub state: Vec<f32>,
    pub indexer: Indexer,
}

impl std::fmt::Debug for Checkpoint {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Checkpoint {{ state: {} f32, indexer: <maps> }}", self.state.len())
    }
}

/// Everything a finished run reports (consumed by the experiment harness).
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    pub artifact: String,
    pub seed: u64,
    /// (global step, train BCE over the window) samples of the loss curve
    pub train_curve: Vec<(usize, f64)>,
    /// (global step, val BCE) at each evaluation point
    pub val_curve: Vec<(usize, f64)>,
    pub best_val_bce: f64,
    /// test metrics at the best-validation checkpoint
    pub test_bce: f64,
    pub test_auc: f64,
    pub epochs_run: usize,
    pub steps_run: usize,
    /// REAL samples trained (padded duplicates in each epoch's final
    /// batch excluded) — the honest numerator for `throughput`
    pub samples_trained: usize,
    /// clustering events whose maps actually landed (an overlapped event
    /// abandoned at end of training because the best checkpoint
    /// supersedes it is not counted)
    pub clusterings_run: usize,
    /// per applied event: steps trained on stale maps between the
    /// event's pool snapshot and its apply (all zeros in synchronous
    /// mode); always `clusterings_run` entries long
    pub cluster_stale_steps: Vec<usize>,
    /// embedding parameter count (Table 1 accounting)
    pub embedding_params: usize,
    /// paper compression measures
    pub compression_total: f64,
    pub compression_largest: f64,
    pub train_secs: f64,
    /// wall time the STEP LOOP was stalled on clustering (sync: the whole
    /// event; overlapped: just the snapshot + apply moments)
    pub cluster_secs: f64,
    /// total event wall time, snapshot → apply (== `cluster_secs` in
    /// synchronous mode; larger in overlapped mode, where the compute
    /// share runs concurrently with training)
    pub cluster_event_secs: f64,
    /// samples/sec over the training phase (excludes eval + clustering)
    pub throughput: f64,
    /// state bytes moved device→host over the run (group-buffer traffic
    /// only; per-batch dense/emb/labels uploads are not state)
    pub bytes_downloaded: u64,
    /// state bytes moved host→device over the run
    pub bytes_uploaded: u64,
    /// the share of `bytes_downloaded` spent on clustering events
    /// (snapshot pulls + applies); with per-group buffers this is
    /// pool-buffer traffic only — 2 pool downloads + 1 pool upload per
    /// overlapped event, 1 + 1 per synchronous event
    pub event_bytes_downloaded: u64,
    /// the share of `bytes_uploaded` spent on clustering events
    pub event_bytes_uploaded: u64,
    /// wire cost (bytes) of moving the pool buffer once — the unit the
    /// event costs above are multiples of
    pub pool_bytes: u64,
    /// the best-validation (state, indexer) pair — what serving should
    /// bake; always `Some` after `train` returns Ok
    pub best_checkpoint: Option<Checkpoint>,
    /// segment files written by the bake-generation hook (`snapshot_dir`),
    /// in generation order; the last one is the final checkpoint's maps
    pub snapshot_files: Vec<String>,
    /// wall time spent baking + writing those segments (not training time)
    pub snapshot_write_secs: f64,
}

/// An overlapped clustering event in flight: the background compute job
/// plus the bookkeeping needed to apply it and account staleness.
struct PendingCluster {
    handle: JobHandle<ClusterComputed>,
    /// global step at which the pool was snapshotted
    started_step: usize,
    /// wall clock at snapshot start (event wall time = snapshot → apply)
    started_at: Instant,
}

/// Apply a computed clustering against the CURRENT device state: patch
/// the pool field (only the clustered subtable ranges change) and swap
/// the live maps. Shared by the synchronous path, the overlapped apply at
/// a step boundary, and the end-of-training drain.
fn apply_computed(
    session: &mut DlrmSession,
    pool: &FieldDesc,
    indexer: &mut Indexer,
    computed: ClusterComputed,
) -> Result<ClusterOutcome> {
    let mut pool_data = session.pull_field(pool)?;
    let res = apply_cluster(&mut pool_data, indexer, computed);
    session.set_field(pool, &pool_data)?;
    Ok(res)
}

/// The bake-generation hook: when `snapshot_dir` is set, bake the current
/// maps and write them as the next segment generation. Called after every
/// applied clustering event and for the final checkpoint, so a serving
/// engine can `SnapshotSlot::install_snapshot` generation N+1 while this
/// run keeps training (the producer half of the live hot-swap loop).
fn write_snapshot_generation(
    dir: &str,
    artifact: &str,
    indexer: &Indexer,
    keep: usize,
    out: &mut TrainOutcome,
) -> Result<()> {
    if dir.is_empty() {
        return Ok(());
    }
    let t0 = Instant::now();
    let generation = out.snapshot_files.len() as u64;
    let mut sp = crate::span!("train.snapshot.bake");
    sp.attr("generation", generation);
    let snap = crate::serving::ServingSnapshot::bake(indexer);
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create snapshot dir {dir}"))?;
    let path = std::path::Path::new(dir).join(format!("{artifact}-gen{generation}.cceseg"));
    let bytes = crate::serving::segment::write_segment(&snap, generation, &path)?;
    let pruned = prune_snapshot_generations(dir, artifact, keep, &path)?;
    out.snapshot_write_secs += t0.elapsed().as_secs_f64();
    log::info!(
        "snapshot generation {generation}: {} ({:.1} MB in {:.1} ms, {pruned} pruned)",
        path.display(),
        bytes as f64 / 1e6,
        t0.elapsed().as_secs_f64() * 1e3
    );
    out.snapshot_files.push(path.display().to_string());
    Ok(())
}

/// Retention GC for `snapshot_dir` (`[train] snapshot_keep = K`): remove
/// this artifact's segment files beyond the newest `keep` generations.
/// `keep == 0` disables pruning. `current` — the generation just published —
/// is never removed, even when stale bookkeeping would rank it prunable
/// (e.g. a fresh run restarting at generation 0 in a directory that still
/// holds a previous run's higher generations): deleting the file a serving
/// watcher is about to install is the one failure mode GC must never have.
/// `.tmp` siblings and files of other artifacts are untouched.
pub fn prune_snapshot_generations(
    dir: &str,
    artifact: &str,
    keep: usize,
    current: &std::path::Path,
) -> Result<usize> {
    if keep == 0 {
        return Ok(0);
    }
    let prefix = format!("{artifact}-gen");
    let rd = std::fs::read_dir(dir).with_context(|| format!("read snapshot dir {dir}"))?;
    let mut gens: Vec<(u64, std::path::PathBuf)> = Vec::new();
    for entry in rd.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(g) = name
            .strip_prefix(&prefix)
            .and_then(|s| s.strip_suffix(".cceseg"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        gens.push((g, path));
    }
    // newest first; everything past the keep window goes
    gens.sort_by(|a, b| b.0.cmp(&a.0));
    let mut pruned = 0usize;
    for (_, path) in gens.into_iter().skip(keep) {
        if path.as_path() == current {
            continue;
        }
        if std::fs::remove_file(&path).is_ok() {
            pruned += 1;
        }
    }
    Ok(pruned)
}

/// Build the indexer an artifact's manifest calls for.
pub fn build_indexer(m: &crate::runtime::Manifest, seed: u64) -> Result<Indexer> {
    let mut rng = Rng::new(seed ^ 0x1D5EED);
    let kind = MethodKind::parse(&m.kind)?;
    Ok(match kind {
        MethodKind::RowWise => {
            let plan = TablePlan::new(&m.vocabs, m.spec.cap, m.spec.t, m.spec.c, m.spec.dc);
            if plan.total_rows != m.spec.pool_rows {
                bail!(
                    "row-plan mismatch: rust computes {} rows, manifest says {} — \
                     specs.py and tables/layout.rs disagree",
                    plan.total_rows,
                    m.spec.pool_rows
                );
            }
            Indexer::new_rowwise(&mut rng, plan)
        }
        MethodKind::ElementWise => {
            let ix = Indexer::new_robe(&mut rng, &m.vocabs, m.spec.cap, m.spec.dim, m.spec.c);
            if ix.robe_pool_elems() != m.spec.pool_rows {
                bail!(
                    "robe-pool mismatch: rust computes {} elems, manifest says {}",
                    ix.robe_pool_elems(),
                    m.spec.pool_rows
                );
            }
            Ok::<_, anyhow::Error>(ix)?
        }
        MethodKind::Dhe => Indexer::new_dhe(&mut rng, &m.vocabs, m.spec.n_hash),
    })
}

/// Run a full training job for one artifact under one config.
pub fn train(store: &ArtifactStore, cfg: &TrainConfig) -> Result<TrainOutcome> {
    cfg.validate()?;
    let mut session = DlrmSession::open(store, &cfg.artifact)
        .with_context(|| format!("opening artifact {}", cfg.artifact))?;
    let m = session.manifest.clone();
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, cfg.seed)?);
    if ds.spec.vocabs != m.vocabs {
        bail!("dataset/manifest vocab mismatch for {}", cfg.artifact);
    }
    let mut indexer = build_indexer(&m, cfg.seed)?;

    // initialize state on host, upload
    let mut rng = Rng::new(cfg.seed ^ 0x57A7E);
    let state0 = init_state(&m.layout, m.state_size, &mut rng);
    session.set_state(&state0)?;
    drop(state0);

    let batch = m.spec.batch;
    let n_train_batches = ds.spec.train_samples.div_ceil(batch);
    let eval_every = if cfg.eval_every > 0 {
        cfg.eval_every
    } else {
        n_train_batches.div_ceil(6).max(1) // paper: ~6 evals per epoch
    };
    // clustering schedule: `ct` events, every `cf` batches (cf=0 → epoch end)
    let cluster_every = if cfg.cluster_every > 0 { cfg.cluster_every } else { n_train_batches };
    let clustering_enabled = m.spec.t >= 2 && matches!(indexer.kind, MethodKind::RowWise);

    let mut out = TrainOutcome {
        artifact: cfg.artifact.clone(),
        seed: cfg.seed,
        embedding_params: m.spec.embedding_params,
        best_val_bce: f64::INFINITY,
        ..Default::default()
    };
    if let MethodKind::RowWise = indexer.kind {
        out.compression_total = indexer.plan.compression_total();
        out.compression_largest = indexer.plan.compression_largest();
    }

    let mut rows = vec![0i32; session.emb_elems("train")?];
    let mut hashes: Vec<f32> = Vec::new();
    if matches!(indexer.kind, MethodKind::Dhe) {
        hashes = vec![0f32; session.emb_elems("train")?];
    }

    // checkpoints pair the state with its contemporaneous index maps:
    // clustering events rewrite both, and they are only valid together
    let mut best_state: Option<(Vec<f32>, Indexer)> = None;
    let mut global_step = 0usize;
    let mut samples_trained = 0usize;
    let mut last_metrics = (0f64, 0f64); // (loss_sum, examples) at last curve sample
    let mut prev_epoch_best = f64::INFINITY;
    let t_start = Instant::now();
    let mut eval_secs = 0f64;
    // registry mirrors, bumped beside the TrainOutcome fields they shadow
    // (tests/obs_metrics.rs pins the deltas against the outcome): the live
    // stats stream and the final report come from the same source sites
    let m_steps = crate::obs_counter!("train.steps");
    let m_events = crate::obs_counter!("train.cluster.events");
    let m_stale = crate::obs_counter!("train.cluster.stale_steps");
    let pool_field = m.layout.iter().find(|f| f.name == "pool").cloned();

    // overlapped clustering: one persistent background worker, at most
    // one event in flight; the compute job leaves a core for the step
    // loop it overlaps with
    let cluster_worker =
        (cfg.cluster_overlap && clustering_enabled).then(|| BackgroundWorker::new("cluster"));
    let overlap_threads =
        crate::util::threadpool::default_threads().saturating_sub(1).max(1);
    let mut pending: Option<PendingCluster> = None;

    'epochs: for epoch in 0..cfg.epochs {
        out.epochs_run = epoch + 1;
        let shuffle = cfg.shuffle.then(|| cfg.seed ^ 0xE90C ^ epoch as u64);
        let mut pipe = BatchPipeline::start(
            &ds,
            Split::Train,
            batch,
            shuffle,
            cfg.pipeline_workers,
            cfg.pipeline_depth,
        );
        let mut epoch_best = f64::INFINITY;
        let mut batch_in_epoch = 0usize;
        while let Some(b) = pipe.next() {
            // padding in the final train batch: train on it anyway (the
            // duplicated sample adds negligible bias at these scales)
            let sp_step = crate::span!("train.step");
            match indexer.kind {
                MethodKind::RowWise => {
                    indexer.fill_rowwise(&b.cats, batch, &mut rows);
                    session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels)?;
                }
                MethodKind::ElementWise => {
                    indexer.fill_elementwise(&b.cats, batch, &mut rows);
                    session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels)?;
                }
                MethodKind::Dhe => {
                    indexer.fill_dhe(&b.cats, batch, &mut hashes);
                    session.train_step(&b.dense, EmbInput::Hashes(&hashes), &b.labels)?;
                }
            }
            drop(sp_step);
            global_step += 1;
            batch_in_epoch += 1;
            samples_trained += b.real;
            m_steps.inc();

            // apply a finished overlapped event at this step boundary
            // BEFORE deciding whether a new event is due — a boundary
            // that coincides with a just-finished job must free the
            // in-flight slot, not skip the scheduled event
            if let Some(mut p) = pending.take() {
                match p.handle.try_join() {
                    Some(computed) => {
                        let t0 = Instant::now();
                        let tb = session.transfer_bytes();
                        let mut sp = crate::span!("train.event.apply");
                        let pf =
                            pool_field.as_ref().expect("rowwise artifact without pool field");
                        let mut res = apply_computed(&mut session, pf, &mut indexer, computed)?;
                        let (d, u) = session.transfer_bytes();
                        out.event_bytes_downloaded += d - tb.0;
                        out.event_bytes_uploaded += u - tb.1;
                        res.stale_steps = global_step - p.started_step;
                        out.cluster_stale_steps.push(res.stale_steps);
                        out.cluster_secs += t0.elapsed().as_secs_f64();
                        out.cluster_event_secs += p.started_at.elapsed().as_secs_f64();
                        // trace attrs carry the same per-event numbers the
                        // outcome (and BENCH_cluster.json) report
                        sp.attr("event_bytes_downloaded", d - tb.0);
                        sp.attr("event_bytes_uploaded", u - tb.1);
                        sp.attr("stale_steps", res.stale_steps as u64);
                        drop(sp);
                        m_events.inc();
                        m_stale.add(res.stale_steps as u64);
                        log::info!(
                            "clustering #{} applied at step {global_step}: {} subtables, \
                             inertia {:.3e}, {} steps on stale maps",
                            out.clusterings_run,
                            res.subtables_clustered,
                            res.total_inertia,
                            res.stale_steps
                        );
                        // publish the post-event maps as generation N+1
                        write_snapshot_generation(
                            &cfg.snapshot_dir,
                            &cfg.artifact,
                            &indexer,
                            cfg.snapshot_keep,
                            &mut out,
                        )?;
                    }
                    None => pending = Some(p),
                }
            }

            // CCE clustering event
            if clustering_enabled
                && out.clusterings_run < cfg.cluster_times
                && global_step % cluster_every == 0
            {
                let pf = pool_field.as_ref().expect("rowwise artifact without pool field");
                let cc = ClusterConfig {
                    kmeans_iters: cfg.kmeans_iters,
                    points_per_centroid: cfg.kmeans_points_per_centroid,
                    seed: cfg.seed ^ 0xC1C ^ out.clusterings_run as u64,
                    n_threads: if cluster_worker.is_some() { overlap_threads } else { 0 },
                };
                if let Some(worker) = &cluster_worker {
                    if pending.is_none() {
                        // overlapped: snapshot the pool + clone the maps,
                        // hand both to the background job, keep training.
                        // With per-group buffers this pull moves pool
                        // bytes only, never the dense-layer share.
                        let t0 = Instant::now();
                        let tb = session.transfer_bytes();
                        let mut sp = crate::span!("train.event.snapshot");
                        let pool = session.pull_field(pf)?;
                        let (d, u) = session.transfer_bytes();
                        out.event_bytes_downloaded += d - tb.0;
                        out.event_bytes_uploaded += u - tb.1;
                        sp.attr("event_bytes_downloaded", d - tb.0);
                        drop(sp);
                        let ix_snapshot = indexer.clone();
                        let handle = worker.submit(move || {
                            let _sp = crate::span!("train.event.compute");
                            compute_cluster(&pool, &ix_snapshot, &cc)
                        });
                        out.clusterings_run += 1;
                        out.cluster_secs += t0.elapsed().as_secs_f64();
                        pending = Some(PendingCluster {
                            handle,
                            started_step: global_step,
                            started_at: t0,
                        });
                        log::info!(
                            "clustering #{} snapshotted at step {global_step} (overlapped)",
                            out.clusterings_run
                        );
                    } else {
                        log::warn!(
                            "clustering due at step {global_step} but the previous event \
                             is still computing; skipping this boundary"
                        );
                    }
                } else {
                    // synchronous: compute + apply back-to-back on the one
                    // held pool copy; only the pool buffer crosses the
                    // wire (1 download + 1 upload)
                    let t0 = Instant::now();
                    let tb = session.transfer_bytes();
                    let sp_snap = crate::span!("train.event.snapshot");
                    let mut pool = session.pull_field(pf)?;
                    drop(sp_snap);
                    let sp_compute = crate::span!("train.event.compute");
                    let computed = compute_cluster(&pool, &indexer, &cc);
                    drop(sp_compute);
                    let mut sp_apply = crate::span!("train.event.apply");
                    let res = apply_cluster(&mut pool, &mut indexer, computed);
                    session.set_field(pf, &pool)?;
                    let (d, u) = session.transfer_bytes();
                    out.event_bytes_downloaded += d - tb.0;
                    out.event_bytes_uploaded += u - tb.1;
                    sp_apply.attr("event_bytes_downloaded", d - tb.0);
                    sp_apply.attr("event_bytes_uploaded", u - tb.1);
                    sp_apply.attr("stale_steps", 0);
                    drop(sp_apply);
                    out.clusterings_run += 1;
                    out.cluster_stale_steps.push(0);
                    m_events.inc();
                    let stall = t0.elapsed().as_secs_f64();
                    out.cluster_secs += stall;
                    out.cluster_event_secs += stall;
                    log::info!(
                        "clustering #{} at step {global_step}: {} subtables, \
                         inertia {:.3e}, {:.2}s",
                        out.clusterings_run,
                        res.subtables_clustered,
                        res.total_inertia,
                        res.elapsed_secs
                    );
                    write_snapshot_generation(
                        &cfg.snapshot_dir,
                        &cfg.artifact,
                        &indexer,
                        cfg.snapshot_keep,
                        &mut out,
                    )?;
                }
            }

            // periodic validation + train-curve sampling
            if batch_in_epoch % eval_every == 0 || batch_in_epoch == pipe.n_batches {
                let te = Instant::now();
                let met = session.metrics()?;
                let (ls, ex) = (met[0] as f64, met[1] as f64);
                let window_bce = (ls - last_metrics.0) / (ex - last_metrics.1).max(1.0);
                last_metrics = (ls, ex);
                out.train_curve.push((global_step, window_bce));
                let vacc = evaluate(&session, &indexer, &ds, Split::Val)?;
                let vbce = vacc.bce();
                out.val_curve.push((global_step, vbce));
                epoch_best = epoch_best.min(vbce);
                if vbce < out.best_val_bce {
                    out.best_val_bce = vbce;
                    best_state = Some((session.pull_state()?, indexer.clone()));
                }
                eval_secs += te.elapsed().as_secs_f64();
                log::info!(
                    "step {global_step}: train {window_bce:.5}, val {vbce:.5} (best {:.5})",
                    out.best_val_bce
                );
            }

            if cfg.max_batches > 0 && global_step >= cfg.max_batches {
                break 'epochs;
            }
        }
        // paper's early stopping: stop when this epoch's best val BCE fails
        // to beat the previous epoch's best
        if cfg.early_stop && epoch > 0 && prev_epoch_best <= epoch_best {
            log::info!("early stop after epoch {}: {prev_epoch_best:.5} <= {epoch_best:.5}", epoch + 1);
            break;
        }
        prev_epoch_best = epoch_best;
    }
    out.steps_run = global_step;
    out.samples_trained = samples_trained;
    // clamp: a short eval-dominated run must not report negative time
    out.train_secs =
        (t_start.elapsed().as_secs_f64() - eval_secs - out.cluster_secs).max(0.0);
    // true samples, not `steps × batch`: the padded duplicates in each
    // epoch's final batch are trained on but must not inflate throughput;
    // a clamped (unmeasurable) training time reports 0, not samples/1e-9
    out.throughput =
        if out.train_secs > 0.0 { samples_trained as f64 / out.train_secs } else { 0.0 };

    // an overlapped event still in flight when training ended
    if let Some(p) = pending.take() {
        if best_state.is_none() {
            // no eval point was reached, so the FINAL state becomes the
            // checkpoint: block and apply so it carries the computed maps
            let t0 = Instant::now();
            let tb = session.transfer_bytes();
            let computed = p.handle.join();
            let pf = pool_field.as_ref().expect("rowwise artifact without pool field");
            apply_computed(&mut session, pf, &mut indexer, computed)?;
            let (d, u) = session.transfer_bytes();
            out.event_bytes_downloaded += d - tb.0;
            out.event_bytes_uploaded += u - tb.1;
            let stale = global_step - p.started_step;
            out.cluster_stale_steps.push(stale);
            out.cluster_secs += t0.elapsed().as_secs_f64();
            out.cluster_event_secs += p.started_at.elapsed().as_secs_f64();
            m_events.inc();
            m_stale.add(stale as u64);
            log::info!(
                "clustering #{} applied after training ended ({stale} steps on stale maps)",
                out.clusterings_run
            );
            // no segment write here: these maps become the final checkpoint
            // below, and the final-generation write covers them
        } else {
            // the best checkpoint supersedes the final state — applying
            // here would be overwritten by the restore below, so don't
            // stall on the background job just to discard its result
            // (the worker's Drop still waits for the thread to finish).
            // The event never completed: take it back out of the applied
            // count so `clusterings_run`/`cluster_stale_steps` only report
            // clusterings whose maps actually landed; its wall time still
            // counts (its snapshot stall went into cluster_secs at submit).
            out.clusterings_run -= 1;
            out.cluster_event_secs += p.started_at.elapsed().as_secs_f64();
            log::info!(
                "clustering event still in flight at end of training; superseded by the \
                 best checkpoint, not applied ({} steps since its snapshot)",
                global_step - p.started_step
            );
        }
    }

    // restore the best (state, maps) checkpoint and evaluate on test; the
    // checkpoint rides out on the outcome so `cce serve` can bake the
    // trained model instead of re-initializing random state. A run that
    // never reached an eval point (tiny max_batches) checkpoints its
    // final state.
    let (ck_state, ck_indexer) = match best_state {
        Some((bs, bix)) => {
            session.set_state(&bs)?;
            (bs, bix)
        }
        None => (session.pull_state()?, indexer),
    };
    let tacc = evaluate(&session, &ck_indexer, &ds, Split::Test)?;
    out.test_bce = tacc.bce();
    out.test_auc = tacc.auc();
    let (d, u) = session.transfer_bytes();
    out.bytes_downloaded = d;
    out.bytes_uploaded = u;
    out.pool_bytes = session.buffer_bytes("pool")?;
    // final generation: the checkpoint that actually ships to serving
    write_snapshot_generation(&cfg.snapshot_dir, &cfg.artifact, &ck_indexer, cfg.snapshot_keep, &mut out)?;
    out.best_checkpoint = Some(Checkpoint { state: ck_state, indexer: ck_indexer });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    fn touch_gen(dir: &std::path::Path, artifact: &str, gen: u64) -> std::path::PathBuf {
        let p = dir.join(format!("{artifact}-gen{gen}.cceseg"));
        std::fs::write(&p, b"x").unwrap();
        p
    }

    fn names(dir: &std::path::Path) -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .collect();
        v.sort();
        v
    }

    #[test]
    fn prune_keeps_newest_k_generations() {
        let dir = TempDir::new("prune_keep");
        for g in 0..5 {
            touch_gen(dir.path(), "a", g);
        }
        let current = dir.path().join("a-gen4.cceseg");
        let d = dir.path().to_str().unwrap();
        let pruned = prune_snapshot_generations(d, "a", 2, &current).unwrap();
        assert_eq!(pruned, 3);
        assert_eq!(names(dir.path()), vec!["a-gen3.cceseg", "a-gen4.cceseg"]);
        // keep = 0 disables pruning entirely
        assert_eq!(prune_snapshot_generations(d, "a", 0, &current).unwrap(), 0);
        assert_eq!(names(dir.path()).len(), 2);
    }

    #[test]
    fn prune_never_removes_the_generation_being_written() {
        // a fresh run restarting at generation 0 in a dir still holding a
        // previous run's generations 5..=7: gen 0 ranks oldest, but it is
        // the file just published — GC must not eat it
        let dir = TempDir::new("prune_current");
        for g in 5..8 {
            touch_gen(dir.path(), "a", g);
        }
        let current = touch_gen(dir.path(), "a", 0);
        let d = dir.path().to_str().unwrap();
        let pruned = prune_snapshot_generations(d, "a", 2, &current).unwrap();
        assert_eq!(pruned, 1, "only gen 5 goes: 7 and 6 are kept, 0 is current");
        assert_eq!(
            names(dir.path()),
            vec!["a-gen0.cceseg", "a-gen6.cceseg", "a-gen7.cceseg"]
        );
    }

    #[test]
    fn prune_ignores_other_artifacts_tmp_and_unparseable_names() {
        let dir = TempDir::new("prune_foreign");
        for g in 0..4 {
            touch_gen(dir.path(), "a", g);
        }
        touch_gen(dir.path(), "other", 9);
        std::fs::write(dir.path().join("a-gen5.cceseg.tmp"), b"x").unwrap();
        std::fs::write(dir.path().join("a-genX.cceseg"), b"x").unwrap();
        std::fs::write(dir.path().join("notes.txt"), b"x").unwrap();
        let current = dir.path().join("a-gen3.cceseg");
        let d = dir.path().to_str().unwrap();
        let pruned = prune_snapshot_generations(d, "a", 1, &current).unwrap();
        assert_eq!(pruned, 3, "only a-gen{{0,1,2}} are prunable");
        assert_eq!(
            names(dir.path()),
            vec![
                "a-gen3.cceseg",
                "a-gen5.cceseg.tmp",
                "a-genX.cceseg",
                "notes.txt",
                "other-gen9.cceseg"
            ]
        );
    }
}
