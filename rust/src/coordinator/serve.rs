//! Thin adapter from the coordinator to the `serving` subsystem: bake the
//! live `Indexer` into a `ServingSnapshot` (or zero-copy load one from a
//! segment file), wire the session into a `SessionExecutor`, and run the
//! multi-worker engine off a hot-swappable `SnapshotSlot`.
//!
//! The old 92-line synchronous loop lived here; it replayed dataset batches,
//! padded every batch to `eval_batch`, dispatched through the training
//! indexer's per-lookup enum match, and charged each request the whole
//! burst's latency. All of that now lives — fixed — in `crate::serving`.

use crate::config::ServeConfig;
use crate::coordinator::trainer::Checkpoint;
use crate::data::synthetic::SyntheticDataset;
use crate::runtime::session::DlrmSession;
use crate::serving::{
    engine, segment, watcher, EngineConfig, ServingSnapshot, SessionExecutor, SnapshotSlot,
    SnapshotWatcher, TrafficGen, WatcherConfig, WatcherReport,
};
use crate::tables::indexer::Indexer;
use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

pub use crate::serving::ServeReport;

fn engine_config(session: &DlrmSession, cfg: &ServeConfig) -> EngineConfig {
    let eval_batch = session.manifest.spec.eval_batch;
    EngineConfig {
        workers: cfg.workers,
        max_batch: if cfg.max_batch == 0 { eval_batch } else { cfg.max_batch },
        max_wait: cfg.max_wait(),
        queue_depth: cfg.queue_depth,
        admission: cfg.admission_policy(),
        pace: cfg.pace(),
    }
}

fn run_engine(
    session: &DlrmSession,
    slot: &SnapshotSlot,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let engine_cfg = engine_config(session, cfg);
    let traffic = TrafficGen::new(ds, cfg.zipf_skew, cfg.seed);
    let mut executor = SessionExecutor::new(session);
    engine::run(&mut executor, slot, traffic, &engine_cfg, cfg.requests)
}

/// Serve `cfg.requests` Zipf-skewed synthetic queries over a trained
/// artifact through the multi-worker engine.
pub fn serve(
    session: &DlrmSession,
    indexer: &Indexer,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    let t_bake = std::time::Instant::now();
    let slot = SnapshotSlot::new(ServingSnapshot::bake(indexer));
    let bake_secs = t_bake.elapsed().as_secs_f64();
    let mut rep = run_engine(session, &slot, ds, cfg)?;
    rep.bake_secs = bake_secs;
    Ok(rep)
}

/// Serve from a trained checkpoint: upload the checkpoint's state and
/// bake its contemporaneous indexer (the pair is only valid together —
/// clustering events rewrite both). This is the ROADMAP "trained-weight
/// serving path": `cce serve --train-steps N` lands here instead of
/// serving a random-initialized model. The state upload (one device
/// buffer per group) is the only transfer at bake time; it is reported
/// as `ServeReport::bake_transfer_bytes`.
pub fn serve_trained(
    session: &mut DlrmSession,
    ckpt: &Checkpoint,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let tb = session.transfer_bytes();
    session.set_state(&ckpt.state)?;
    let (d, u) = session.transfer_bytes();
    let mut rep = serve(session, &ckpt.indexer, ds, cfg)?;
    rep.bake_transfer_bytes = (d - tb.0) + (u - tb.1);
    Ok(rep)
}

/// Boot the engine straight from an on-disk segment (`cce serve --snapshot`):
/// no bake, no training run — the snapshot tables are mmapped and served
/// zero-copy, so this path cold-starts in milliseconds regardless of table
/// size. The device state stays as the caller initialized it (segments carry
/// index maps, not weights — see ROADMAP "unified checkpoint").
pub fn serve_snapshot(
    session: &DlrmSession,
    path: &Path,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    let t_load = std::time::Instant::now();
    let loaded = segment::load_segment(path)?;
    let load_secs = t_load.elapsed().as_secs_f64();
    log::info!(
        "segment {}: generation {}, {:.1} MB, {} in {:.3} ms",
        path.display(),
        loaded.generation,
        loaded.file_bytes as f64 / 1e6,
        if loaded.mapped { "mmapped" } else { "read (mmap unavailable)" },
        load_secs * 1e3
    );
    let slot = SnapshotSlot::new(loaded.snapshot);
    let mut rep = run_engine(session, &slot, ds, cfg)?;
    rep.load_secs = load_secs;
    Ok(rep)
}

/// Boot from the newest fully-verified segment in a directory and serve
/// with a `SnapshotWatcher` attached (`cce serve --snapshot-dir`): newer
/// generations written by a concurrent `cce train --snapshot-dir` run are
/// checksum-verified and hot-swapped in automatically; corrupt or torn
/// files are retried then skipped without disturbing the run.
pub fn serve_watch(
    session: &DlrmSession,
    dir: &Path,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<(ServeReport, WatcherReport)> {
    cfg.validate()?;
    let t_load = std::time::Instant::now();
    let Some((path, loaded)) = watcher::load_newest_verified(dir)? else {
        anyhow::bail!(
            "no usable segment in {} (none present, or none passed verification)",
            dir.display()
        );
    };
    let load_secs = t_load.elapsed().as_secs_f64();
    log::info!(
        "booting from {} (generation {}), watching {} for newer generations",
        path.display(),
        loaded.generation,
        dir.display()
    );
    let boot_generation = loaded.generation;
    let slot = Arc::new(SnapshotSlot::new(loaded.snapshot));
    let watcher = SnapshotWatcher::spawn(
        slot.clone(),
        WatcherConfig {
            dir: dir.to_path_buf(),
            poll: Duration::from_millis(cfg.watch_poll_ms),
            ..WatcherConfig::new(dir)
        },
        Some(boot_generation),
    );
    let engine_result = run_engine(session, &slot, ds, cfg);
    let watch_rep = watcher.stop();
    let mut rep = engine_result?;
    rep.load_secs = load_secs;
    Ok((rep, watch_rep))
}
