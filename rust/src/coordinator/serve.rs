//! Thin adapter from the coordinator to the `serving` subsystem: bake the
//! live `Indexer` into a `ServingSnapshot`, wire the session into a
//! `SessionExecutor`, and run the multi-worker engine.
//!
//! The old 92-line synchronous loop lived here; it replayed dataset batches,
//! padded every batch to `eval_batch`, dispatched through the training
//! indexer's per-lookup enum match, and charged each request the whole
//! burst's latency. All of that now lives — fixed — in `crate::serving`.

use crate::config::ServeConfig;
use crate::coordinator::trainer::Checkpoint;
use crate::data::synthetic::SyntheticDataset;
use crate::runtime::session::DlrmSession;
use crate::serving::{engine, EngineConfig, ServingSnapshot, SessionExecutor, TrafficGen};
use crate::tables::indexer::Indexer;
use anyhow::Result;

pub use crate::serving::ServeReport;

/// Serve `cfg.requests` Zipf-skewed synthetic queries over a trained
/// artifact through the multi-worker engine.
pub fn serve(
    session: &DlrmSession,
    indexer: &Indexer,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    cfg.validate()?;
    let t_bake = std::time::Instant::now();
    let snapshot = ServingSnapshot::bake(indexer);
    let bake_secs = t_bake.elapsed().as_secs_f64();
    let eval_batch = session.manifest.spec.eval_batch;
    let engine_cfg = EngineConfig {
        workers: cfg.workers,
        max_batch: if cfg.max_batch == 0 { eval_batch } else { cfg.max_batch },
        max_wait: cfg.max_wait(),
        queue_depth: cfg.queue_depth,
    };
    let traffic = TrafficGen::new(ds, cfg.zipf_skew, cfg.seed);
    let mut executor = SessionExecutor::new(session);
    let mut rep = engine::run(&mut executor, &snapshot, traffic, &engine_cfg, cfg.requests)?;
    rep.bake_secs = bake_secs;
    Ok(rep)
}

/// Serve from a trained checkpoint: upload the checkpoint's state and
/// bake its contemporaneous indexer (the pair is only valid together —
/// clustering events rewrite both). This is the ROADMAP "trained-weight
/// serving path": `cce serve --train-steps N` lands here instead of
/// serving a random-initialized model.
pub fn serve_trained(
    session: &mut DlrmSession,
    ckpt: &Checkpoint,
    ds: &SyntheticDataset,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    session.set_state(&ckpt.state)?;
    serve(session, &ckpt.indexer, ds, cfg)
}
