//! Batched inference serving loop (the `examples/serve.rs` backend).
//!
//! A toy but complete serving path: a request source emits single
//! (dense, cats) queries; the dynamic batcher packs up to `eval_batch`
//! requests (padding the remainder), runs `predict`, and records
//! end-to-end latency per request. This exercises exactly the deployment
//! shape the paper motivates — index lookup on CPU (Appendix E point 1),
//! model on the accelerator.

use crate::data::batch::{BatchIter, Split};
use crate::data::synthetic::SyntheticDataset;
use crate::runtime::session::{DlrmSession, EmbInput};
use crate::tables::indexer::{Indexer, MethodKind};
use crate::util::timer::{percentile, TimingStats};
use anyhow::Result;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
    /// per-request end-to-end latency
    pub latency: TimingStats,
    /// time spent in index generation (the CPU-side cost Appendix E argues
    /// is cheap) vs device execution
    pub index_secs: f64,
    pub exec_secs: f64,
}

/// Serve `n_requests` synthetic queries with dynamic batching of at most
/// `max_batch_wait` requests per batch (≤ the artifact's eval_batch).
pub fn serve(
    session: &DlrmSession,
    indexer: &Indexer,
    ds: &SyntheticDataset,
    n_requests: usize,
    batch_fill: usize,
) -> Result<ServeReport> {
    let eb = session.manifest.spec.eval_batch;
    let fill = batch_fill.clamp(1, eb);
    let mut it = BatchIter::new(ds, Split::Test, eb, None);
    let mut raw = it.alloc_batch();
    let mut rows = vec![0i32; session.emb_elems("predict")?];
    let mut hashes = vec![0f32; session.emb_elems("predict")?];
    let mut latencies = Vec::with_capacity(n_requests);
    let mut served = 0usize;
    let mut batches = 0usize;
    let mut index_secs = 0f64;
    let mut exec_secs = 0f64;
    let t_all = Instant::now();
    while served < n_requests {
        if !it.next_into(&mut raw) {
            it = BatchIter::new(ds, Split::Test, eb, None); // wrap around
            it.next_into(&mut raw);
        }
        let n_now = fill.min(n_requests - served).min(raw.real);
        let t_req = Instant::now(); // arrival of the whole burst
        let ti = Instant::now();
        match indexer.kind {
            MethodKind::RowWise => indexer.fill_rowwise(&raw.cats, eb, &mut rows),
            MethodKind::ElementWise => indexer.fill_elementwise(&raw.cats, eb, &mut rows),
            MethodKind::Dhe => indexer.fill_dhe(&raw.cats, eb, &mut hashes),
        }
        index_secs += ti.elapsed().as_secs_f64();
        let te = Instant::now();
        let _probs = match indexer.kind {
            MethodKind::Dhe => session.predict(&raw.dense, EmbInput::Hashes(&hashes))?,
            _ => session.predict(&raw.dense, EmbInput::Rows(&rows))?,
        };
        exec_secs += te.elapsed().as_secs_f64();
        let lat = t_req.elapsed().as_nanos() as f64;
        for _ in 0..n_now {
            latencies.push(lat);
        }
        served += n_now;
        batches += 1;
    }
    let elapsed = t_all.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let _ = percentile(&latencies, 0.5);
    Ok(ServeReport {
        requests: served,
        batches,
        elapsed_secs: elapsed,
        throughput_rps: served as f64 / elapsed,
        latency: TimingStats::from_samples(latencies),
        index_secs,
        exec_secs,
    })
}
