//! Producer/consumer batch pipeline with bounded backpressure.
//!
//! Worker threads generate raw host batches (zipf sampling is the
//! expensive part); the exec thread — which owns all PJRT objects — pulls
//! them in deterministic order. Workers are striped over batch indices and
//! each has its own bounded channel, so consumption order equals the
//! unsharded order regardless of worker timing.
//!
//! Index generation deliberately happens on the CONSUMER side: CCE
//! clustering events rewrite the index maps mid-epoch, and any indices
//! precomputed by producers would go stale (DESIGN.md §2-L3).

use crate::data::batch::{Batch, BatchIter, Split};
use crate::data::synthetic::SyntheticDataset;
use std::sync::mpsc::{sync_channel, Receiver};

pub struct BatchPipeline {
    rx: Vec<Receiver<Batch>>,
    next: usize,
    pub n_batches: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl BatchPipeline {
    /// Stream one epoch of `split` through `workers` producer threads with
    /// per-worker queue depth `depth`.
    pub fn start(
        ds: &SyntheticDataset,
        split: Split,
        batch_size: usize,
        shuffle_seed: Option<u64>,
        workers: usize,
        depth: usize,
    ) -> BatchPipeline {
        let workers = workers.max(1);
        let probe = BatchIter::new(ds, split, batch_size, shuffle_seed);
        let n_batches = probe.n_batches();
        let mut rx = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, r) = sync_channel::<Batch>(depth.max(1));
            rx.push(r);
            // each worker re-creates the iterator and skips to its stripe;
            // the dataset generator is cheap to clone conceptually but we
            // rebuild from the spec to keep the thread 'static
            let spec = ds.spec.clone();
            handles.push(std::thread::spawn(move || {
                let ds = SyntheticDataset::new(spec);
                let mut it = BatchIter::new(&ds, split, batch_size, shuffle_seed);
                let mut batch = it.alloc_batch();
                it.skip_batches(w); // jump to this worker's stripe
                while it.next_into(&mut batch) {
                    // send a fresh allocation; the consumer owns it
                    if tx.send(batch.clone()).is_err() {
                        return; // consumer dropped early (early stop)
                    }
                    it.skip_batches(workers - 1);
                }
            }));
        }
        BatchPipeline { rx, next: 0, n_batches, handles }
    }

    /// Next batch in deterministic order; None at end of epoch.
    pub fn next(&mut self) -> Option<Batch> {
        if self.next >= self.n_batches {
            return None;
        }
        let w = self.next % self.rx.len();
        self.next += 1;
        self.rx[w].recv().ok()
    }

    /// Batches handed out so far.
    pub fn consumed(&self) -> usize {
        self.next
    }
}

impl Drop for BatchPipeline {
    fn drop(&mut self) {
        // close receivers first so blocked producers exit
        self.rx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetSpec;

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50],
            n_dense: 3,
            train_samples: 130,
            val_samples: 16,
            test_samples: 16,
            latent_clusters: 4,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: 1,
        })
    }

    fn collect_serial(ds: &SyntheticDataset, shuffle: Option<u64>) -> Vec<Vec<f32>> {
        let mut it = BatchIter::new(ds, Split::Train, 16, shuffle);
        let mut b = it.alloc_batch();
        let mut out = Vec::new();
        while it.next_into(&mut b) {
            out.push(b.labels.clone());
        }
        out
    }

    #[test]
    fn pipeline_matches_serial_order() {
        let ds = ds();
        for shuffle in [None, Some(5)] {
            let want = collect_serial(&ds, shuffle);
            for workers in [1usize, 2, 4] {
                let mut p = BatchPipeline::start(&ds, Split::Train, 16, shuffle, workers, 2);
                let mut got = Vec::new();
                while let Some(b) = p.next() {
                    got.push(b.labels.clone());
                }
                assert_eq!(got, want, "workers={workers} shuffle={shuffle:?}");
            }
        }
    }

    #[test]
    fn early_drop_does_not_hang() {
        let ds = ds();
        let mut p = BatchPipeline::start(&ds, Split::Train, 16, None, 3, 1);
        let _ = p.next();
        drop(p); // must join cleanly with producers mid-stream
    }

    #[test]
    fn n_batches_reported() {
        let ds = ds();
        let p = BatchPipeline::start(&ds, Split::Train, 16, None, 2, 2);
        assert_eq!(p.n_batches, 130usize.div_ceil(16));
    }
}
