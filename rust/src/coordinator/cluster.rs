//! The CCE clustering event (Algorithm 3, `Cluster`):
//!
//! For every compressed feature `f` and column `j`:
//!   1. materialize the current embeddings `T[id] = Σ_t M_t[h_t(id)]` for
//!      the feature's vocabulary (the paper instead samples 256·k ids for
//!      the K-means itself — our K-means applies the same FAISS sampling
//!      rule internally, then assigns the full vocabulary);
//!   2. K-means them into `k_f` clusters;
//!   3. `h_0 ← assignments` (learned), `M_0 ← centroids`;
//!   4. `h_1 ← fresh random hash`, `M_1 ← 0`.
//!
//! The event is split into two phases so the trainer can overlap it with
//! continued training (CAFE-style background restructuring):
//!
//!   * [`compute_cluster`] — the expensive part (materialization +
//!     K-means). Pure function of a POOL-FIELD SNAPSHOT and an `Indexer`
//!     clone; safe to run on a `threadpool::BackgroundWorker` while
//!     training continues on the old maps.
//!   * [`apply_cluster`] — cheap and deterministic: writes centroids into
//!     the clustered term-0 subtable ranges, zeroes the helper ranges,
//!     and rewrites the live maps. Only the clustered subtable ranges of
//!     the pool are touched, so applying against a pool that has TRAINED
//!     PAST the snapshot is well-defined: untouched rows (identity
//!     features) keep their freshest values.
//!
//! Timeline of an overlapped event: snapshot pool + clone maps at step S
//! → background compute → at the first step boundary `S + n` where the
//! job is done, `apply_cluster` against the CURRENT pool. The `n` steps
//! in between trained on stale maps; [`ClusterOutcome::stale_steps`]
//! records that per event (0 in synchronous mode, where
//! [`cluster_event`] runs both phases back-to-back on the same state).
//!
//! Synchronous [`cluster_event`] mutates the pool range of the state
//! vector in place on the host; the caller re-uploads it afterwards.
//! With per-group device buffers, `DlrmSession::set_field` on the pool
//! field is a pure upload of the pool buffer — the dense layers never
//! cross the wire during an event. Features whose subtables are identity
//! (full tables under the cap) are skipped — clustering a lossless table
//! can only discard information.
//!
//! §Perf log, opt L3-2 (clustering-event hot path): materialization used
//! to walk `Indexer::global_row` per `(t, v)` lookup — an enum-dispatch
//! branch inside the innermost loop — and allocate a fresh `vocab × dc`
//! buffer per `(f, j)` job; results came back through a
//! `Vec<Mutex<Option<JobResult>>>`. Now each job flattens its T maps once
//! via `materialize_global_into` into a per-THREAD arena and runs a
//! branch-free gather-accumulate over all T terms per row, jobs collect
//! through the lock-free `par_map_with`, and the fused parallel K-means
//! (see `kmeans::lloyd`) gets the per-job thread budget that is left over
//! (remainder threads spread over the first jobs — every split yields the
//! same bits). Per-job results are bit-identical for any thread split, so
//! the event stays deterministic given the seed at any parallelism.
//! Before/after is tracked in `BENCH_cluster.json`
//! (benches/perf_cluster.rs); on the 16-core dev host the terabyte-ish
//! shape improved ~3.5–5× end-to-end and materialization alone ~4× (see
//! the bench's dispatch-vs-flat row).

use crate::kmeans::{kmeans, KmeansConfig};
use crate::runtime::manifest::FieldDesc;
use crate::tables::indexer::Indexer;
use crate::tables::layout::SubtableId;
use crate::util::{threadpool, Rng};
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub kmeans_iters: usize,
    pub points_per_centroid: usize,
    pub seed: u64,
    /// worker threads for the event; 0 = `default_threads()`. The outcome
    /// is bit-identical for every value.
    pub n_threads: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig { kmeans_iters: 20, points_per_centroid: 256, seed: 0, n_threads: 0 }
    }
}

#[derive(Clone, Debug, Default)]
pub struct ClusterOutcome {
    /// (feature, column) pairs actually clustered
    pub subtables_clustered: usize,
    /// total K-means objective across clustered subtables
    pub total_inertia: f64,
    /// compute + apply wall time (for an overlapped event the compute
    /// share ran concurrently with training, not as a stall)
    pub elapsed_secs: f64,
    /// CPU-seconds summed over jobs: embedding materialization (flat
    /// gather-accumulate) vs the K-means itself — the split the perf
    /// bench tracks
    pub materialize_secs: f64,
    pub kmeans_secs: f64,
    /// training steps executed between this event's pool snapshot and the
    /// apply of its new maps — 0 in synchronous mode, set by the trainer
    /// in overlapped mode
    pub stale_steps: usize,
}

/// Per-worker arenas reused across `(f, j)` jobs: the `vocab × dc` point
/// buffer and the `T × vocab` flat gather tables.
#[derive(Default)]
struct Scratch {
    pts: Vec<f32>,
    gather: Vec<u32>,
}

#[derive(Default)]
struct JobResult {
    assignments: Vec<u32>,
    centroids: Vec<f32>,
    inertia: f64,
    materialize_secs: f64,
    kmeans_secs: f64,
}

/// Everything the compute phase produced from one pool snapshot: the
/// per-(feature, column) K-means results plus the seed the apply phase
/// re-seeds the helper maps with. `Send` by construction so it can ride
/// back from a `threadpool::BackgroundWorker` job.
pub struct ClusterComputed {
    jobs: Vec<(usize, usize)>,
    results: Vec<JobResult>,
    seed: u64,
    /// wall time of the compute phase
    pub compute_secs: f64,
}

impl ClusterComputed {
    /// Number of (feature, column) subtables the compute phase clustered.
    pub fn n_jobs(&self) -> usize {
        self.jobs.len()
    }
}

/// Materialize `T[v] = Σ_t M_t[h_t(v)]` for one `(feature, column)` into
/// `scratch.pts` (returning the filled `vocab × dc` prefix): flatten each
/// term's map once, then one branch-free blocked gather-accumulate pass —
/// term 0 initializes each row, terms 1.. add onto it while the row is
/// hot in L1.
fn materialize_points<'a>(
    indexer: &Indexer,
    pool_data: &[f32],
    feature: usize,
    column: usize,
    scratch: &'a mut Scratch,
) -> &'a mut [f32] {
    let plan = &indexer.plan;
    let vocab = plan.vocabs[feature];
    let dc = plan.dc;
    let Scratch { pts, gather } = scratch;
    gather.resize(plan.t * vocab, 0);
    let gather = &mut gather[..plan.t * vocab];
    for t in 0..plan.t {
        let id = SubtableId { feature, term: t, column };
        indexer.materialize_global_into(id, &mut gather[t * vocab..][..vocab]);
    }
    pts.resize(vocab * dc, 0.0);
    let pts = &mut pts[..vocab * dc];
    let (term0, rest) = gather.split_at(vocab);
    for (v, dst) in pts.chunks_exact_mut(dc).enumerate() {
        dst.copy_from_slice(&pool_data[term0[v] as usize * dc..][..dc]);
        for tbl in rest.chunks_exact(vocab) {
            let src = &pool_data[tbl[v] as usize * dc..][..dc];
            for (de, &se) in dst.iter_mut().zip(src) {
                *de += se;
            }
        }
    }
    pts
}

/// The expensive phase of a clustering event: materialize + K-means every
/// compressed (feature, column) against a pool-field snapshot. Pure —
/// touches neither the live state nor the live maps, so it can run on a
/// background worker while training continues.
pub fn compute_cluster(
    pool_data: &[f32],
    indexer: &Indexer,
    cfg: &ClusterConfig,
) -> ClusterComputed {
    let t0 = Instant::now();
    let plan = &indexer.plan;
    assert!(plan.t >= 2, "clustering needs a helper table (T ≥ 2), got T={}", plan.t);
    let dc = plan.dc;
    assert_eq!(pool_data.len(), plan.total_rows * dc, "pool field does not match plan");

    // jobs: one per (feature, column) with a non-identity main map
    let jobs: Vec<(usize, usize)> = (0..plan.n_features())
        .filter(|&f| !indexer.is_identity(SubtableId { feature: f, term: 0, column: 0 }))
        .flat_map(|f| (0..plan.c).map(move |j| (f, j)))
        .collect();
    if jobs.is_empty() {
        return ClusterComputed {
            jobs,
            results: Vec::new(),
            seed: cfg.seed,
            compute_secs: t0.elapsed().as_secs_f64(),
        };
    }

    let threads =
        if cfg.n_threads == 0 { threadpool::default_threads() } else { cfg.n_threads };
    // few jobs → push the budget into each job's K-means, spreading the
    // remainder over the first `threads % jobs` jobs so no core idles;
    // many jobs → job-level parallelism only. Either split yields the
    // same bits (the fused K-means is thread-count-invariant).
    let inner_base = threads / jobs.len();
    let inner_rem = threads % jobs.len();

    let results: Vec<JobResult> = threadpool::par_map_with(
        jobs.len(),
        threads,
        Scratch::default,
        |scratch, ji| {
            let (f, j) = jobs[ji];
            let k = plan.subtable_rows(f);
            let inner_threads =
                if inner_base == 0 { 1 } else { inner_base + usize::from(ji < inner_rem) };
            let tm = Instant::now();
            let pts = materialize_points(indexer, pool_data, f, j, scratch);
            let materialize_secs = tm.elapsed().as_secs_f64();
            let tk = Instant::now();
            let res = kmeans(
                pts,
                dc,
                &KmeansConfig {
                    k,
                    n_iter: cfg.kmeans_iters,
                    max_points_per_centroid: cfg.points_per_centroid,
                    seed: cfg.seed ^ ((f as u64) << 20) ^ (j as u64),
                    n_threads: inner_threads,
                    ..Default::default()
                },
            );
            JobResult {
                assignments: res.assignments,
                centroids: res.centroids,
                inertia: res.inertia,
                materialize_secs,
                kmeans_secs: tk.elapsed().as_secs_f64(),
            }
        },
    );
    ClusterComputed { jobs, results, seed: cfg.seed, compute_secs: t0.elapsed().as_secs_f64() }
}

/// The cheap phase: write the computed centroids into the clustered
/// term-0 subtable ranges of `pool_data`, zero the helper ranges, replace
/// the live maps (learned term-0 assignments, fresh random helpers).
/// `pool_data` may have trained past the snapshot `computed` was built
/// from — only the clustered subtable ranges are overwritten.
pub fn apply_cluster(
    pool_data: &mut [f32],
    indexer: &mut Indexer,
    computed: ClusterComputed,
) -> ClusterOutcome {
    let t0 = Instant::now();
    let plan = indexer.plan.clone();
    let dc = plan.dc;
    assert_eq!(pool_data.len(), plan.total_rows * dc, "pool field does not match plan");
    let mut outcome = ClusterOutcome::default();
    // centroids → term-0 subtable, zeros → term-1.., maps updated
    let rng = Rng::new(computed.seed ^ 0xC1E5);
    for (&(f, j), r) in computed.jobs.iter().zip(computed.results) {
        let k = plan.subtable_rows(f);
        let main = SubtableId { feature: f, term: 0, column: j };
        let base0 = plan.subtable_base(main);
        // centroids may be fewer than k when vocab < k (kmeans clamps)
        let k_eff = r.centroids.len() / dc;
        let dst = &mut pool_data[base0 * dc..(base0 + k) * dc];
        dst.fill(0.0);
        dst[..k_eff * dc].copy_from_slice(&r.centroids);
        indexer.set_learned(main, r.assignments);
        for t in 1..plan.t {
            let helper = SubtableId { feature: f, term: t, column: j };
            let base = plan.subtable_base(helper);
            pool_data[base * dc..(base + k) * dc].fill(0.0);
            // the fork key carries (feature, column, term): distinct
            // columns MUST draw distinct random helper maps, or the
            // dynamic-hashing property degenerates column-wise (the old
            // `(f << 8) | t` key collided across columns)
            let key = ((f as u64) << 16) | ((j as u64) << 8) | t as u64;
            indexer.set_random(helper, &mut rng.fork(key));
        }
        outcome.subtables_clustered += 1;
        outcome.total_inertia += r.inertia;
        outcome.materialize_secs += r.materialize_secs;
        outcome.kmeans_secs += r.kmeans_secs;
    }
    outcome.elapsed_secs = computed.compute_secs + t0.elapsed().as_secs_f64();
    outcome
}

/// Run one synchronous clustering event over all compressed features:
/// both phases back-to-back against the pool range of `state`.
pub fn cluster_event(
    state: &mut [f32],
    pool: &FieldDesc,
    indexer: &mut Indexer,
    cfg: &ClusterConfig,
) -> ClusterOutcome {
    let pool_data = &mut state[pool.offset..pool.offset + pool.size];
    let computed = compute_cluster(pool_data, indexer, cfg);
    apply_cluster(pool_data, indexer, computed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::InitSpec;
    use crate::tables::layout::TablePlan;

    fn setup() -> (Vec<f32>, FieldDesc, Indexer) {
        let plan = TablePlan::new(&[5, 64], 8, 2, 2, 4); // f0 identity, f1 compressed
        let mut rng = Rng::new(0);
        let indexer = Indexer::new_rowwise(&mut rng, plan.clone());
        let pool_size = plan.total_rows * plan.dc;
        let mut state = vec![0f32; pool_size + 4];
        Rng::new(1).fill_normal(&mut state[..pool_size], 0.3);
        let field = FieldDesc {
            name: "pool".into(),
            shape: vec![plan.total_rows, plan.dc],
            offset: 0,
            size: pool_size,
            init: InitSpec::Normal(0.3),
            group: "pool".into(),
        };
        (state, field, indexer)
    }

    fn cfg() -> ClusterConfig {
        ClusterConfig { kmeans_iters: 20, points_per_centroid: 256, seed: 7, n_threads: 0 }
    }

    #[test]
    fn clusters_only_compressed_features() {
        let (mut state, field, mut ix) = setup();
        let out = cluster_event(&mut state, &field, &mut ix, &cfg());
        // feature 1 has c=2 columns; feature 0 is identity → skipped
        assert_eq!(out.subtables_clustered, 2);
        assert!(ix.is_learned(SubtableId { feature: 1, term: 0, column: 0 }));
        assert!(ix.is_learned(SubtableId { feature: 1, term: 0, column: 1 }));
        assert!(!ix.is_learned(SubtableId { feature: 1, term: 1, column: 0 }));
        assert!(ix.is_identity(SubtableId { feature: 0, term: 0, column: 0 }));
    }

    #[test]
    fn helper_tables_are_zeroed() {
        let (mut state, field, mut ix) = setup();
        cluster_event(&mut state, &field, &mut ix, &cfg());
        let plan = ix.plan.clone();
        for j in 0..plan.c {
            let helper = SubtableId { feature: 1, term: 1, column: j };
            let base = plan.subtable_base(helper);
            let k = plan.subtable_rows(1);
            assert!(
                state[base * plan.dc..(base + k) * plan.dc].iter().all(|&x| x == 0.0),
                "helper subtable {j} not zeroed"
            );
        }
    }

    #[test]
    fn helper_maps_differ_across_columns() {
        // regression: the helper re-seed fork key used to be
        // `(f << 8) | t` — identical for every column of a feature, so
        // after each event the "fresh random" maps of a c ≥ 2 plan were
        // the SAME map repeated per column (breaking the dynamic-hashing
        // property of Shi et al.'s compositional embeddings). The key now
        // carries the column.
        let (mut state, field, mut ix) = setup();
        cluster_event(&mut state, &field, &mut ix, &cfg());
        let h0 = ix.materialize(SubtableId { feature: 1, term: 1, column: 0 });
        let h1 = ix.materialize(SubtableId { feature: 1, term: 1, column: 1 });
        assert_ne!(h0, h1, "helper maps identical across columns — fork key lost the column");
    }

    #[test]
    fn flat_gather_matches_per_lookup_dispatch() {
        // the materialization rework contract: the arena'd flat-gather
        // pass must reproduce the per-(t, v) `global_row` walk bit-for-bit
        let (state, _, ix) = setup();
        let plan = ix.plan.clone();
        let pool = &state[..plan.total_rows * plan.dc];
        let mut scratch = Scratch::default();
        for j in 0..plan.c {
            let fast = materialize_points(&ix, pool, 1, j, &mut scratch).to_vec();
            let mut slow = vec![0f32; plan.vocabs[1] * plan.dc];
            for t in 0..plan.t {
                let id = SubtableId { feature: 1, term: t, column: j };
                for v in 0..plan.vocabs[1] as u32 {
                    let row = ix.global_row(id, v) as usize;
                    for e in 0..plan.dc {
                        slow[v as usize * plan.dc + e] += pool[row * plan.dc + e];
                    }
                }
            }
            assert_eq!(fast, slow, "column {j}");
        }
    }

    #[test]
    fn embedding_continuity_ids_keep_close_vectors() {
        // after clustering, each id's embedding must equal its cluster
        // centroid, which K-means guarantees is close to the pre-cluster
        // embedding (that is the whole point of CCE's cluster step)
        let (mut state, field, mut ix) = setup();
        let plan = ix.plan.clone();
        let dc = plan.dc;
        // embeddings before
        let emb = |state: &[f32], ix: &Indexer, v: u32, j: usize| -> Vec<f32> {
            let mut e = vec![0f32; dc];
            for t in 0..plan.t {
                let row =
                    ix.global_row(SubtableId { feature: 1, term: t, column: j }, v) as usize;
                for d in 0..dc {
                    e[d] += state[row * dc + d];
                }
            }
            e
        };
        let before: Vec<Vec<f32>> = (0..64).map(|v| emb(&state, &ix, v, 0)).collect();
        cluster_event(&mut state, &field, &mut ix, &cfg());
        let after: Vec<Vec<f32>> = (0..64).map(|v| emb(&state, &ix, v, 0)).collect();
        // mean drift must be smaller than mean embedding norm (continuity)
        let drift: f32 = (0..64)
            .map(|v| {
                before[v]
                    .iter()
                    .zip(&after[v])
                    .map(|(a, b)| (a - b).powi(2))
                    .sum::<f32>()
                    .sqrt()
            })
            .sum::<f32>()
            / 64.0;
        let scale: f32 =
            before.iter().map(|e| e.iter().map(|x| x * x).sum::<f32>().sqrt()).sum::<f32>() / 64.0;
        assert!(drift < scale, "drift {drift} vs scale {scale}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut s1, f1, mut i1) = setup();
        let (mut s2, f2, mut i2) = setup();
        cluster_event(&mut s1, &f1, &mut i1, &cfg());
        cluster_event(&mut s2, &f2, &mut i2, &cfg());
        assert_eq!(s1, s2);
        let id = SubtableId { feature: 1, term: 0, column: 0 };
        assert_eq!(i1.materialize(id), i2.materialize(id));
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // flat-gather path + fused K-means: sweeping the worker count
        // (and with it the job/inner thread split — including RAGGED
        // splits where threads % jobs != 0 and the remainder spreads over
        // the first jobs) must not move a bit
        let (mut s1, f1, mut i1) = setup();
        let base_cfg = ClusterConfig { n_threads: 1, ..cfg() };
        let base_out = cluster_event(&mut s1, &f1, &mut i1, &base_cfg);
        // 2 jobs here: 3, 5, 7 exercise the ragged remainder path
        for threads in [2, 3, 5, 7, 8] {
            let (mut s2, f2, mut i2) = setup();
            let tcfg = ClusterConfig { n_threads: threads, ..cfg() };
            let out = cluster_event(&mut s2, &f2, &mut i2, &tcfg);
            assert_eq!(s1, s2, "{threads} threads");
            assert!(out.total_inertia == base_out.total_inertia, "{threads} threads");
            for j in 0..i1.plan.c {
                let id = SubtableId { feature: 1, term: 0, column: j };
                assert_eq!(i1.materialize(id), i2.materialize(id), "{threads} threads col {j}");
                let helper = SubtableId { feature: 1, term: 1, column: j };
                assert_eq!(i1.materialize(helper), i2.materialize(helper), "{threads} threads");
            }
        }
    }

    #[test]
    fn split_phases_match_synchronous_event() {
        // compute-on-snapshot + apply must equal the one-shot event when
        // nothing trains in between (the overlap refactor's base case)
        let (mut s1, f1, mut i1) = setup();
        cluster_event(&mut s1, &f1, &mut i1, &cfg());
        let (mut s2, f2, mut i2) = setup();
        let snapshot = s2[f2.offset..f2.offset + f2.size].to_vec();
        let computed = compute_cluster(&snapshot, &i2, &cfg());
        assert_eq!(computed.n_jobs(), 2);
        apply_cluster(&mut s2[f2.offset..f2.offset + f2.size], &mut i2, computed);
        assert_eq!(s1, s2);
        for id in i1.plan.clone().subtables() {
            assert_eq!(i1.materialize(id), i2.materialize(id), "{id:?}");
        }
    }

    #[test]
    fn apply_patches_only_clustered_ranges() {
        // overlap semantics: the pool may train past the snapshot; apply
        // must overwrite ONLY the clustered subtable ranges and keep the
        // drifted values everywhere else (identity feature 0 here)
        let (mut state, field, mut ix) = setup();
        let snapshot = state[..field.size].to_vec();
        let computed = compute_cluster(&snapshot, &ix, &cfg());
        // drift the live pool as if training continued
        for v in state[..field.size].iter_mut() {
            *v += 1.5;
        }
        let drifted = state[..field.size].to_vec();
        apply_cluster(&mut state[..field.size], &mut ix, computed);
        let plan = ix.plan.clone();
        let dc = plan.dc;
        // feature 0 (identity, never clustered) keeps the drifted values
        for t in 0..plan.t {
            for j in 0..plan.c {
                let id = SubtableId { feature: 0, term: t, column: j };
                let base = plan.subtable_base(id);
                let rows = plan.subtable_rows(0);
                assert_eq!(
                    state[base * dc..(base + rows) * dc],
                    drifted[base * dc..(base + rows) * dc],
                    "unclustered range {id:?} was touched by apply"
                );
            }
        }
        // feature 1 helpers zeroed, term 0 rewritten from the SNAPSHOT's
        // clustering (not the drifted pool)
        let k = plan.subtable_rows(1);
        for j in 0..plan.c {
            let helper = SubtableId { feature: 1, term: 1, column: j };
            let hb = plan.subtable_base(helper);
            assert!(state[hb * dc..(hb + k) * dc].iter().all(|&x| x == 0.0), "helper {j}");
            let main = SubtableId { feature: 1, term: 0, column: j };
            let mb = plan.subtable_base(main);
            assert_ne!(
                state[mb * dc..(mb + k) * dc],
                drifted[mb * dc..(mb + k) * dc],
                "main {j} not rewritten"
            );
        }
    }

    #[test]
    #[should_panic(expected = "helper table")]
    fn rejects_single_term_plans() {
        let plan = TablePlan::new(&[64], 8, 1, 2, 4);
        let mut rng = Rng::new(0);
        let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
        let mut state = vec![0f32; plan.total_rows * plan.dc];
        let field = FieldDesc {
            name: "pool".into(),
            shape: vec![plan.total_rows, plan.dc],
            offset: 0,
            size: state.len(),
            init: InitSpec::Zeros,
            group: "pool".into(),
        };
        cluster_event(&mut state, &field, &mut ix, &cfg());
    }
}
