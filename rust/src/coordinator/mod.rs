//! The L3 coordinator — the paper's system contribution: interleaving
//! K-means re-clustering of the sketch with normal training, plus the
//! producer/consumer training pipeline, evaluation, early stopping, and a
//! small serving loop.

pub mod cluster;
pub mod eval;
pub mod pipeline;
pub mod serve;
pub mod trainer;

pub use cluster::{
    apply_cluster, cluster_event, compute_cluster, ClusterComputed, ClusterConfig, ClusterOutcome,
};
pub use trainer::{train, Checkpoint, TrainOutcome};
