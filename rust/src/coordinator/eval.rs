//! Split evaluation: stream a split through the `predict` executable and
//! accumulate BCE/AUC over the real (non-padding) rows only.

use crate::data::batch::{BatchIter, Split};
use crate::data::synthetic::SyntheticDataset;
use crate::metrics::EvalAccumulator;
use crate::runtime::session::{DlrmSession, EmbInput};
use crate::tables::indexer::{Indexer, MethodKind};
use anyhow::Result;

/// Evaluate `split`; returns the filled accumulator.
pub fn evaluate(
    session: &DlrmSession,
    indexer: &Indexer,
    ds: &SyntheticDataset,
    split: Split,
) -> Result<EvalAccumulator> {
    let eb = session.manifest.spec.eval_batch;
    let mut it = BatchIter::new(ds, split, eb, None);
    let mut batch = it.alloc_batch();
    let mut acc = EvalAccumulator::new();
    let mut rows = vec![0i32; session.emb_elems("predict").unwrap_or(0).max(1)];
    let mut hashes = vec![0f32; rows.len()];
    while it.next_into(&mut batch) {
        let probs = match indexer.kind {
            MethodKind::RowWise => {
                indexer.fill_rowwise(&batch.cats, eb, &mut rows);
                session.predict(&batch.dense, EmbInput::Rows(&rows))?
            }
            MethodKind::ElementWise => {
                indexer.fill_elementwise(&batch.cats, eb, &mut rows);
                session.predict(&batch.dense, EmbInput::Rows(&rows))?
            }
            MethodKind::Dhe => {
                indexer.fill_dhe(&batch.cats, eb, &mut hashes);
                session.predict(&batch.dense, EmbInput::Hashes(&hashes))?
            }
        };
        acc.push(&probs[..batch.real], &batch.labels[..batch.real]);
    }
    Ok(acc)
}
