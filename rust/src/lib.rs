//! # CCE — Clustered Compositional Embeddings
//!
//! Production-shaped reproduction of *"Clustering the Sketch: Dynamic
//! Compression for Embedding Tables"* (Tsang & Ahle) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2 (build time)**: `python/compile/` lowers the DLRM model
//!   with Pallas embedding/interaction/K-means kernels to HLO text.
//! * **Layer 3 (this crate)**: the coordinator — synthetic Criteo-like
//!   data, per-method index generation, the CCE clustering scheduler,
//!   training/eval loops over the PJRT runtime, and the paper's
//!   experiment harness.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record.
//!
//! Unsafe/atomics policy: see `docs/UNSAFE_POLICY.md` and run
//! `scripts/analyze.sh` — every `unsafe` needs a `// SAFETY:` comment,
//! every atomic `Ordering` a `// ORDERING:` justification.

// Every unsafe operation must sit in an explicit `unsafe { }` block with
// its own SAFETY comment, even inside `unsafe fn` bodies.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod baselines;
pub mod cce;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod hashing;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod serving;
pub mod tables;
pub mod testutil;
pub mod util;
