//! Baseline compression methods.
//!
//! Most of the zoo (full / hashing trick / hash embeddings / CE / ROBE /
//! DHE) needs no code beyond `tables::Indexer` — the methods differ only
//! in (T, c, cap) and index semantics, exactly the paper's §2.1 framing.
//! This module holds the two baselines that need real machinery:
//! post-training Product Quantization and the "circular clustering"
//! negative result from Appendix A/H.

pub mod circular;
pub mod pq;

pub use circular::circular_cluster_event;
pub use pq::{pq_quantize_pool, PqReport};
