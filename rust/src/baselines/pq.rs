//! Post-training Product Quantization of a trained (full) embedding pool.
//!
//! The paper's PQ baseline: train the FULL model, then quantize each
//! feature's table — split dim `d` into `c` blocks, K-means each block's
//! rows into `k` codewords, replace each row block by its codeword. The
//! quantized table is written back into the same state vector, so the
//! unmodified `predict` executable evaluates the compressed model (no
//! fine-tuning, which the paper found to overfit immediately).

use crate::kmeans::{kmeans, KmeansConfig};
use crate::runtime::manifest::FieldDesc;
use crate::tables::layout::{SubtableId, TablePlan};
use crate::util::threadpool;

#[derive(Clone, Debug, Default)]
pub struct PqReport {
    /// codebook parameters after quantization (centroids)
    pub codebook_params: usize,
    /// index-pointer entries (one per value per block)
    pub index_entries: usize,
    /// parameters of the original full table
    pub full_params: usize,
    /// total K-means reconstruction error
    pub inertia: f64,
}

impl PqReport {
    /// Compression counting codebook + 16-bit pointers in f32 units
    /// (2 bytes per pointer = ½ f32), the accounting Appendix E suggests.
    pub fn compression(&self) -> f64 {
        self.full_params as f64 / (self.codebook_params as f64 + self.index_entries as f64 * 0.5)
    }
}

/// Quantize a full-table pool in place.
///
/// `plan` must be the full-table plan (t=1, c=1, cap=∞): each feature's
/// subtable has `vocab` rows of width d. `k` is the codewords per block
/// and `c_blocks` the number of d/c blocks (the paper's c=4).
pub fn pq_quantize_pool(
    state: &mut [f32],
    pool: &FieldDesc,
    plan: &TablePlan,
    k: usize,
    c_blocks: usize,
    kmeans_iters: usize,
    seed: u64,
) -> PqReport {
    assert_eq!(plan.t, 1, "PQ baseline runs on the full-table plan");
    assert_eq!(plan.c, 1);
    let d = plan.dc;
    assert_eq!(d % c_blocks, 0, "dim {d} not divisible by {c_blocks} blocks");
    let db = d / c_blocks;
    let pool_data_off = pool.offset;

    struct Job {
        feature: usize,
        block: usize,
    }
    let jobs: Vec<Job> = (0..plan.n_features())
        .flat_map(|f| (0..c_blocks).map(move |b| Job { feature: f, block: b }))
        .collect();

    // phase 1 (parallel, read-only): cluster every (feature, block);
    // results collect through the lock-free ordered `par_map`. The inner
    // kmeans gets whatever thread budget the job fan-out leaves over
    // (same split as `cluster_event`; the result is budget-invariant)
    let pool_snapshot = state[pool.offset..pool.offset + pool.size].to_vec();
    let threads = threadpool::default_threads();
    let inner_threads = (threads / jobs.len().max(1)).max(1);
    let results: Vec<(Vec<u32>, Vec<f32>, f64, usize)> =
        threadpool::par_map(jobs.len(), threads, |ji| {
            let Job { feature, block } = jobs[ji];
            let vocab = plan.vocabs[feature];
            let base = plan.subtable_base(SubtableId { feature, term: 0, column: 0 });
            let k_eff = k.min(vocab);
            let mut pts = vec![0f32; vocab * db];
            for v in 0..vocab {
                let row = &pool_snapshot[(base + v) * d + block * db..][..db];
                pts[v * db..(v + 1) * db].copy_from_slice(row);
            }
            let res = kmeans(
                &pts,
                db,
                &KmeansConfig {
                    k: k_eff,
                    n_iter: kmeans_iters,
                    seed: seed ^ ((feature as u64) << 16) ^ block as u64,
                    n_threads: inner_threads,
                    ..Default::default()
                },
            );
            (res.assignments, res.centroids, res.inertia, k_eff)
        });

    // phase 2 (serial): write the quantized rows back
    let mut report = PqReport { full_params: plan.params(), ..Default::default() };
    for (ji, (assign, centroids, inertia, k_eff)) in results.into_iter().enumerate() {
        let Job { feature, block } = jobs[ji];
        let vocab = plan.vocabs[feature];
        let base = plan.subtable_base(SubtableId { feature, term: 0, column: 0 });
        for v in 0..vocab {
            let cw = &centroids[assign[v] as usize * db..][..db];
            let dst_off = pool_data_off + (base + v) * d + block * db;
            state[dst_off..dst_off + db].copy_from_slice(cw);
        }
        report.codebook_params += k_eff * db;
        report.index_entries += vocab;
        report.inertia += inertia;
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::InitSpec;
    use crate::util::Rng;

    fn setup(vocabs: &[usize], d: usize) -> (Vec<f32>, FieldDesc, TablePlan) {
        let plan = TablePlan::new(vocabs, usize::MAX, 1, 1, d);
        let size = plan.total_rows * d;
        let mut state = vec![0f32; size];
        Rng::new(3).fill_normal(&mut state, 1.0);
        let field = FieldDesc {
            name: "pool".into(),
            shape: vec![plan.total_rows, d],
            offset: 0,
            size,
            init: InitSpec::Zeros,
            group: "pool".into(),
        };
        (state, field, plan)
    }

    #[test]
    fn quantized_rows_come_from_codebook() {
        let (mut state, field, plan) = setup(&[40], 8);
        pq_quantize_pool(&mut state, &field, &plan, 4, 2, 20, 0);
        // per block, at most 4 distinct rows remain
        for block in 0..2 {
            let mut uniq = std::collections::HashSet::new();
            for v in 0..40 {
                let row: Vec<u32> = state[v * 8 + block * 4..v * 8 + block * 4 + 4]
                    .iter()
                    .map(|x| x.to_bits())
                    .collect();
            uniq.insert(row);
            }
            assert!(uniq.len() <= 4, "block {block}: {} uniques", uniq.len());
        }
    }

    #[test]
    fn reconstruction_error_decreases_with_k() {
        let (state, field, plan) = setup(&[100], 8);
        let mut s2 = state.clone();
        let r2 = pq_quantize_pool(&mut s2, &field, &plan, 2, 2, 20, 0);
        let mut s16 = state.clone();
        let r16 = pq_quantize_pool(&mut s16, &field, &plan, 16, 2, 20, 0);
        assert!(r16.inertia < r2.inertia);
    }

    #[test]
    fn report_accounting() {
        let (mut state, field, plan) = setup(&[50, 30], 8);
        let r = pq_quantize_pool(&mut state, &field, &plan, 8, 4, 10, 1);
        assert_eq!(r.full_params, 80 * 8);
        assert_eq!(r.codebook_params, 2 * 4 * 8 * 2); // 2 features × 4 blocks × 8 cw × 2 dims
        assert_eq!(r.index_entries, 4 * 80);
        assert!(r.compression() > 1.0);
    }

    #[test]
    fn small_vocab_clamps_codewords() {
        let (mut state, field, plan) = setup(&[3], 4);
        let r = pq_quantize_pool(&mut state, &field, &plan, 8, 2, 10, 2);
        assert_eq!(r.codebook_params, 2 * 3 * 2); // k clamped to vocab=3
        assert!(r.inertia < 1e-9); // 3 points, 3 clusters → exact
    }
}
