//! "Circular clustering" — the Appendix A/H negative result, kept as a
//! baseline because the paper's table-collapse diagnostics (H₁/H₂) are
//! defined by it.
//!
//! Instead of clustering each column's own d/c-dimensional embeddings,
//! circular clustering clusters every column on the FULL d-dimensional
//! embedding. All columns then see (nearly) the same geometry, so their
//! index-pointer tables come out (nearly) identical — pairwise entropy H₂
//! collapses toward H₁ and the method degenerates to the hashing trick.

use crate::coordinator::cluster::ClusterConfig;
use crate::kmeans::{kmeans, KmeansConfig};
use crate::runtime::manifest::FieldDesc;
use crate::tables::indexer::Indexer;
use crate::tables::layout::SubtableId;
use crate::util::Rng;

/// Like `coordinator::cluster_event`, but clustering every column on the
/// concatenated full-dim embedding (the failure mode under study).
pub fn circular_cluster_event(
    state: &mut [f32],
    pool: &FieldDesc,
    indexer: &mut Indexer,
    cfg: &ClusterConfig,
) {
    let plan = indexer.plan.clone();
    assert!(plan.t >= 2);
    let dc = plan.dc;
    let d = dc * plan.c;
    let pool_data = state[pool.offset..pool.offset + pool.size].to_vec();
    let rng = Rng::new(cfg.seed ^ 0xC19C);

    for f in 0..plan.n_features() {
        if indexer.is_identity(SubtableId { feature: f, term: 0, column: 0 }) {
            continue;
        }
        let vocab = plan.vocabs[f];
        let k = plan.subtable_rows(f);
        // full-dim embeddings: concat over columns of Σ_t subtable rows
        let mut pts = vec![0f32; vocab * d];
        for j in 0..plan.c {
            for t in 0..plan.t {
                let id = SubtableId { feature: f, term: t, column: j };
                for v in 0..vocab as u32 {
                    let row = indexer.global_row(id, v) as usize;
                    let src = &pool_data[row * dc..(row + 1) * dc];
                    let dst = &mut pts[v as usize * d + j * dc..][..dc];
                    for e in 0..dc {
                        dst[e] += src[e];
                    }
                }
            }
        }
        // ONE clustering of the full vectors...
        let res = kmeans(
            &pts,
            d,
            &KmeansConfig {
                k,
                n_iter: cfg.kmeans_iters,
                max_points_per_centroid: cfg.points_per_centroid,
                seed: cfg.seed ^ (f as u64) << 20,
                n_threads: cfg.n_threads,
                ..Default::default()
            },
        );
        // ...applied to EVERY column: identical index-pointer functions,
        // centroids projected onto each column's block
        for j in 0..plan.c {
            let main = SubtableId { feature: f, term: 0, column: j };
            let base0 = plan.subtable_base(main);
            let k_eff = res.centroids.len() / d;
            let dst = &mut state[pool.offset + base0 * dc..pool.offset + (base0 + k) * dc];
            dst.fill(0.0);
            for cw in 0..k_eff {
                dst[cw * dc..(cw + 1) * dc]
                    .copy_from_slice(&res.centroids[cw * d + j * dc..][..dc]);
            }
            indexer.set_learned(main, res.assignments.clone());
            for t in 1..plan.t {
                let helper = SubtableId { feature: f, term: t, column: j };
                let base = plan.subtable_base(helper);
                state[pool.offset + base * dc..pool.offset + (base + k) * dc].fill(0.0);
                indexer.set_random(helper, &mut rng.fork((f as u64) << 8 | (t * 7 + j) as u64));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::cluster_event;
    use crate::metrics::entropy::{h1, h2};
    use crate::runtime::manifest::InitSpec;
    use crate::tables::layout::TablePlan;

    fn setup() -> (Vec<f32>, FieldDesc, Indexer) {
        let plan = TablePlan::new(&[512], 16, 2, 4, 4);
        let mut rng = Rng::new(0);
        let indexer = Indexer::new_rowwise(&mut rng, plan.clone());
        let size = plan.total_rows * plan.dc;
        let mut state = vec![0f32; size];
        Rng::new(1).fill_normal(&mut state, 0.5);
        let field = FieldDesc {
            name: "pool".into(),
            shape: vec![plan.total_rows, plan.dc],
            offset: 0,
            size,
            init: InitSpec::Zeros,
            group: "pool".into(),
        };
        (state, field, indexer)
    }

    fn cfg() -> ClusterConfig {
        ClusterConfig { kmeans_iters: 25, points_per_centroid: 256, seed: 9, n_threads: 0 }
    }

    #[test]
    fn circular_collapses_pairwise_entropy() {
        // the Appendix H table: circular clustering's H2 ≈ H1 (collapse),
        // per-column CCE keeps H2 well above H1
        let (mut s1, f1, mut ix1) = setup();
        circular_cluster_event(&mut s1, &f1, &mut ix1, &cfg());
        let tables_circ: Vec<Vec<u32>> = (0..4)
            .map(|j| ix1.materialize(SubtableId { feature: 0, term: 0, column: j }))
            .collect();
        let (h1c, h2c) = (h1(&tables_circ), h2(&tables_circ));

        let (mut s2, f2, mut ix2) = setup();
        cluster_event(&mut s2, &f2, &mut ix2, &cfg());
        let tables_cce: Vec<Vec<u32>> = (0..4)
            .map(|j| ix2.materialize(SubtableId { feature: 0, term: 0, column: j }))
            .collect();
        let (h1p, h2p) = (h1(&tables_cce), h2(&tables_cce));

        // circular: identical columns → pair entropy == column entropy
        assert!(h2c - h1c < 0.05, "circular H2 {h2c} vs H1 {h1c} — should collapse");
        // per-column CCE: independent clusterings → extra pair information
        assert!(h2p - h1p > 0.3, "cce H2 {h2p} vs H1 {h1p} — should NOT collapse");
    }
}
