//! K-means for the CCE clustering events (paper: FAISS with
//! `max_points_per_centroid=256`, `niter=50`; here: our own kmeans++ /
//! Lloyd with the same sampling rule, parallel over the thread pool).
//!
//! §Perf log, opt L3-2 (clustering-event rework): every reduction in this
//! module runs over FIXED `ACC_CHUNK`-point chunks whose partial results
//! are merged in ascending chunk order. The chunk tree is part of the
//! algorithm contract: it makes `assign`/`inertia`/`kmeans` bit-identical
//! for ANY worker-thread count (the chunk decomposition depends only on
//! `n`, never on how chunks land on threads), which is what lets
//! `cluster_event` pick per-job thread budgets freely while
//! `deterministic_given_seed` keeps passing bit-exactly. See
//! `tests/proptests.rs::prop_fused_lloyd_bit_identical_to_scalar_reference`
//! for the scalar pin and `benches/perf_cluster.rs` (`BENCH_cluster.json`)
//! for the tracked before/after numbers; on the 16-core dev host the
//! `perf_hot_paths` kmeans row (65k pts, d=4, k=4096, 10 iters) went from
//! ~2.9s serial-update to ~0.6s fused (~4.8×).

mod lloyd;

pub use lloyd::{kmeans, KmeansConfig, KmeansResult};

use crate::util::threadpool::{self, SharedSlice};

/// Points per accumulation chunk for every deterministic parallel
/// reduction (centroid sums, kmeans++ weights, inertia). Fixed — NOT a
/// function of the thread count — so partial-merge order, and therefore
/// every last floating-point bit, is identical at any parallelism.
pub const ACC_CHUNK: usize = 4096;

/// Centroid block width for the transposed-distance kernel:
/// `ASSIGN_BLOCK * (d + 1)` f32 stays in L1 (§Perf log, opt L3-1).
pub const ASSIGN_BLOCK: usize = 512;

/// Staged centroids for nearest-centroid queries: transposed layout
/// (`ct[e*k + j]`) plus ½‖c‖² per centroid, so the per-point inner loops
/// run unit-stride over `j` and autovectorize — ~6× over the naive
/// per-point dot-product loop at the embedding dims (d ≤ 16) this system
/// uses (§Perf log, opt L3-1). Staging once per Lloyd iteration also lets
/// the fused assignment/accumulation pass share one kernel with `assign`.
pub struct AssignStage {
    ct: Vec<f32>,
    half_norms: Vec<f32>,
    k: usize,
    d: usize,
}

impl AssignStage {
    pub fn new(centroids: &[f32], d: usize) -> AssignStage {
        let k = centroids.len() / d;
        assert_eq!(centroids.len(), k * d);
        assert!(k > 0);
        let mut ct = vec![0f32; k * d];
        let mut half_norms = vec![0f32; k];
        for j in 0..k {
            let c = &centroids[j * d..(j + 1) * d];
            half_norms[j] = 0.5 * c.iter().map(|v| v * v).sum::<f32>();
            for e in 0..d {
                ct[e * k + j] = c[e];
            }
        }
        AssignStage { ct, half_norms, k, d }
    }

    /// Nearest centroid of one point (squared L2, ties → lowest index)
    /// plus its squared distance (clamped ≥ 0 against half-distance
    /// cancellation). `dist` is caller-provided scratch so hot loops keep
    /// it on the stack.
    #[inline]
    pub fn nearest(&self, x: &[f32], dist: &mut [f32; ASSIGN_BLOCK]) -> (u32, f32) {
        let (k, d) = (self.k, self.d);
        debug_assert_eq!(x.len(), d);
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        let mut j0 = 0;
        while j0 < k {
            let jb = ASSIGN_BLOCK.min(k - j0);
            let dist = &mut dist[..jb];
            dist.copy_from_slice(&self.half_norms[j0..j0 + jb]);
            for (e2, &xe) in x.iter().enumerate() {
                let row = &self.ct[e2 * k + j0..e2 * k + j0 + jb];
                // unit-stride over j: vectorizes
                for (dj, &cj) in dist.iter_mut().zip(row) {
                    *dj -= xe * cj;
                }
            }
            // two-pass argmin: a branchless vectorizable min-reduce,
            // then a positional scan only when the block improves on
            // the running best (rare after the first blocks)
            let block_min = {
                // 8-lane min accumulator: vectorizes where the scalar
                // fold's sequential dependency chain cannot
                let mut lanes = [f32::INFINITY; 8];
                let mut it = dist.chunks_exact(8);
                for ch in &mut it {
                    for (l, &v) in lanes.iter_mut().zip(ch) {
                        *l = l.min(v);
                    }
                }
                let mut m = it.remainder().iter().copied().fold(f32::INFINITY, f32::min);
                for l in lanes {
                    m = m.min(l);
                }
                m
            };
            if block_min < best_d {
                best_d = block_min;
                let jj = dist.iter().position(|&dj| dj == block_min).unwrap();
                best = (j0 + jj) as u32;
            }
            j0 += jb;
        }
        // best_d is ½‖x−c‖² − ½‖x‖²; restore the true squared distance
        let x_norm: f32 = x.iter().map(|v| v * v).sum();
        (best, (2.0 * best_d + x_norm).max(0.0))
    }
}

/// Assign each point to its nearest centroid (squared L2, ties → lowest
/// index). `points: [n, d]`, `centroids: [k, d]` row-major.
pub fn assign(points: &[f32], centroids: &[f32], d: usize, out: &mut [u32]) {
    assign_t(points, centroids, d, out, threadpool::default_threads());
}

/// `assign` with an explicit worker-thread count. Per-point work is
/// independent, so the result is identical for every `n_threads`.
pub fn assign_t(points: &[f32], centroids: &[f32], d: usize, out: &mut [u32], n_threads: usize) {
    let n = points.len() / d;
    assert_eq!(points.len(), n * d);
    assert_eq!(out.len(), n);
    let stage = AssignStage::new(centroids, d);
    let out_s = SharedSlice::new(out);
    threadpool::scope_chunks(n, n_threads, |_, s, e| {
        // SAFETY: scope_chunks hands each worker a distinct [s, e) range
        // with e <= n == out_s.len(), so the chunk slices are disjoint.
        let out = unsafe { out_s.range_mut(s, e - s) };
        let mut dist = [0f32; ASSIGN_BLOCK];
        for (slot, i) in out.iter_mut().zip(s..e) {
            *slot = stage.nearest(&points[i * d..(i + 1) * d], &mut dist).0;
        }
    });
}

/// Sum of squared distances to assigned centroids (the K-means objective).
/// Chunk-parallel with ordered partial merge — deterministic for any
/// thread count (and for the same reason no longer bit-equal to the old
/// single-accumulator serial sum; every consumer compares inertia with
/// tolerances or against itself).
pub fn inertia(points: &[f32], centroids: &[f32], d: usize, assignments: &[u32]) -> f64 {
    inertia_t(points, centroids, d, assignments, threadpool::default_threads())
}

/// `inertia` with an explicit worker-thread count.
pub fn inertia_t(
    points: &[f32],
    centroids: &[f32],
    d: usize,
    assignments: &[u32],
    n_threads: usize,
) -> f64 {
    let n = points.len() / d;
    assert_eq!(assignments.len(), n);
    let n_chunks = n.div_ceil(ACC_CHUNK).max(1);
    let partials = threadpool::par_map(n_chunks, n_threads, |c| {
        let (s, e) = (c * ACC_CHUNK, ((c + 1) * ACC_CHUNK).min(n));
        let mut acc = 0f64;
        for i in s..e {
            let x = &points[i * d..(i + 1) * d];
            let c = &centroids[assignments[i] as usize * d..][..d];
            let mut s2 = 0f32;
            for e2 in 0..d {
                let diff = x[e2] - c[e2];
                s2 += diff * diff;
            }
            acc += s2 as f64;
        }
        acc
    });
    // ordered merge: the value depends only on n, never on thread count
    partials.iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_picks_nearest() {
        let points = [0.0f32, 0.0, 10.0, 10.0, 0.1, -0.1];
        let centroids = [0.0f32, 0.0, 10.0, 10.0];
        let mut out = vec![0u32; 3];
        assign(&points, &centroids, 2, &mut out);
        assert_eq!(out, vec![0, 1, 0]);
    }

    #[test]
    fn assign_ties_break_to_lowest_index() {
        let points = [0.0f32, 0.0];
        let centroids = [1.0f32, 0.0, -1.0, 0.0];
        let mut out = vec![0u32; 1];
        assign(&points, &centroids, 2, &mut out);
        assert_eq!(out[0], 0);
    }

    #[test]
    fn inertia_zero_when_points_are_centroids() {
        let pts = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = vec![0u32; 2];
        assign(&pts, &pts, 2, &mut out);
        assert_eq!(out, vec![0, 1]);
        assert_eq!(inertia(&pts, &pts, 2, &out), 0.0);
    }

    #[test]
    fn nearest_reports_true_squared_distance() {
        let centroids = [1.0f32, 0.0, -2.0, 0.5];
        let stage = AssignStage::new(&centroids, 2);
        let mut dist = [0f32; ASSIGN_BLOCK];
        let (j, d2) = stage.nearest(&[1.5, 0.5], &mut dist);
        assert_eq!(j, 0);
        assert!((d2 - 0.5).abs() < 1e-6, "d2 {d2}");
    }

    #[test]
    fn assign_and_inertia_invariant_across_thread_counts() {
        let mut rng = crate::util::Rng::new(11);
        let n = ACC_CHUNK + 137; // force multiple chunks
        let d = 3;
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let cen: Vec<f32> = (0..7 * d).map(|_| rng.normal() as f32).collect();
        let mut base = vec![0u32; n];
        assign_t(&pts, &cen, d, &mut base, 1);
        let base_inertia = inertia_t(&pts, &cen, d, &base, 1);
        for threads in [2, 3, 8] {
            let mut out = vec![0u32; n];
            assign_t(&pts, &cen, d, &mut out, threads);
            assert_eq!(out, base, "assign diverged at {threads} threads");
            let i = inertia_t(&pts, &cen, d, &out, threads);
            assert!(i == base_inertia, "inertia diverged at {threads} threads: {i}");
        }
    }
}
