//! K-means for the CCE clustering events (paper: FAISS with
//! `max_points_per_centroid=256`, `niter=50`; here: our own kmeans++ /
//! Lloyd with the same sampling rule, parallel over the thread pool).

mod lloyd;

pub use lloyd::{kmeans, KmeansConfig, KmeansResult};

use crate::util::threadpool;

/// Assign each point to its nearest centroid (squared L2, ties → lowest
/// index). `points: [n, d]`, `centroids: [k, d]` row-major.
///
/// Hot-path layout (§Perf log, opt L3-1): centroids are staged TRANSPOSED
/// (`ct[e*k + j]`) and half-distances accumulated per CENTROID-block, so
/// the inner loops run unit-stride over `j` and autovectorize — ~6× over
/// the naive per-point dot-product loop at the embedding dims (d ≤ 16)
/// this system uses. ‖x‖² is constant per point and omitted.
pub fn assign(points: &[f32], centroids: &[f32], d: usize, out: &mut [u32]) {
    let n = points.len() / d;
    let k = centroids.len() / d;
    assert_eq!(points.len(), n * d);
    assert_eq!(out.len(), n);
    assert!(k > 0);
    // transposed centroids + ½‖c‖² (dist/2 preserves the argmin)
    let mut ct = vec![0f32; k * d];
    let mut half_norms = vec![0f32; k];
    for j in 0..k {
        let c = &centroids[j * d..(j + 1) * d];
        half_norms[j] = 0.5 * c.iter().map(|v| v * v).sum::<f32>();
        for e in 0..d {
            ct[e * k + j] = c[e];
        }
    }
    const JB: usize = 512; // centroid block: JB*(d+1) f32 stays in L1
    let out_ptr = SyncSlice(out.as_mut_ptr());
    threadpool::scope_chunks(n, threadpool::default_threads(), |_, s, e| {
        // chunks write disjoint [s, e) ranges; the wrapper makes the raw
        // pointer capturable across the scoped threads
        let out = unsafe { std::slice::from_raw_parts_mut(out_ptr.get(), n) };
        let mut dist = vec![0f32; JB];
        for i in s..e {
            let x = &points[i * d..(i + 1) * d];
            let mut best = 0u32;
            let mut best_d = f32::INFINITY;
            let mut j0 = 0;
            while j0 < k {
                let jb = JB.min(k - j0);
                let dist = &mut dist[..jb];
                dist.copy_from_slice(&half_norms[j0..j0 + jb]);
                for (e2, &xe) in x.iter().enumerate() {
                    let row = &ct[e2 * k + j0..e2 * k + j0 + jb];
                    // unit-stride over j: vectorizes
                    for (dj, &cj) in dist.iter_mut().zip(row) {
                        *dj -= xe * cj;
                    }
                }
                // two-pass argmin: a branchless vectorizable min-reduce,
                // then a positional scan only when the block improves on
                // the running best (rare after the first blocks)
                let block_min = {
                    // 8-lane min accumulator: vectorizes where the scalar
                    // fold's sequential dependency chain cannot
                    let mut lanes = [f32::INFINITY; 8];
                    let mut it = dist.chunks_exact(8);
                    for ch in &mut it {
                        for (l, &v) in lanes.iter_mut().zip(ch) {
                            *l = l.min(v);
                        }
                    }
                    let mut m = it.remainder().iter().copied().fold(f32::INFINITY, f32::min);
                    for l in lanes {
                        m = m.min(l);
                    }
                    m
                };
                if block_min < best_d {
                    best_d = block_min;
                    let jj = dist.iter().position(|&dj| dj == block_min).unwrap();
                    best = (j0 + jj) as u32;
                }
                j0 += jb;
            }
            out[i] = best;
        }
    });
}

/// Wrapper so the raw pointer can cross the scoped-thread boundary; safe
/// because the chunks write disjoint ranges. (The accessor method forces
/// closures to capture the whole wrapper, not the raw-pointer field —
/// edition-2021 disjoint capture would otherwise grab the `!Sync` pointer.)
struct SyncSlice(*mut u32);
unsafe impl Sync for SyncSlice {}
unsafe impl Send for SyncSlice {}
impl SyncSlice {
    fn get(&self) -> *mut u32 {
        self.0
    }
}

/// Sum of squared distances to assigned centroids (the K-means objective).
pub fn inertia(points: &[f32], centroids: &[f32], d: usize, assignments: &[u32]) -> f64 {
    let n = points.len() / d;
    let mut acc = 0f64;
    for i in 0..n {
        let x = &points[i * d..(i + 1) * d];
        let c = &centroids[assignments[i] as usize * d..][..d];
        let mut s = 0f32;
        for e in 0..d {
            let diff = x[e] - c[e];
            s += diff * diff;
        }
        acc += s as f64;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_picks_nearest() {
        let points = [0.0f32, 0.0, 10.0, 10.0, 0.1, -0.1];
        let centroids = [0.0f32, 0.0, 10.0, 10.0];
        let mut out = vec![0u32; 3];
        assign(&points, &centroids, 2, &mut out);
        assert_eq!(out, vec![0, 1, 0]);
    }

    #[test]
    fn assign_ties_break_to_lowest_index() {
        let points = [0.0f32, 0.0];
        let centroids = [1.0f32, 0.0, -1.0, 0.0];
        let mut out = vec![0u32; 1];
        assign(&points, &centroids, 2, &mut out);
        assert_eq!(out[0], 0);
    }

    #[test]
    fn inertia_zero_when_points_are_centroids() {
        let pts = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = vec![0u32; 2];
        assign(&pts, &pts, 2, &mut out);
        assert_eq!(out, vec![0, 1]);
        assert_eq!(inertia(&pts, &pts, 2, &out), 0.0);
    }
}
