//! kmeans++ seeding + Lloyd iterations with FAISS-style point subsampling
//! and empty-cluster repair.

use crate::kmeans::{assign, inertia};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct KmeansConfig {
    pub k: usize,
    /// Lloyd iterations (paper: niter=50; 300 gave no measurable benefit)
    pub n_iter: usize,
    /// subsample to `max_points_per_centroid * k` points (paper: 256)
    pub max_points_per_centroid: usize,
    pub seed: u64,
    /// stop early when relative inertia improvement falls below this
    pub tol: f64,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig { k: 8, n_iter: 50, max_points_per_centroid: 256, seed: 0, tol: 1e-4 }
    }
}

#[derive(Debug)]
pub struct KmeansResult {
    /// `[k, d]` row-major
    pub centroids: Vec<f32>,
    /// assignment of every INPUT point (not just the subsample)
    pub assignments: Vec<u32>,
    pub inertia: f64,
    pub iterations: usize,
}

/// Full K-means: subsample → kmeans++ seed → Lloyd → assign all points.
pub fn kmeans(points: &[f32], d: usize, cfg: &KmeansConfig) -> KmeansResult {
    let n = points.len() / d;
    assert!(n > 0 && cfg.k > 0);
    assert_eq!(points.len(), n * d);
    let k = cfg.k.min(n);
    let mut rng = Rng::new(cfg.seed);

    // -- subsample (FAISS rule) ---------------------------------------------
    let budget = cfg.max_points_per_centroid.max(1) * k;
    let sub_owned: Vec<f32>;
    let sub: &[f32] = if n > budget {
        let idx = rng.sample_indices(n, budget);
        let mut buf = Vec::with_capacity(budget * d);
        for &i in &idx {
            buf.extend_from_slice(&points[i * d..(i + 1) * d]);
        }
        sub_owned = buf;
        &sub_owned
    } else {
        points
    };
    let sn = sub.len() / d;

    // -- kmeans++ seeding -----------------------------------------------------
    let mut centroids = vec![0f32; k * d];
    let first = rng.below(sn as u64) as usize;
    centroids[..d].copy_from_slice(&sub[first * d..(first + 1) * d]);
    let mut min_d2 = vec![f32::INFINITY; sn];
    for j in 1..k {
        // update distances to the newest centroid
        let c = &centroids[(j - 1) * d..j * d];
        for i in 0..sn {
            let x = &sub[i * d..(i + 1) * d];
            let mut s = 0f32;
            for e in 0..d {
                let diff = x[e] - c[e];
                s += diff * diff;
            }
            if s < min_d2[i] {
                min_d2[i] = s;
            }
        }
        let total: f64 = min_d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.below(sn as u64) as usize
        } else {
            let mut target = rng.uniform() * total;
            let mut pick = sn - 1;
            for (i, &w) in min_d2.iter().enumerate() {
                target -= w as f64;
                if target <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centroids[j * d..(j + 1) * d].copy_from_slice(&sub[pick * d..(pick + 1) * d]);
    }

    // -- Lloyd ----------------------------------------------------------------
    let mut asg = vec![0u32; sn];
    let mut prev_inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.n_iter {
        iterations = it + 1;
        assign(sub, &centroids, d, &mut asg);
        // centroid update
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0u64; k];
        for i in 0..sn {
            let j = asg[i] as usize;
            counts[j] += 1;
            for e in 0..d {
                sums[j * d + e] += sub[i * d + e] as f64;
            }
        }
        // empty-cluster repair: reseed from the point furthest from its centroid
        for j in 0..k {
            if counts[j] == 0 {
                let far = (0..sn)
                    .max_by(|&a, &b| {
                        d2(sub, &centroids, d, a, asg[a]).total_cmp(&d2(
                            sub, &centroids, d, b, asg[b],
                        ))
                    })
                    .unwrap();
                centroids[j * d..(j + 1) * d].copy_from_slice(&sub[far * d..(far + 1) * d]);
            } else {
                for e in 0..d {
                    centroids[j * d + e] = (sums[j * d + e] / counts[j] as f64) as f32;
                }
            }
        }
        let cur = inertia(sub, &centroids, d, &asg);
        if prev_inertia.is_finite() && (prev_inertia - cur) <= cfg.tol * prev_inertia.abs() {
            break;
        }
        prev_inertia = cur;
    }

    // -- final assignment over ALL input points -------------------------------
    let mut assignments = vec![0u32; n];
    assign(points, &centroids, d, &mut assignments);
    let total_inertia = inertia(points, &centroids, d, &assignments);
    KmeansResult { centroids, assignments, inertia: total_inertia, iterations }
}

#[inline]
fn d2(points: &[f32], centroids: &[f32], d: usize, i: usize, j: u32) -> f64 {
    let x = &points[i * d..(i + 1) * d];
    let c = &centroids[j as usize * d..][..d];
    let mut s = 0f64;
    for e in 0..d {
        let diff = (x[e] - c[e]) as f64;
        s += diff * diff;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// three well-separated gaussian blobs
    fn blobs(n_per: usize, seed: u64) -> (Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for (g, c) in centers.iter().enumerate() {
            for _ in 0..n_per {
                pts.push(c[0] + rng.normal() as f32 * 0.3);
                pts.push(c[1] + rng.normal() as f32 * 0.3);
                truth.push(g as u32);
            }
        }
        (pts, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (pts, truth) = blobs(100, 0);
        let res = kmeans(&pts, 2, &KmeansConfig { k: 3, seed: 1, ..Default::default() });
        // each true blob maps to exactly one cluster id
        for g in 0..3 {
            let ids: std::collections::HashSet<u32> = truth
                .iter()
                .zip(&res.assignments)
                .filter(|(t, _)| **t == g)
                .map(|(_, &a)| a)
                .collect();
            assert_eq!(ids.len(), 1, "blob {g} split across clusters");
        }
        assert!(res.inertia < 300.0 * 0.5, "inertia {}", res.inertia);
    }

    #[test]
    fn deterministic_given_seed() {
        let (pts, _) = blobs(50, 2);
        let cfg = KmeansConfig { k: 3, seed: 9, ..Default::default() };
        let a = kmeans(&pts, 2, &cfg);
        let b = kmeans(&pts, 2, &cfg);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let pts = [0.0f32, 0.0, 1.0, 1.0];
        let res = kmeans(&pts, 2, &KmeansConfig { k: 10, ..Default::default() });
        assert_eq!(res.centroids.len() / 2, 2);
        assert!(res.assignments.iter().all(|&a| a < 2));
    }

    #[test]
    fn subsampling_still_assigns_everything() {
        let (pts, _) = blobs(500, 3); // 1500 points
        let cfg = KmeansConfig { k: 3, max_points_per_centroid: 10, seed: 4, ..Default::default() };
        let res = kmeans(&pts, 2, &cfg);
        assert_eq!(res.assignments.len(), 1500);
        assert!(res.inertia < 1500.0, "inertia {}", res.inertia);
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let (pts, _) = blobs(100, 5);
        let i2 = kmeans(&pts, 2, &KmeansConfig { k: 2, seed: 6, ..Default::default() }).inertia;
        let i3 = kmeans(&pts, 2, &KmeansConfig { k: 3, seed: 6, ..Default::default() }).inertia;
        let i8 = kmeans(&pts, 2, &KmeansConfig { k: 8, seed: 6, ..Default::default() }).inertia;
        assert!(i3 < i2);
        assert!(i8 < i3);
    }

    #[test]
    fn no_empty_clusters_on_duplicated_points() {
        // all points identical except one outlier → repair must fire
        let mut pts = vec![1.0f32; 40]; // 20 identical 2-d points
        pts.extend_from_slice(&[50.0, 50.0]);
        let res = kmeans(&pts, 2, &KmeansConfig { k: 2, seed: 7, ..Default::default() });
        let uniq: std::collections::HashSet<u32> = res.assignments.iter().copied().collect();
        assert_eq!(uniq.len(), 2);
    }
}
