//! kmeans++ seeding + Lloyd iterations with FAISS-style point subsampling
//! and empty-cluster repair.
//!
//! §Perf log, opt L3-2 (fused parallel Lloyd): the seed implementation
//! parallelized only `assign`; the centroid update, kmeans++ min-distance
//! update, and both inertia passes were serial, and empty-cluster repair
//! re-derived `d2` twice per comparison inside a `max_by`. Now:
//!
//!   * assignment and centroid accumulation are FUSED into one pass over
//!     fixed `ACC_CHUNK`-point chunks; each chunk writes its own
//!     `sums/counts` partial, merged serially in ascending chunk order —
//!     bit-identical results for any worker-thread count;
//!   * the per-point squared distances computed during assignment are
//!     cached and reused for empty-cluster repair (an argmax scan per
//!     empty cluster instead of two `d2` recomputations per `max_by`
//!     comparison, with used points consumed so repairs stay distinct);
//!   * the kmeans++ min-distance update runs chunk-parallel, fused with
//!     the per-chunk weight sums; the weighted pick walks chunk partials
//!     first and only then the winning chunk (O(n_chunks + ACC_CHUNK)
//!     instead of O(sn) per pick);
//!   * inertia (convergence check and final objective) is chunk-parallel.
//!
//! Tracked in `BENCH_cluster.json` (benches/perf_cluster.rs); the scalar
//! reference pin lives in tests/proptests.rs. On the 16-core dev host the
//! terabyte-ish `cluster_event` shape improved ~3.5–5× end-to-end, the
//! kmeans n/k/d sweep rows 4–6×.

use crate::kmeans::{assign_t, inertia_t, AssignStage, ACC_CHUNK, ASSIGN_BLOCK};
use crate::util::threadpool::{self, SharedSlice};
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct KmeansConfig {
    pub k: usize,
    /// Lloyd iterations (paper: niter=50; 300 gave no measurable benefit)
    pub n_iter: usize,
    /// subsample to `max_points_per_centroid * k` points (paper: 256)
    pub max_points_per_centroid: usize,
    pub seed: u64,
    /// stop early when relative inertia improvement falls below this
    pub tol: f64,
    /// worker threads for the parallel passes; 0 = `default_threads()`.
    /// Results are bit-identical for every value (fixed-chunk reductions).
    pub n_threads: usize,
}

impl Default for KmeansConfig {
    fn default() -> Self {
        KmeansConfig {
            k: 8,
            n_iter: 50,
            max_points_per_centroid: 256,
            seed: 0,
            tol: 1e-4,
            n_threads: 0,
        }
    }
}

#[derive(Debug)]
pub struct KmeansResult {
    /// `[k, d]` row-major
    pub centroids: Vec<f32>,
    /// assignment of every INPUT point (not just the subsample)
    pub assignments: Vec<u32>,
    pub inertia: f64,
    pub iterations: usize,
}

/// Full K-means: subsample → kmeans++ seed → fused Lloyd → assign all
/// points. Deterministic given `cfg.seed`, for any `cfg.n_threads`.
pub fn kmeans(points: &[f32], d: usize, cfg: &KmeansConfig) -> KmeansResult {
    let n = points.len() / d;
    assert!(n > 0 && cfg.k > 0);
    assert_eq!(points.len(), n * d);
    let k = cfg.k.min(n);
    let threads = if cfg.n_threads == 0 { threadpool::default_threads() } else { cfg.n_threads };
    let mut rng = Rng::new(cfg.seed);

    // -- subsample (FAISS rule) ---------------------------------------------
    let budget = cfg.max_points_per_centroid.max(1) * k;
    let sub_owned: Vec<f32>;
    let sub: &[f32] = if n > budget {
        let idx = rng.sample_indices(n, budget);
        let mut buf = Vec::with_capacity(budget * d);
        for &i in &idx {
            buf.extend_from_slice(&points[i * d..(i + 1) * d]);
        }
        sub_owned = buf;
        &sub_owned
    } else {
        points
    };
    let sn = sub.len() / d;
    let n_chunks = sn.div_ceil(ACC_CHUNK);

    // -- kmeans++ seeding -----------------------------------------------------
    let mut centroids = vec![0f32; k * d];
    let first = rng.below(sn as u64) as usize;
    centroids[..d].copy_from_slice(&sub[first * d..(first + 1) * d]);
    let mut min_d2 = vec![f32::INFINITY; sn];
    let mut weight_partials = vec![0f64; n_chunks];
    for j in 1..k {
        // update distances to the newest centroid, fused with per-chunk
        // weight sums (chunk-parallel; per-point math is unchanged scalar)
        let c = &centroids[(j - 1) * d..j * d];
        {
            let md_s = SharedSlice::new(&mut min_d2);
            let wp_s = SharedSlice::new(&mut weight_partials);
            threadpool::par_for_each_dynamic(n_chunks, threads, |ci| {
                let (s, e) = (ci * ACC_CHUNK, ((ci + 1) * ACC_CHUNK).min(sn));
                // SAFETY: chunk ci exclusively owns min_d2[s..e]; the fixed
                // ACC_CHUNK ranges are pairwise disjoint and e <= sn.
                let md = unsafe { md_s.range_mut(s, e - s) };
                let mut acc = 0f64;
                for (o, i) in (s..e).enumerate() {
                    let x = &sub[i * d..(i + 1) * d];
                    let mut s2 = 0f32;
                    for e2 in 0..d {
                        let diff = x[e2] - c[e2];
                        s2 += diff * diff;
                    }
                    if s2 < md[o] {
                        md[o] = s2;
                    }
                    acc += md[o] as f64;
                }
                // SAFETY: chunk ci exclusively owns weight_partials[ci] and
                // ci < n_chunks == wp_s.len().
                unsafe { wp_s.write(ci, acc) };
            });
        }
        // ordered merge → thread-count-invariant total
        let total: f64 = weight_partials.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(sn as u64) as usize
        } else {
            let target = rng.uniform() * total;
            weighted_pick(target, &weight_partials, &min_d2, sn)
        };
        centroids[j * d..(j + 1) * d].copy_from_slice(&sub[pick * d..(pick + 1) * d]);
    }

    // -- fused Lloyd ----------------------------------------------------------
    // per-chunk partials, reused across iterations; chunk ci owns
    // psums[ci*k*d..] / pcounts[ci*k..] and zeroes them itself
    let mut asg = vec![0u32; sn];
    let mut d2 = vec![0f32; sn];
    let mut psums = vec![0f64; n_chunks * k * d];
    let mut pcounts = vec![0u64; n_chunks * k];
    let mut sums = vec![0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut prev_inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.n_iter {
        iterations = it + 1;
        let stage = AssignStage::new(&centroids, d);
        {
            let asg_s = SharedSlice::new(&mut asg);
            let d2_s = SharedSlice::new(&mut d2);
            let ps_s = SharedSlice::new(&mut psums);
            let pc_s = SharedSlice::new(&mut pcounts);
            threadpool::par_for_each_dynamic(n_chunks, threads, |ci| {
                let (s, e) = (ci * ACC_CHUNK, ((ci + 1) * ACC_CHUNK).min(sn));
                // SAFETY: chunk ci exclusively owns asg[s..e]; the fixed
                // ACC_CHUNK ranges are pairwise disjoint and e <= sn.
                let asg = unsafe { asg_s.range_mut(s, e - s) };
                // SAFETY: same disjoint chunk range, over d2 this time.
                let d2 = unsafe { d2_s.range_mut(s, e - s) };
                // SAFETY: chunk ci exclusively owns its psums partial
                // [ci*k*d, (ci+1)*k*d) — disjoint per ci, n_chunks*k*d total.
                let sums = unsafe { ps_s.range_mut(ci * k * d, k * d) };
                // SAFETY: chunk ci exclusively owns its pcounts partial
                // [ci*k, (ci+1)*k) — disjoint per ci, n_chunks*k total.
                let counts = unsafe { pc_s.range_mut(ci * k, k) };
                sums.fill(0.0);
                counts.fill(0);
                let mut dist = [0f32; ASSIGN_BLOCK];
                for (o, i) in (s..e).enumerate() {
                    let x = &sub[i * d..(i + 1) * d];
                    let (best, dd) = stage.nearest(x, &mut dist);
                    asg[o] = best;
                    d2[o] = dd;
                    counts[best as usize] += 1;
                    let row = &mut sums[best as usize * d..][..d];
                    for (acc, &xe) in row.iter_mut().zip(x) {
                        *acc += xe as f64;
                    }
                }
            });
        }
        // merge partials in ascending chunk order (serial; the merge is
        // O(n_chunks·k·d) — noise next to the O(sn·k·d) fused pass)
        sums.fill(0.0);
        counts.fill(0);
        for ci in 0..n_chunks {
            for (a, &b) in counts.iter_mut().zip(&pcounts[ci * k..(ci + 1) * k]) {
                *a += b;
            }
            for (a, &b) in sums.iter_mut().zip(&psums[ci * k * d..(ci + 1) * k * d]) {
                *a += b;
            }
        }
        // empty-cluster repair: reseed from the point furthest from its
        // centroid, using the distances CACHED during the fused pass (all
        // relative to this iteration's pre-update centroids — the old
        // implementation re-derived d2 against partially-updated centroids
        // twice per max_by comparison, and could hand two empty clusters
        // the SAME point, collapsing them onto duplicate centroids).
        // Last-max scan mirrors max_by's tie-break; each used point's
        // cached distance is consumed so successive empty clusters reseed
        // from distinct points.
        for j in 0..k {
            if counts[j] == 0 {
                let mut far = 0;
                for (i, &dd) in d2.iter().enumerate() {
                    if dd >= d2[far] {
                        far = i;
                    }
                }
                centroids[j * d..(j + 1) * d].copy_from_slice(&sub[far * d..(far + 1) * d]);
                d2[far] = 0.0;
            } else {
                for e in 0..d {
                    centroids[j * d + e] = (sums[j * d + e] / counts[j] as f64) as f32;
                }
            }
        }
        let cur = inertia_t(sub, &centroids, d, &asg, threads);
        if prev_inertia.is_finite() && (prev_inertia - cur) <= cfg.tol * prev_inertia.abs() {
            break;
        }
        prev_inertia = cur;
    }

    // -- final assignment over ALL input points -------------------------------
    let mut assignments = vec![0u32; n];
    assign_t(points, &centroids, d, &mut assignments, threads);
    let total_inertia = inertia_t(points, &centroids, d, &assignments, threads);
    KmeansResult { centroids, assignments, inertia: total_inertia, iterations }
}

/// Two-level weighted pick: walk chunk partials, then the winning chunk's
/// elements, subtracting weights until the target is exhausted — the same
/// chunk tree as the weight sum, so the choice is thread-count-invariant.
/// Falls back to the last candidate when float rounding leaves a residue.
fn weighted_pick(mut target: f64, partials: &[f64], weights: &[f32], sn: usize) -> usize {
    for (ci, &p) in partials.iter().enumerate() {
        if target > p {
            target -= p;
            continue;
        }
        let (s, e) = (ci * ACC_CHUNK, ((ci + 1) * ACC_CHUNK).min(sn));
        for (i, &w) in weights[s..e].iter().enumerate() {
            target -= w as f64;
            if target <= 0.0 {
                return s + i;
            }
        }
        return e - 1;
    }
    sn - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    /// three well-separated gaussian blobs
    fn blobs(n_per: usize, seed: u64) -> (Vec<f32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let mut pts = Vec::new();
        let mut truth = Vec::new();
        for (g, c) in centers.iter().enumerate() {
            for _ in 0..n_per {
                pts.push(c[0] + rng.normal() as f32 * 0.3);
                pts.push(c[1] + rng.normal() as f32 * 0.3);
                truth.push(g as u32);
            }
        }
        (pts, truth)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (pts, truth) = blobs(100, 0);
        let res = kmeans(&pts, 2, &KmeansConfig { k: 3, seed: 1, ..Default::default() });
        // each true blob maps to exactly one cluster id
        for g in 0..3 {
            let ids: std::collections::HashSet<u32> = truth
                .iter()
                .zip(&res.assignments)
                .filter(|(t, _)| **t == g)
                .map(|(_, &a)| a)
                .collect();
            assert_eq!(ids.len(), 1, "blob {g} split across clusters");
        }
        assert!(res.inertia < 300.0 * 0.5, "inertia {}", res.inertia);
    }

    #[test]
    fn deterministic_given_seed() {
        let (pts, _) = blobs(50, 2);
        let cfg = KmeansConfig { k: 3, seed: 9, ..Default::default() };
        let a = kmeans(&pts, 2, &cfg);
        let b = kmeans(&pts, 2, &cfg);
        assert_eq!(a.centroids, b.centroids);
        assert_eq!(a.assignments, b.assignments);
    }

    #[test]
    fn deterministic_across_thread_counts() {
        // the whole point of the fixed-chunk reductions: sweeping the
        // worker count must not move a single bit of the result
        let (pts, _) = blobs(700, 8); // 2100 points
        let base_cfg = KmeansConfig { k: 5, seed: 3, n_threads: 1, ..Default::default() };
        let base = kmeans(&pts, 2, &base_cfg);
        for threads in [2, 3, 8, 16] {
            let cfg = KmeansConfig { k: 5, seed: 3, n_threads: threads, ..Default::default() };
            let r = kmeans(&pts, 2, &cfg);
            assert_eq!(r.centroids, base.centroids, "{threads} threads");
            assert_eq!(r.assignments, base.assignments, "{threads} threads");
            assert!(r.inertia == base.inertia, "{threads} threads");
            assert_eq!(r.iterations, base.iterations, "{threads} threads");
        }
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let pts = [0.0f32, 0.0, 1.0, 1.0];
        let res = kmeans(&pts, 2, &KmeansConfig { k: 10, ..Default::default() });
        assert_eq!(res.centroids.len() / 2, 2);
        assert!(res.assignments.iter().all(|&a| a < 2));
    }

    #[test]
    fn subsampling_still_assigns_everything() {
        let (pts, _) = blobs(500, 3); // 1500 points
        let cfg = KmeansConfig { k: 3, max_points_per_centroid: 10, seed: 4, ..Default::default() };
        let res = kmeans(&pts, 2, &cfg);
        assert_eq!(res.assignments.len(), 1500);
        assert!(res.inertia < 1500.0, "inertia {}", res.inertia);
    }

    #[test]
    fn inertia_decreases_with_more_clusters() {
        let (pts, _) = blobs(100, 5);
        let i2 = kmeans(&pts, 2, &KmeansConfig { k: 2, seed: 6, ..Default::default() }).inertia;
        let i3 = kmeans(&pts, 2, &KmeansConfig { k: 3, seed: 6, ..Default::default() }).inertia;
        let i8 = kmeans(&pts, 2, &KmeansConfig { k: 8, seed: 6, ..Default::default() }).inertia;
        assert!(i3 < i2);
        assert!(i8 < i3);
    }

    #[test]
    fn no_empty_clusters_on_duplicated_points() {
        // all points identical except one outlier → repair must fire
        let mut pts = vec![1.0f32; 40]; // 20 identical 2-d points
        pts.extend_from_slice(&[50.0, 50.0]);
        let res = kmeans(&pts, 2, &KmeansConfig { k: 2, seed: 7, ..Default::default() });
        let uniq: std::collections::HashSet<u32> = res.assignments.iter().copied().collect();
        assert_eq!(uniq.len(), 2);
    }

    #[test]
    fn weighted_pick_matches_flat_scan_semantics() {
        // weights 1..=5 in one chunk: target just under the cumulative sum
        // of the first i weights must pick index i-1
        let weights: Vec<f32> = (1..=5).map(|x| x as f32).collect();
        let partials = [weights.iter().map(|&w| w as f64).sum::<f64>()];
        let mut cum = 0.0;
        for (i, &w) in weights.iter().enumerate() {
            cum += w as f64;
            assert_eq!(weighted_pick(cum - 0.5, &partials, &weights, 5), i);
        }
        // a rounding residue past the total falls back to the last index
        assert_eq!(weighted_pick(cum + 1.0, &partials, &weights, 5), 4);
    }
}
