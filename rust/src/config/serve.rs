//! Serving-engine configuration: the `[serve]` TOML section and the
//! `cce serve` CLI flags, mirroring how `TrainConfig` is layered
//! (defaults ← TOML ← CLI overrides).

use crate::config::TomlDoc;
use crate::serving::AdmissionPolicy;
use crate::util::Args;
use anyhow::{bail, Result};
use std::time::Duration;

/// Everything the serving engine needs besides the baked snapshot.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact name (selects model, dataset shapes, eval batch)
    pub artifact: String,
    pub seed: u64,
    /// total requests the synthetic traffic source emits
    pub requests: usize,
    /// admitted requests per device batch; 0 = the artifact's `eval_batch`
    pub max_batch: usize,
    /// admission fill window (microseconds): once a worker picks up the
    /// first request of a batch it waits at most this long for the batch to
    /// fill to `max_batch` before dispatching what accumulated (time spent
    /// queued before pickup is NOT counted against this window)
    pub max_wait_us: u64,
    /// index-generation worker threads feeding the device
    pub workers: usize,
    /// bounded request-queue depth (admission backpressure)
    pub queue_depth: usize,
    /// Zipf exponent of the traffic source's sample popularity; 0 = uniform.
    /// Higher skew concentrates traffic on hot ids — the CAFE-style serving
    /// scenario the snapshot must stay fast under.
    pub zipf_skew: f64,
    /// train this many batches first and serve the best-validation
    /// checkpoint (state + index maps) instead of a random-initialized
    /// model; 0 = skip training (the seed behavior, useful for pure
    /// serving-path benchmarks)
    pub train_steps: usize,
    /// boot straight from an on-disk segment file (zero-copy mmap load)
    /// instead of baking; mutually exclusive with `train_steps` — the
    /// segment already carries the frozen index maps of a specific run
    pub snapshot_path: String,
    /// boot from the newest verified segment in this directory AND attach a
    /// `SnapshotWatcher` that auto-installs newer generations as the trainer
    /// writes them; mutually exclusive with `snapshot_path`/`train_steps`
    pub snapshot_dir: String,
    /// watcher poll interval (milliseconds)
    pub watch_poll_ms: u64,
    /// admission policy: "block" (producers wait on a full queue — the
    /// replay-benchmark contract) or "shed" (full queue rejects, expired
    /// requests are dropped at batch formation — the production contract)
    pub admission: String,
    /// shed-mode per-request deadline (microseconds), measured from arrival;
    /// 0 = shed on queue pressure only
    pub deadline_us: u64,
    /// offered load in requests/second; 0 = emit as fast as the queue
    /// accepts. Paced traffic stamps each request with its intended emission
    /// time, which is what makes overload visible in block mode
    pub pace_rps: f64,
    /// bind a Prometheus-text `/metrics` endpoint here for the run's
    /// duration (e.g. "127.0.0.1:9184"; port 0 picks an ephemeral port,
    /// logged at startup); empty = no endpoint
    pub metrics_addr: String,
    /// append a JSONL metrics snapshot to this file every
    /// `stats_interval_ms` (docs/OBSERVABILITY.md); empty = off
    pub stats_out: String,
    /// interval between stats snapshots (milliseconds)
    pub stats_interval_ms: u64,
    /// record spans into the bounded trace ring and dump a Chrome
    /// `trace.json` here at the end of the run; empty = tracing off
    pub trace_out: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact: "quick_cce".into(),
            seed: 0,
            requests: 10_000,
            max_batch: 0,
            max_wait_us: 200,
            workers: 4,
            queue_depth: 4096,
            zipf_skew: 0.99,
            train_steps: 0,
            snapshot_path: String::new(),
            snapshot_dir: String::new(),
            watch_poll_ms: 200,
            admission: "block".into(),
            deadline_us: 0,
            pace_rps: 0.0,
            metrics_addr: String::new(),
            stats_out: String::new(),
            stats_interval_ms: 500,
            trace_out: String::new(),
        }
    }
}

impl ServeConfig {
    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> ServeConfig {
        self.artifact = args.str_or("artifact", &self.artifact);
        self.seed = args.u64_or("seed", self.seed);
        self.requests = args.usize_or("requests", self.requests);
        self.max_batch = args.usize_or("max-batch", self.max_batch);
        self.max_wait_us = args.u64_or("max-wait-us", self.max_wait_us);
        self.workers = args.usize_or("workers", self.workers);
        self.queue_depth = args.usize_or("queue-depth", self.queue_depth);
        self.zipf_skew = args.f64_or("zipf", self.zipf_skew);
        self.train_steps = args.usize_or("train-steps", self.train_steps);
        self.snapshot_path = args.str_or("snapshot", &self.snapshot_path);
        self.snapshot_dir = args.str_or("snapshot-dir", &self.snapshot_dir);
        self.watch_poll_ms = args.u64_or("watch-poll-ms", self.watch_poll_ms);
        self.admission = args.str_or("admission", &self.admission);
        self.deadline_us = args.u64_or("deadline-us", self.deadline_us);
        self.pace_rps = args.f64_or("pace-rps", self.pace_rps);
        self.metrics_addr = args.str_or("metrics-addr", &self.metrics_addr);
        self.stats_out = args.str_or("stats-out", &self.stats_out);
        self.stats_interval_ms = args.u64_or("stats-interval-ms", self.stats_interval_ms);
        self.trace_out = args.str_or("trace-out", &self.trace_out);
        self
    }

    /// Load from a TOML-subset file ([serve] section).
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig> {
        let mut c = ServeConfig::default();
        for (k, v) in doc.section("serve") {
            match k.as_str() {
                "artifact" => c.artifact = v.as_str().to_string(),
                "seed" => c.seed = v.as_u64()?,
                "requests" => c.requests = v.as_u64()? as usize,
                "max_batch" => c.max_batch = v.as_u64()? as usize,
                "max_wait_us" => c.max_wait_us = v.as_u64()?,
                "workers" => c.workers = v.as_u64()? as usize,
                "queue_depth" => c.queue_depth = v.as_u64()? as usize,
                "zipf_skew" => c.zipf_skew = v.as_f64()?,
                "train_steps" => c.train_steps = v.as_u64()? as usize,
                "snapshot_path" => c.snapshot_path = v.as_str().to_string(),
                "snapshot_dir" => c.snapshot_dir = v.as_str().to_string(),
                "watch_poll_ms" => c.watch_poll_ms = v.as_u64()?,
                "admission" => c.admission = v.as_str().to_string(),
                "deadline_us" => c.deadline_us = v.as_u64()?,
                "pace_rps" => c.pace_rps = v.as_f64()?,
                "metrics_addr" => c.metrics_addr = v.as_str().to_string(),
                "stats_out" => c.stats_out = v.as_str().to_string(),
                "stats_interval_ms" => c.stats_interval_ms = v.as_u64()?,
                "trace_out" => c.trace_out = v.as_str().to_string(),
                other => bail!("unknown [serve] key {other:?}"),
            }
        }
        Ok(c)
    }

    /// Batch-formation fill window as a `Duration`.
    pub fn max_wait(&self) -> Duration {
        Duration::from_micros(self.max_wait_us)
    }

    /// The engine admission policy this config selects. In shed mode the
    /// queue budget is `queue_depth` and `deadline_us > 0` arms per-request
    /// deadlines.
    pub fn admission_policy(&self) -> AdmissionPolicy {
        match self.admission.as_str() {
            "shed" => AdmissionPolicy::Shed {
                queue_depth: self.queue_depth,
                deadline: (self.deadline_us > 0)
                    .then(|| Duration::from_micros(self.deadline_us)),
            },
            _ => AdmissionPolicy::Block,
        }
    }

    /// Offered-load pacing interval; `None` = unpaced.
    pub fn pace(&self) -> Option<Duration> {
        (self.pace_rps > 0.0).then(|| Duration::from_nanos((1e9 / self.pace_rps) as u64))
    }

    pub fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("requests must be ≥ 1");
        }
        if self.workers == 0 || self.queue_depth == 0 {
            bail!("serve workers/queue depth must be ≥ 1");
        }
        if !self.zipf_skew.is_finite() || self.zipf_skew < 0.0 {
            bail!("zipf skew must be a finite value ≥ 0");
        }
        if !self.snapshot_path.is_empty() && self.train_steps > 0 {
            bail!(
                "snapshot_path and train_steps are mutually exclusive: a segment \
                 file already pins one trained model's index maps"
            );
        }
        if !self.snapshot_dir.is_empty()
            && (!self.snapshot_path.is_empty() || self.train_steps > 0)
        {
            bail!(
                "snapshot_dir is mutually exclusive with snapshot_path/train_steps: \
                 the watcher owns which generation is served"
            );
        }
        match self.admission.as_str() {
            "block" | "shed" => {}
            other => bail!("admission must be \"block\" or \"shed\", got {other:?}"),
        }
        if self.admission == "block" && self.deadline_us > 0 {
            bail!("deadline_us requires admission = \"shed\" (block mode never drops)");
        }
        if !self.pace_rps.is_finite() || self.pace_rps < 0.0 {
            bail!("pace_rps must be a finite value ≥ 0");
        }
        if !self.snapshot_dir.is_empty() && self.watch_poll_ms == 0 {
            bail!("watch_poll_ms must be ≥ 1 when snapshot_dir is set");
        }
        if !self.stats_out.is_empty() && self.stats_interval_ms == 0 {
            bail!("stats_interval_ms must be ≥ 1 when stats_out is set");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_override_defaults() {
        let args = Args::parse(
            "x --requests 500 --max-batch 64 --workers 8 --zipf 1.2 --max-wait-us 50 \
             --train-steps 300"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = ServeConfig::default().apply_args(&args);
        assert_eq!(c.requests, 500);
        assert_eq!(c.max_batch, 64);
        assert_eq!(c.workers, 8);
        assert_eq!(c.max_wait_us, 50);
        assert_eq!(c.train_steps, 300);
        assert!((c.zipf_skew - 1.2).abs() < 1e-12);
        assert!(c.validate().is_ok());
        assert_eq!(c.max_wait(), Duration::from_micros(50));
    }

    #[test]
    fn toml_round_trip() {
        let doc = TomlDoc::parse(
            "[serve]\nartifact = \"smoke_cce\"\nrequests = 2000\nzipf_skew = 0.0\nworkers = 2\n\
             train_steps = 64\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(c.artifact, "smoke_cce");
        assert_eq!(c.requests, 2000);
        assert_eq!(c.workers, 2);
        assert_eq!(c.zipf_skew, 0.0);
        assert_eq!(c.train_steps, 64);
    }

    #[test]
    fn unknown_toml_key_rejected() {
        let doc = TomlDoc::parse("[serve]\nbogus = 1\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn validation_catches_zeroes() {
        let c = ServeConfig { requests: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { workers: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { zipf_skew: -0.1, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { zipf_skew: f64::NAN, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn admission_knobs_layer_and_validate() {
        let doc = TomlDoc::parse(
            "[serve]\nadmission = \"shed\"\ndeadline_us = 5000\npace_rps = 2000.0\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(
            c.admission_policy(),
            AdmissionPolicy::Shed {
                queue_depth: c.queue_depth,
                deadline: Some(Duration::from_micros(5000)),
            }
        );
        assert_eq!(c.pace(), Some(Duration::from_nanos(500_000)));
        // CLI overrides win
        let args = Args::parse(
            "serve --admission block --deadline-us 0 --pace-rps 0"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = c.apply_args(&args);
        assert!(c.validate().is_ok());
        assert_eq!(c.admission_policy(), AdmissionPolicy::Block);
        assert_eq!(c.pace(), None);
        // a deadline without shedding is a configuration error, as is an
        // unknown admission mode
        let c = ServeConfig { deadline_us: 100, ..ServeConfig::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { admission: "drop".into(), ..ServeConfig::default() };
        assert!(c.validate().is_err());
        // shed without a deadline sheds on queue pressure only
        let c = ServeConfig { admission: "shed".into(), ..ServeConfig::default() };
        assert!(c.validate().is_ok());
        assert_eq!(c.admission_policy().deadline(), None);
    }

    #[test]
    fn snapshot_dir_excludes_other_boot_sources() {
        let doc = TomlDoc::parse("[serve]\nsnapshot_dir = \"snaps\"\n").unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(c.snapshot_dir, "snaps");
        let bad = ServeConfig { snapshot_path: "x.cceseg".into(), ..c.clone() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { train_steps: 5, ..c.clone() };
        assert!(bad.validate().is_err());
        let bad = ServeConfig { watch_poll_ms: 0, ..c };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn observability_knobs_layer_and_validate() {
        let doc = TomlDoc::parse(
            "[serve]\nmetrics_addr = \"127.0.0.1:9184\"\nstats_out = \"stats.jsonl\"\n\
             stats_interval_ms = 250\ntrace_out = \"trace.json\"\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert!(c.validate().is_ok());
        assert_eq!(c.metrics_addr, "127.0.0.1:9184");
        assert_eq!(c.stats_out, "stats.jsonl");
        assert_eq!(c.stats_interval_ms, 250);
        assert_eq!(c.trace_out, "trace.json");
        // CLI overrides win
        let args = Args::parse(
            "serve --metrics-addr 127.0.0.1:0 --trace-out other.json"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = c.apply_args(&args);
        assert_eq!(c.metrics_addr, "127.0.0.1:0");
        assert_eq!(c.trace_out, "other.json");
        // a stats file with a zero interval would busy-write: rejected
        let bad = ServeConfig { stats_interval_ms: 0, ..c };
        assert!(bad.validate().is_err());
        // no stats file → the interval is irrelevant
        let ok = ServeConfig { stats_interval_ms: 0, ..ServeConfig::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn snapshot_path_layers_and_excludes_training() {
        let doc = TomlDoc::parse("[serve]\nsnapshot_path = \"snaps/gen3.cceseg\"\n").unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(c.snapshot_path, "snaps/gen3.cceseg");
        assert!(c.validate().is_ok());
        // CLI --snapshot overrides the TOML value
        let args = Args::parse(
            "serve --snapshot other.cceseg".split_whitespace().map(String::from),
        )
        .unwrap();
        let c = c.apply_args(&args);
        assert_eq!(c.snapshot_path, "other.cceseg");
        // serving a segment and training-then-serving are mutually exclusive
        let c = ServeConfig { train_steps: 10, ..c };
        assert!(c.validate().is_err());
    }
}
