//! Serving-engine configuration: the `[serve]` TOML section and the
//! `cce serve` CLI flags, mirroring how `TrainConfig` is layered
//! (defaults ← TOML ← CLI overrides).

use crate::config::TomlDoc;
use crate::util::Args;
use anyhow::{bail, Result};
use std::time::Duration;

/// Everything the serving engine needs besides the baked snapshot.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// artifact name (selects model, dataset shapes, eval batch)
    pub artifact: String,
    pub seed: u64,
    /// total requests the synthetic traffic source emits
    pub requests: usize,
    /// admitted requests per device batch; 0 = the artifact's `eval_batch`
    pub max_batch: usize,
    /// admission fill window (microseconds): once a worker picks up the
    /// first request of a batch it waits at most this long for the batch to
    /// fill to `max_batch` before dispatching what accumulated (time spent
    /// queued before pickup is NOT counted against this window)
    pub max_wait_us: u64,
    /// index-generation worker threads feeding the device
    pub workers: usize,
    /// bounded request-queue depth (admission backpressure)
    pub queue_depth: usize,
    /// Zipf exponent of the traffic source's sample popularity; 0 = uniform.
    /// Higher skew concentrates traffic on hot ids — the CAFE-style serving
    /// scenario the snapshot must stay fast under.
    pub zipf_skew: f64,
    /// train this many batches first and serve the best-validation
    /// checkpoint (state + index maps) instead of a random-initialized
    /// model; 0 = skip training (the seed behavior, useful for pure
    /// serving-path benchmarks)
    pub train_steps: usize,
    /// boot straight from an on-disk segment file (zero-copy mmap load)
    /// instead of baking; mutually exclusive with `train_steps` — the
    /// segment already carries the frozen index maps of a specific run
    pub snapshot_path: String,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifact: "quick_cce".into(),
            seed: 0,
            requests: 10_000,
            max_batch: 0,
            max_wait_us: 200,
            workers: 4,
            queue_depth: 4096,
            zipf_skew: 0.99,
            train_steps: 0,
            snapshot_path: String::new(),
        }
    }
}

impl ServeConfig {
    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> ServeConfig {
        self.artifact = args.str_or("artifact", &self.artifact);
        self.seed = args.u64_or("seed", self.seed);
        self.requests = args.usize_or("requests", self.requests);
        self.max_batch = args.usize_or("max-batch", self.max_batch);
        self.max_wait_us = args.u64_or("max-wait-us", self.max_wait_us);
        self.workers = args.usize_or("workers", self.workers);
        self.queue_depth = args.usize_or("queue-depth", self.queue_depth);
        self.zipf_skew = args.f64_or("zipf", self.zipf_skew);
        self.train_steps = args.usize_or("train-steps", self.train_steps);
        self.snapshot_path = args.str_or("snapshot", &self.snapshot_path);
        self
    }

    /// Load from a TOML-subset file ([serve] section).
    pub fn from_toml(doc: &TomlDoc) -> Result<ServeConfig> {
        let mut c = ServeConfig::default();
        for (k, v) in doc.section("serve") {
            match k.as_str() {
                "artifact" => c.artifact = v.as_str().to_string(),
                "seed" => c.seed = v.as_u64()?,
                "requests" => c.requests = v.as_u64()? as usize,
                "max_batch" => c.max_batch = v.as_u64()? as usize,
                "max_wait_us" => c.max_wait_us = v.as_u64()?,
                "workers" => c.workers = v.as_u64()? as usize,
                "queue_depth" => c.queue_depth = v.as_u64()? as usize,
                "zipf_skew" => c.zipf_skew = v.as_f64()?,
                "train_steps" => c.train_steps = v.as_u64()? as usize,
                "snapshot_path" => c.snapshot_path = v.as_str().to_string(),
                other => bail!("unknown [serve] key {other:?}"),
            }
        }
        Ok(c)
    }

    /// Admission deadline as a `Duration`.
    pub fn max_wait(&self) -> Duration {
        Duration::from_micros(self.max_wait_us)
    }

    pub fn validate(&self) -> Result<()> {
        if self.requests == 0 {
            bail!("requests must be ≥ 1");
        }
        if self.workers == 0 || self.queue_depth == 0 {
            bail!("serve workers/queue depth must be ≥ 1");
        }
        if !self.zipf_skew.is_finite() || self.zipf_skew < 0.0 {
            bail!("zipf skew must be a finite value ≥ 0");
        }
        if !self.snapshot_path.is_empty() && self.train_steps > 0 {
            bail!(
                "snapshot_path and train_steps are mutually exclusive: a segment \
                 file already pins one trained model's index maps"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_override_defaults() {
        let args = Args::parse(
            "x --requests 500 --max-batch 64 --workers 8 --zipf 1.2 --max-wait-us 50 \
             --train-steps 300"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = ServeConfig::default().apply_args(&args);
        assert_eq!(c.requests, 500);
        assert_eq!(c.max_batch, 64);
        assert_eq!(c.workers, 8);
        assert_eq!(c.max_wait_us, 50);
        assert_eq!(c.train_steps, 300);
        assert!((c.zipf_skew - 1.2).abs() < 1e-12);
        assert!(c.validate().is_ok());
        assert_eq!(c.max_wait(), Duration::from_micros(50));
    }

    #[test]
    fn toml_round_trip() {
        let doc = TomlDoc::parse(
            "[serve]\nartifact = \"smoke_cce\"\nrequests = 2000\nzipf_skew = 0.0\nworkers = 2\n\
             train_steps = 64\n",
        )
        .unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(c.artifact, "smoke_cce");
        assert_eq!(c.requests, 2000);
        assert_eq!(c.workers, 2);
        assert_eq!(c.zipf_skew, 0.0);
        assert_eq!(c.train_steps, 64);
    }

    #[test]
    fn unknown_toml_key_rejected() {
        let doc = TomlDoc::parse("[serve]\nbogus = 1\n").unwrap();
        assert!(ServeConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn validation_catches_zeroes() {
        let c = ServeConfig { requests: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { workers: 0, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { zipf_skew: -0.1, ..Default::default() };
        assert!(c.validate().is_err());
        let c = ServeConfig { zipf_skew: f64::NAN, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn snapshot_path_layers_and_excludes_training() {
        let doc = TomlDoc::parse("[serve]\nsnapshot_path = \"snaps/gen3.cceseg\"\n").unwrap();
        let c = ServeConfig::from_toml(&doc).unwrap();
        assert_eq!(c.snapshot_path, "snaps/gen3.cceseg");
        assert!(c.validate().is_ok());
        // CLI --snapshot overrides the TOML value
        let args = Args::parse(
            "serve --snapshot other.cceseg".split_whitespace().map(String::from),
        )
        .unwrap();
        let c = c.apply_args(&args);
        assert_eq!(c.snapshot_path, "other.cceseg");
        // serving a segment and training-then-serving are mutually exclusive
        let c = ServeConfig { train_steps: 10, ..c };
        assert!(c.validate().is_err());
    }
}
