//! Run configuration: a TOML-subset parser, typed configs, and presets.
//!
//! Experiments are launched either from presets (`--preset kaggle_small`)
//! or from a config file (`--config run.toml`); CLI flags override both.

mod serve;
mod toml;

pub use serve::ServeConfig;
pub use toml::TomlDoc;

use crate::util::Args;
use anyhow::{bail, Result};

/// Everything a training run needs besides the artifact itself.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// artifact name (selects method, dataset shapes, budget)
    pub artifact: String,
    pub seed: u64,
    pub epochs: usize,
    /// CCE clustering: number of clustering events (ct in the paper)
    pub cluster_times: usize,
    /// batches between clusterings (cf); 0 = once per epoch
    pub cluster_every: usize,
    /// evaluate on the validation split every this many batches
    pub eval_every: usize,
    /// early stopping on validation BCE (paper: stop when the epoch's best
    /// val BCE fails to improve on the previous epoch's best)
    pub early_stop: bool,
    /// shuffle training data each epoch
    pub shuffle: bool,
    /// cap on training batches (0 = no cap; smoke tests use this)
    pub max_batches: usize,
    /// K-means Lloyd iterations at each clustering event
    pub kmeans_iters: usize,
    /// FAISS-style sample budget per centroid
    pub kmeans_points_per_centroid: usize,
    /// offload the K-means inner loop to the PJRT kmeans artifact
    pub kmeans_offload: bool,
    /// overlap clustering events with continued training: compute on a
    /// background worker against a pool snapshot, apply at the first
    /// step boundary where the job is done. Off (synchronous, bit-
    /// reproducible events) by default.
    pub cluster_overlap: bool,
    /// worker threads producing index batches
    pub pipeline_workers: usize,
    /// bounded-queue depth between producers and the exec thread
    pub pipeline_depth: usize,
    /// when non-empty, write a serving segment (generation N) into this
    /// directory after every applied clustering event and for the final
    /// checkpoint — the producer half of the live hot-swap loop
    pub snapshot_dir: String,
    /// retention: after each segment write, prune all but the newest K
    /// generations of this artifact from `snapshot_dir` (the generation
    /// just written is never pruned); 0 = keep every generation
    pub snapshot_keep: usize,
    /// append a JSONL metrics snapshot to this file every
    /// `stats_interval_ms` during the run (docs/OBSERVABILITY.md);
    /// empty = off
    pub stats_out: String,
    /// interval between stats snapshots (milliseconds)
    pub stats_interval_ms: u64,
    /// record spans (train.step, train.event.*, train.snapshot.bake) into
    /// the bounded trace ring and dump a Chrome `trace.json` here after
    /// training; empty = tracing off
    pub trace_out: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifact: "quick_cce".into(),
            seed: 0,
            epochs: 1,
            cluster_times: 1,
            cluster_every: 0,
            eval_every: 0,
            early_stop: false,
            shuffle: true,
            max_batches: 0,
            kmeans_iters: 10,
            kmeans_points_per_centroid: 32,
            kmeans_offload: false,
            cluster_overlap: false,
            pipeline_workers: 2,
            pipeline_depth: 4,
            snapshot_dir: String::new(),
            snapshot_keep: 0,
            stats_out: String::new(),
            stats_interval_ms: 500,
            trace_out: String::new(),
        }
    }
}

impl TrainConfig {
    /// Apply CLI overrides on top of this config.
    pub fn apply_args(mut self, args: &Args) -> TrainConfig {
        self.artifact = args.str_or("artifact", &self.artifact);
        self.seed = args.u64_or("seed", self.seed);
        self.epochs = args.usize_or("epochs", self.epochs);
        self.cluster_times = args.usize_or("cluster-times", self.cluster_times);
        self.cluster_every = args.usize_or("cluster-every", self.cluster_every);
        self.eval_every = args.usize_or("eval-every", self.eval_every);
        if args.flag("early-stop") {
            self.early_stop = true;
        }
        if args.flag("no-shuffle") {
            self.shuffle = false;
        }
        self.max_batches = args.usize_or("max-batches", self.max_batches);
        self.kmeans_iters = args.usize_or("kmeans-iters", self.kmeans_iters);
        if args.flag("kmeans-offload") {
            self.kmeans_offload = true;
        }
        if args.flag("cluster-overlap") {
            self.cluster_overlap = true;
        }
        self.pipeline_workers = args.usize_or("workers", self.pipeline_workers);
        self.pipeline_depth = args.usize_or("queue-depth", self.pipeline_depth);
        self.snapshot_dir = args.str_or("snapshot-dir", &self.snapshot_dir);
        self.snapshot_keep = args.usize_or("snapshot-keep", self.snapshot_keep);
        self.stats_out = args.str_or("stats-out", &self.stats_out);
        self.stats_interval_ms = args.u64_or("stats-interval-ms", self.stats_interval_ms);
        self.trace_out = args.str_or("trace-out", &self.trace_out);
        self
    }

    /// Load from a TOML-subset file ([train] section).
    pub fn from_toml(doc: &TomlDoc) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        for (k, v) in doc.section("train") {
            match k.as_str() {
                "artifact" => c.artifact = v.as_str().to_string(),
                "seed" => c.seed = v.as_u64()?,
                "epochs" => c.epochs = v.as_u64()? as usize,
                "cluster_times" => c.cluster_times = v.as_u64()? as usize,
                "cluster_every" => c.cluster_every = v.as_u64()? as usize,
                "eval_every" => c.eval_every = v.as_u64()? as usize,
                "early_stop" => c.early_stop = v.as_bool()?,
                "shuffle" => c.shuffle = v.as_bool()?,
                "max_batches" => c.max_batches = v.as_u64()? as usize,
                "kmeans_iters" => c.kmeans_iters = v.as_u64()? as usize,
                "kmeans_points_per_centroid" => {
                    c.kmeans_points_per_centroid = v.as_u64()? as usize
                }
                "kmeans_offload" => c.kmeans_offload = v.as_bool()?,
                "cluster_overlap" => c.cluster_overlap = v.as_bool()?,
                "pipeline_workers" => c.pipeline_workers = v.as_u64()? as usize,
                "pipeline_depth" => c.pipeline_depth = v.as_u64()? as usize,
                "snapshot_dir" => c.snapshot_dir = v.as_str().to_string(),
                "snapshot_keep" => c.snapshot_keep = v.as_u64()? as usize,
                "stats_out" => c.stats_out = v.as_str().to_string(),
                "stats_interval_ms" => c.stats_interval_ms = v.as_u64()?,
                "trace_out" => c.trace_out = v.as_str().to_string(),
                other => bail!("unknown [train] key {other:?}"),
            }
        }
        Ok(c)
    }

    pub fn validate(&self) -> Result<()> {
        if self.epochs == 0 {
            bail!("epochs must be ≥ 1");
        }
        if self.pipeline_depth == 0 || self.pipeline_workers == 0 {
            bail!("pipeline workers/depth must be ≥ 1");
        }
        if !self.stats_out.is_empty() && self.stats_interval_ms == 0 {
            bail!("stats_interval_ms must be ≥ 1 when stats_out is set");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_override_defaults() {
        let args = Args::parse(
            "x --artifact quick_ce --epochs 3 --cluster-times 6 --kmeans-offload \
             --cluster-overlap --snapshot-dir snaps --snapshot-keep 3"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        let c = TrainConfig::default().apply_args(&args);
        assert_eq!(c.artifact, "quick_ce");
        assert_eq!(c.epochs, 3);
        assert_eq!(c.cluster_times, 6);
        assert!(c.kmeans_offload);
        assert!(c.cluster_overlap);
        assert_eq!(c.snapshot_dir, "snaps");
        assert_eq!(c.snapshot_keep, 3);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn toml_round_trip() {
        let doc = TomlDoc::parse(
            "[train]\nartifact = \"smoke_cce\"\nepochs = 2\nearly_stop = true\nshuffle = false\n\
             cluster_overlap = true\nsnapshot_dir = \"snaps\"\nsnapshot_keep = 2\n\
             stats_out = \"stats.jsonl\"\ntrace_out = \"trace.json\"\n",
        )
        .unwrap();
        let c = TrainConfig::from_toml(&doc).unwrap();
        assert_eq!(c.artifact, "smoke_cce");
        assert_eq!(c.epochs, 2);
        assert!(c.early_stop);
        assert!(!c.shuffle);
        assert!(c.cluster_overlap);
        assert_eq!(c.snapshot_dir, "snaps");
        assert_eq!(c.snapshot_keep, 2);
        assert_eq!(c.stats_out, "stats.jsonl");
        assert_eq!(c.trace_out, "trace.json");
        assert!(c.validate().is_ok());
        // a stats file with a zero interval would busy-write: rejected
        let bad = TrainConfig { stats_interval_ms: 0, ..c };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn unknown_toml_key_rejected() {
        let doc = TomlDoc::parse("[train]\nbogus = 1\n").unwrap();
        assert!(TrainConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn validation_catches_zeroes() {
        let mut c = TrainConfig::default();
        c.epochs = 0;
        assert!(c.validate().is_err());
    }
}
