//! TOML-subset parser: `[section]` headers and `key = value` pairs where
//! value is a string, integer, float, or boolean. That covers every config
//! file in the repo; arrays/tables-of-tables are intentionally out of scope.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> &str {
        match self {
            TomlValue::Str(s) => s,
            _ => panic!("not a string"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

#[derive(Debug, Default)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut current = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            let value = parse_value(v.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            doc.sections.entry(current.clone()).or_default().insert(key, value);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        TomlDoc::parse(&std::fs::read_to_string(path)?)
    }

    /// Key/value pairs of a section (empty iterator if absent).
    pub fn section(&self, name: &str) -> impl Iterator<Item = (&String, &TomlValue)> {
        self.sections.get(name).into_iter().flatten()
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, ch) in line.char_indices() {
        match ch {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(q) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(q.to_string()));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            "# top comment\n[a]\nx = 1\ny = 2.5\nz = \"hi # not comment\"\nw = true # trailing\n\n[b]\nn = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get("a", "x"), Some(&TomlValue::Int(1)));
        assert_eq!(doc.get("a", "y"), Some(&TomlValue::Float(2.5)));
        assert_eq!(doc.get("a", "z"), Some(&TomlValue::Str("hi # not comment".into())));
        assert_eq!(doc.get("a", "w"), Some(&TomlValue::Bool(true)));
        assert_eq!(doc.get("b", "n").unwrap().as_u64().unwrap(), 1000);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[a]\nno equals here\n").is_err());
        assert!(TomlDoc::parse("[a]\nx = @bad\n").is_err());
    }

    #[test]
    fn missing_section_is_empty() {
        let doc = TomlDoc::parse("[a]\nx = 1\n").unwrap();
        assert_eq!(doc.section("nope").count(), 0);
        assert_eq!(doc.get("a", "missing"), None);
    }

    #[test]
    fn type_coercions() {
        assert!(TomlValue::Int(-1).as_u64().is_err());
        assert_eq!(TomlValue::Int(3).as_f64().unwrap(), 3.0);
        assert!(TomlValue::Str("x".into()).as_bool().is_err());
    }
}
