//! Deterministic interleaving harness (loom-lite, no deps).
//!
//! Model-checks small concurrent scenarios by running their steps under a
//! scheduler that enforces ONE chosen interleaving at a time, with no
//! wall-clock sleeps: a [`Plan`] declares per-thread step lists, a schedule
//! is a sequence of thread indices, and [`explore`] enumerates every
//! interleaving (all multiset permutations that preserve per-thread program
//! order) up to a bound, falling back to deterministic seeded sampling when
//! the space is larger.
//!
//! Steps come in two flavors:
//! * [`step`] — runs to completion before the scheduler grants the next
//!   schedule entry (strict serialization).
//! * [`blocking_step`] — may park inside a lock/condvar (e.g. a bounded
//!   queue `push` against a full queue); the scheduler waits only for the
//!   step to START, then moves on so a later entry can unblock it.
//!
//! The only timeout in the harness is a generous watchdog used purely as a
//! deadlock DETECTOR (it panics with the stuck state); it never orders
//! steps. Scenario invariants live in the plan's `check` closure, which
//! runs after every thread has finished.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One schedulable action of a scenario thread.
pub struct Step {
    name: &'static str,
    blocking: bool,
    run: Box<dyn FnOnce() + Send>,
}

/// A step the scheduler serializes: the next schedule entry is granted only
/// after this one returns.
pub fn step(name: &'static str, f: impl FnOnce() + Send + 'static) -> Step {
    Step { name, blocking: false, run: Box::new(f) }
}

/// A step that may park (blocking queue op, condvar wait): the scheduler
/// waits for it to start, then proceeds so a later entry can unblock it.
pub fn blocking_step(name: &'static str, f: impl FnOnce() + Send + 'static) -> Step {
    Step { name, blocking: true, run: Box::new(f) }
}

/// A scenario: per-thread step lists plus a final invariant check that runs
/// once every thread has finished.
pub struct Plan {
    threads: Vec<Vec<Step>>,
    check: Box<dyn FnOnce() + Send>,
}

impl Plan {
    pub fn new(threads: Vec<Vec<Step>>, check: impl FnOnce() + Send + 'static) -> Plan {
        Plan { threads, check: Box::new(check) }
    }
}

/// Deadlock DETECTOR only — never used to order steps.
const WATCHDOG: Duration = Duration::from_secs(5);

struct CtrlState {
    /// per thread: number of steps granted by the scheduler
    granted: Vec<usize>,
    /// per thread: number of steps that have begun executing
    started: Vec<usize>,
    /// per thread: number of steps that have finished executing
    done: Vec<usize>,
    /// per thread: the worker closure exited (normally or by panic)
    finished: Vec<bool>,
}

struct Ctrl {
    state: Mutex<CtrlState>,
    cv: Condvar,
}

impl Ctrl {
    fn new(n_threads: usize) -> Ctrl {
        Ctrl {
            state: Mutex::new(CtrlState {
                granted: vec![0; n_threads],
                started: vec![0; n_threads],
                done: vec![0; n_threads],
                finished: vec![false; n_threads],
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until `pred` holds; watchdog-panic if it stays false.
    fn wait_until(&self, what: &str, mut pred: impl FnMut(&CtrlState) -> bool) {
        let mut st = self.state.lock().unwrap();
        while !pred(&st) {
            let (s2, to) = self.cv.wait_timeout(st, WATCHDOG).unwrap();
            st = s2;
            if to.timed_out() && !pred(&st) {
                panic!(
                    "interleave watchdog: stuck waiting for {what}; granted={:?} \
                     started={:?} done={:?} finished={:?}",
                    st.granted, st.started, st.done, st.finished
                );
            }
        }
    }

    fn set(&self, update: impl FnOnce(&mut CtrlState)) {
        let mut st = self.state.lock().unwrap();
        update(&mut st);
        self.cv.notify_all();
    }

    /// Scheduler side: pick the first unconsumed schedule entry whose
    /// thread is idle and grant its next step. Entries of finished threads
    /// (a step panicked) are consumed without granting so the scheduler can
    /// drain and let the scope join surface the panic. Returns
    /// `(entry index, Some(step index))` on grant, `(entry index, None)`
    /// on a dead-thread skip.
    fn pick_and_grant(&self, schedule: &[usize], consumed: &[bool]) -> (usize, Option<usize>) {
        let mut st = self.state.lock().unwrap();
        let mut timed_out = false;
        loop {
            for (idx, &t) in schedule.iter().enumerate() {
                if consumed[idx] {
                    continue;
                }
                if st.finished[t] {
                    return (idx, None);
                }
                if st.done[t] == st.granted[t] {
                    let k = st.granted[t];
                    st.granted[t] += 1;
                    self.cv.notify_all();
                    return (idx, Some(k));
                }
            }
            if timed_out {
                panic!(
                    "interleave watchdog: schedule {schedule:?} stuck (every remaining \
                     entry's thread is blocked); granted={:?} done={:?} finished={:?}",
                    st.granted, st.done, st.finished
                );
            }
            let (s2, to) = self.cv.wait_timeout(st, WATCHDOG).unwrap();
            st = s2;
            timed_out = to.timed_out();
        }
    }
}

/// Marks a step done even if it panics, so the scheduler can drain.
struct DoneGuard<'a> {
    ctrl: &'a Ctrl,
    ti: usize,
    k: usize,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let (ti, k) = (self.ti, self.k);
        self.ctrl.set(|st| st.done[ti] = k + 1);
    }
}

/// Marks the thread finished even if a step panics.
struct FinishGuard<'a> {
    ctrl: &'a Ctrl,
    ti: usize,
}

impl Drop for FinishGuard<'_> {
    fn drop(&mut self) {
        let ti = self.ti;
        self.ctrl.set(|st| st.finished[ti] = true);
    }
}

/// Run `plan` under exactly one interleaving. `schedule[j]` names the
/// thread whose next step is granted `j`-th; thread `t` must appear exactly
/// `plan.threads[t].len()` times. Use this to pin a regression schedule
/// found by [`explore`].
pub fn run_one(schedule: &[usize], plan: Plan) {
    let Plan { threads, check } = plan;
    let n_threads = threads.len();
    let mut have = vec![0usize; n_threads];
    for &t in schedule {
        assert!(t < n_threads, "schedule names thread {t}, plan has {n_threads}");
        have[t] += 1;
    }
    let need: Vec<usize> = threads.iter().map(|t| t.len()).collect();
    assert_eq!(have, need, "schedule step counts must match the plan");

    let blocking: Vec<Vec<bool>> =
        threads.iter().map(|s| s.iter().map(|st| st.blocking).collect()).collect();
    let ctrl = Ctrl::new(n_threads);
    std::thread::scope(|s| {
        for (ti, steps) in threads.into_iter().enumerate() {
            let ctrl = &ctrl;
            s.spawn(move || {
                let _fin = FinishGuard { ctrl, ti };
                for (k, step) in steps.into_iter().enumerate() {
                    ctrl.wait_until(step.name, |st| st.granted[ti] > k);
                    ctrl.set(|st| st.started[ti] = k + 1);
                    let _dg = DoneGuard { ctrl, ti, k };
                    (step.run)();
                }
            });
        }
        let mut consumed = vec![false; schedule.len()];
        let mut remaining = schedule.len();
        while remaining > 0 {
            let (idx, granted) = ctrl.pick_and_grant(schedule, &consumed);
            consumed[idx] = true;
            remaining -= 1;
            if let Some(k) = granted {
                let t = schedule[idx];
                ctrl.wait_until("step start", |st| st.started[t] > k);
                if !blocking[t][k] {
                    ctrl.wait_until("step completion", |st| st.done[t] > k);
                }
            }
        }
        ctrl.wait_until("all threads finished", |st| st.finished.iter().all(|&f| f));
    });
    check();
}

/// Number of program-order-preserving interleavings of threads with the
/// given step counts (multinomial coefficient), exact in u128.
pub fn count_interleavings(counts: &[usize]) -> u128 {
    let mut total: u128 = 1;
    let mut seen: u128 = 0;
    for &c in counts {
        for i in 1..=c {
            seen += 1;
            // running product total·C(seen, i) stays integral at each step
            total = total * seen / i as u128;
        }
    }
    total
}

/// Lexicographic next multiset permutation; false once exhausted.
fn next_permutation(v: &mut [usize]) -> bool {
    if v.len() < 2 {
        return false;
    }
    let mut i = v.len() - 1;
    while i > 0 && v[i - 1] >= v[i] {
        i -= 1;
    }
    if i == 0 {
        return false;
    }
    let mut j = v.len() - 1;
    while v[j] <= v[i - 1] {
        j -= 1;
    }
    v.swap(i - 1, j);
    v[i..].reverse();
    true
}

/// Explore the scenario produced by `build` under every interleaving when
/// the space fits in `max_schedules`, otherwise under `max_schedules`
/// deterministic seeded samples (duplicates possible). `build` is called
/// once per schedule and must produce an equivalent plan each time (fresh
/// state, same step structure). Returns the number of schedules run.
pub fn explore(max_schedules: usize, build: impl Fn() -> Plan) -> usize {
    let counts: Vec<usize> = build().threads.iter().map(|t| t.len()).collect();
    let mut base: Vec<usize> = Vec::new();
    for (t, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            base.push(t);
        }
    }
    if base.is_empty() {
        run_one(&[], build());
        return 1;
    }

    let total = count_interleavings(&counts);
    let mut ran = 0usize;
    if total <= max_schedules as u128 {
        // exhaustive: `base` starts lexicographically smallest (sorted)
        let mut schedule = base;
        loop {
            run_schedule(&schedule, build());
            ran += 1;
            if !next_permutation(&mut schedule) {
                break;
            }
        }
    } else {
        // bounded: deterministic seeded Fisher–Yates samples
        let mut lcg = 0x5EED_1E55_C0FF_EE00u64;
        let mut next = move || {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            lcg >> 33
        };
        let mut schedule = base;
        for _ in 0..max_schedules {
            for i in (1..schedule.len()).rev() {
                let j = (next() % (i as u64 + 1)) as usize;
                schedule.swap(i, j);
            }
            run_schedule(&schedule, build());
            ran += 1;
        }
    }
    ran
}

/// `run_one` plus schedule context on failure, so a panicking invariant
/// names the interleaving that produced it.
fn run_schedule(schedule: &[usize], plan: Plan) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_one(schedule, plan);
    }));
    if let Err(payload) = result {
        eprintln!("interleave: failing schedule: {schedule:?}");
        std::panic::resume_unwind(payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn counts_match_multinomial() {
        assert_eq!(count_interleavings(&[2, 1]), 3);
        assert_eq!(count_interleavings(&[2, 2]), 6);
        assert_eq!(count_interleavings(&[3, 3]), 20);
        assert_eq!(count_interleavings(&[1, 1, 1]), 6);
    }

    #[test]
    fn explores_every_interleaving_of_two_one() {
        let logs: Arc<Mutex<Vec<Vec<&'static str>>>> = Arc::new(Mutex::new(Vec::new()));
        let n = explore(100, || {
            let log = Arc::new(Mutex::new(Vec::new()));
            let (a1, a2, b1) = (log.clone(), log.clone(), log.clone());
            let logs = logs.clone();
            Plan::new(
                vec![
                    vec![
                        step("a1", move || a1.lock().unwrap().push("a1")),
                        step("a2", move || a2.lock().unwrap().push("a2")),
                    ],
                    vec![step("b1", move || b1.lock().unwrap().push("b1"))],
                ],
                move || logs.lock().unwrap().push(log.lock().unwrap().clone()),
            )
        });
        assert_eq!(n, 3);
        let seen = logs.lock().unwrap();
        // program order a1 < a2 always; b1 lands in all 3 positions
        let want: [&[&str]; 3] =
            [&["a1", "a2", "b1"], &["a1", "b1", "a2"], &["b1", "a1", "a2"]];
        for w in want {
            assert!(seen.iter().any(|s| s == w), "missing interleaving {w:?} in {seen:?}");
        }
    }

    #[test]
    fn exposes_lost_update_in_some_but_not_all_interleavings() {
        // classic read-modify-write race: two threads each read the cell,
        // then write back read+1. Serialized schedules end at 2; schedules
        // where both read before either writes end at 1 (lost update).
        let finals: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
        let n = explore(100, || {
            let cell = Arc::new(Mutex::new(0usize));
            let tmps: Vec<Arc<Mutex<usize>>> =
                (0..2).map(|_| Arc::new(Mutex::new(0))).collect();
            let mut threads = Vec::new();
            for tmp in &tmps {
                let (rc, rt) = (cell.clone(), tmp.clone());
                let (wc, wt) = (cell.clone(), tmp.clone());
                threads.push(vec![
                    step("read", move || *rt.lock().unwrap() = *rc.lock().unwrap()),
                    step("write", move || *wc.lock().unwrap() = *wt.lock().unwrap() + 1),
                ]);
            }
            let (finals, cell) = (finals.clone(), cell.clone());
            Plan::new(threads, move || finals.lock().unwrap().push(*cell.lock().unwrap()))
        });
        assert_eq!(n, 6);
        let finals = finals.lock().unwrap();
        assert!(finals.contains(&1), "no schedule exposed the lost update: {finals:?}");
        assert!(finals.contains(&2), "no schedule serialized cleanly: {finals:?}");
    }

    #[test]
    fn sampling_mode_bounds_the_schedule_count() {
        let runs = Arc::new(Mutex::new(0usize));
        let runs2 = runs.clone();
        // [3, 3] has 20 interleavings > 5 → seeded sampling caps at 5
        let n = explore(5, move || {
            let runs = runs2.clone();
            let mk = || step("noop", || {});
            Plan::new(
                vec![vec![mk(), mk(), mk()], vec![mk(), mk(), mk()]],
                move || *runs.lock().unwrap() += 1,
            )
        });
        assert_eq!(n, 5);
        assert_eq!(*runs.lock().unwrap(), 5);
    }

    #[test]
    fn blocking_step_is_unblocked_by_a_later_entry() {
        // producer parks on a full bounded channel (capacity 0 rendezvous
        // via Mutex+Condvar stand-in): a sync_channel(1) that is already
        // full blocks the second send until the drainer receives.
        let n = explore(100, || {
            let (tx, rx) = std::sync::mpsc::sync_channel::<u32>(1);
            tx.send(0).unwrap(); // fill the buffer: next send blocks
            let tx2 = tx.clone();
            let got = Arc::new(Mutex::new(Vec::new()));
            let (g1, g2) = (got.clone(), got.clone());
            Plan::new(
                vec![
                    vec![blocking_step("send", move || tx2.send(1).unwrap())],
                    vec![
                        // recv1 never parks: the pre-filled item is always
                        // still buffered when it runs (send only adds)
                        step("recv1", move || g1.lock().unwrap().push(rx.recv().unwrap())),
                        // recv2 may park on the empty channel until the
                        // send entry is granted — must be a blocking step
                        blocking_step("recv2", move || {
                            g2.lock().unwrap().push(rx.recv().unwrap());
                        }),
                    ],
                ],
                move || {
                    assert_eq!(*got.lock().unwrap(), vec![0, 1]);
                },
            )
        });
        assert_eq!(n, 3);
    }
}
