//! Miniature property-testing framework (proptest is unavailable offline):
//! seeded random-input generation with a bounded shrink pass on failure.
//!
//! Usage:
//! ```ignore
//! prop::check(100, |g| {
//!     let n = g.usize(1..100);
//!     let xs = g.vec_f32(n, -1.0..1.0);
//!     prop::assert_prop(invariant(&xs), "invariant violated");
//! });
//! ```

pub mod prop {
    use crate::util::Rng;

    /// Random-input generator handed to each property-test case.
    pub struct Gen {
        rng: Rng,
        /// trace of drawn values for reproduction messages
        pub trace: Vec<String>,
    }

    impl Gen {
        pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
            assert!(!range.is_empty());
            let v = range.start + self.rng.below((range.end - range.start) as u64) as usize;
            self.trace.push(format!("usize={v}"));
            v
        }

        pub fn u32(&mut self, range: std::ops::Range<u32>) -> u32 {
            self.usize(range.start as usize..range.end as usize) as u32
        }

        pub fn u64(&mut self) -> u64 {
            let v = self.rng.next_u64();
            self.trace.push(format!("u64={v}"));
            v
        }

        pub fn f64(&mut self, range: std::ops::Range<f64>) -> f64 {
            let v = self.rng.uniform_in(range.start, range.end);
            self.trace.push(format!("f64={v}"));
            v
        }

        pub fn bool(&mut self) -> bool {
            self.rng.bernoulli(0.5)
        }

        pub fn vec_f32(&mut self, n: usize, range: std::ops::Range<f64>) -> Vec<f32> {
            (0..n).map(|_| self.rng.uniform_in(range.start, range.end) as f32).collect()
        }

        pub fn vec_u32(&mut self, n: usize, below: u32) -> Vec<u32> {
            (0..n).map(|_| self.rng.below(below as u64) as u32).collect()
        }

        pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.usize(0..xs.len())]
        }
    }

    /// Run `f` on `cases` seeded inputs; panic with the failing seed so the
    /// case can be replayed with `check_seed`.
    pub fn check(cases: u64, mut f: impl FnMut(&mut Gen)) {
        let base = std::env::var("CCE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_u64);
        for case in 0..cases {
            let seed = base.wrapping_add(case);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen { rng: Rng::new(seed), trace: Vec::new() };
                f(&mut g);
                g.trace
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property failed on case {case} (seed {seed}; replay with \
                     CCE_PROP_SEED={seed} and cases=1): {msg}"
                );
            }
        }
    }

    /// Assertion that includes the generated-value trace on failure.
    #[macro_export]
    macro_rules! prop_assert {
        ($g:expr, $cond:expr, $($fmt:tt)*) => {
            if !$cond {
                panic!("{} | trace: {:?}", format!($($fmt)*), $g.trace);
            }
        };
    }

    pub use crate::prop_assert;
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::sync::atomic::AtomicU64::new(0);
        prop::check(25, |g| {
            let n = g.usize(1..10);
            assert!(n >= 1 && n < 10);
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_seed() {
        prop::check(10, |g| {
            let n = g.usize(0..100);
            assert!(n < 90, "drew {n}");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut collected = Vec::new();
        prop::check(3, |g| {
            collected.push(g.u64());
        });
        // second run reproduces the same draws (same base seed)
        let mut second = Vec::new();
        prop::check(3, |g| {
            second.push(g.u64());
        });
        assert_eq!(collected[..3], second[..3]);
    }
}
