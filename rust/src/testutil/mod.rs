//! Miniature property-testing framework (proptest is unavailable offline):
//! seeded random-input generation with a bounded shrink pass on failure.
//!
//! Usage:
//! ```ignore
//! prop::check(100, |g| {
//!     let n = g.usize(1..100);
//!     let xs = g.vec_f32(n, -1.0..1.0);
//!     prop::assert_prop(invariant(&xs), "invariant violated");
//! });
//! ```

pub mod interleave;

use std::path::{Path, PathBuf};

/// RAII temp directory for tests that need real files (segments, snapshot
/// dirs). Unique per process + tag so parallel test binaries never collide;
/// recreated fresh on `new` (a leftover from a killed run must not leak
/// state) and removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(tag: &str) -> TempDir {
        let path = std::env::temp_dir().join(format!("cce_{}_{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Fault-injection helpers for segment files: controlled corruption that
/// tests (and the watcher's skip-don't-crash contract) exercise. Every
/// helper damages the file in a way the header-only `load_segment` CANNOT
/// see — that asymmetry is the point: it proves the verified paths
/// (`load_segment_verified`, `SnapshotSlot::install_snapshot`, the watcher)
/// are what stand between a bit flip and live traffic.
pub mod fault {
    use crate::serving::segment::{parse_header, SECTION_NAMES};
    use anyhow::{Context, Result};
    use std::path::Path;

    /// Flip one byte inside the named section's payload (`byte` is taken
    /// modulo the section length). The header — including the section's
    /// STORED checksum — is untouched, so `parse_header`/`load_segment`
    /// still accept the file; only checksum verification catches the flip.
    pub fn flip_section_byte(path: &Path, section: &str, byte: u64) -> Result<()> {
        let mut bytes =
            std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        let h = parse_header(&bytes)?;
        let idx = SECTION_NAMES
            .iter()
            .position(|&n| n == section)
            .with_context(|| format!("unknown section {section:?}"))?;
        let d = h.sections[idx];
        anyhow::ensure!(d.len > 0, "section {section:?} is empty in this segment");
        let off = (d.offset + byte % d.len) as usize;
        bytes[off] ^= 0xFF;
        std::fs::write(path, &bytes).with_context(|| format!("rewrite {}", path.display()))
    }

    /// Cut the file to `keep` bytes — a torn write that crashed before the
    /// tail sections landed. Callers pass `keep >= HEADER_BYTES` to model a
    /// file whose header is intact but whose data is missing; the loader's
    /// `file_len` check rejects it without reading any section.
    pub fn truncate_segment(path: &Path, keep: u64) -> Result<()> {
        let f = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("open {}", path.display()))?;
        f.set_len(keep).with_context(|| format!("truncate {}", path.display()))
    }
}

pub mod prop {
    use crate::util::Rng;

    /// Random-input generator handed to each property-test case.
    pub struct Gen {
        rng: Rng,
        /// trace of drawn values for reproduction messages
        pub trace: Vec<String>,
    }

    impl Gen {
        pub fn usize(&mut self, range: std::ops::Range<usize>) -> usize {
            assert!(!range.is_empty());
            let v = range.start + self.rng.below((range.end - range.start) as u64) as usize;
            self.trace.push(format!("usize={v}"));
            v
        }

        pub fn u32(&mut self, range: std::ops::Range<u32>) -> u32 {
            self.usize(range.start as usize..range.end as usize) as u32
        }

        pub fn u64(&mut self) -> u64 {
            let v = self.rng.next_u64();
            self.trace.push(format!("u64={v}"));
            v
        }

        pub fn f64(&mut self, range: std::ops::Range<f64>) -> f64 {
            let v = self.rng.uniform_in(range.start, range.end);
            self.trace.push(format!("f64={v}"));
            v
        }

        pub fn bool(&mut self) -> bool {
            self.rng.bernoulli(0.5)
        }

        pub fn vec_f32(&mut self, n: usize, range: std::ops::Range<f64>) -> Vec<f32> {
            (0..n).map(|_| self.rng.uniform_in(range.start, range.end) as f32).collect()
        }

        pub fn vec_u32(&mut self, n: usize, below: u32) -> Vec<u32> {
            (0..n).map(|_| self.rng.below(below as u64) as u32).collect()
        }

        pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
            &xs[self.usize(0..xs.len())]
        }
    }

    /// Run `f` on `cases` seeded inputs; panic with the failing seed so the
    /// case can be replayed with `check_seed`.
    pub fn check(cases: u64, mut f: impl FnMut(&mut Gen)) {
        let base = std::env::var("CCE_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5EED_u64);
        for case in 0..cases {
            let seed = base.wrapping_add(case);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut g = Gen { rng: Rng::new(seed), trace: Vec::new() };
                f(&mut g);
                g.trace
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".into());
                panic!(
                    "property failed on case {case} (seed {seed}; replay with \
                     CCE_PROP_SEED={seed} and cases=1): {msg}"
                );
            }
        }
    }

    /// Assertion that includes the generated-value trace on failure.
    #[macro_export]
    macro_rules! prop_assert {
        ($g:expr, $cond:expr, $($fmt:tt)*) => {
            if !$cond {
                panic!("{} | trace: {:?}", format!($($fmt)*), $g.trace);
            }
        };
    }

    pub use crate::prop_assert;
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::sync::atomic::AtomicU64::new(0);
        prop::check(25, |g| {
            let n = g.usize(1..10);
            assert!(n >= 1 && n < 10);
            // ORDERING: Relaxed — single-threaded check loop, no races
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        // ORDERING: Relaxed — same thread as the adds above
        assert_eq!(count.load(std::sync::atomic::Ordering::Relaxed), 25);
    }

    #[test]
    #[should_panic(expected = "property failed on case")]
    fn failing_property_reports_seed() {
        prop::check(10, |g| {
            let n = g.usize(0..100);
            assert!(n < 90, "drew {n}");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut collected = Vec::new();
        prop::check(3, |g| {
            collected.push(g.u64());
        });
        // second run reproduces the same draws (same base seed)
        let mut second = Vec::new();
        prop::check(3, |g| {
            second.push(g.u64());
        });
        assert_eq!(collected[..3], second[..3]);
    }
}
