//! Hash functions and index maps for compressed embedding tables.
//!
//! The paper's framework (§2.1) represents every compression method as a
//! sparse matrix `H`: the Hashing Trick has one random 1 per row, Hash
//! Embeddings two, CE one per column block, and CCE replaces random rows of
//! `H` with *learned* cluster assignments. This module implements both
//! halves: universal hashing (the random `H`) and `IndexMap` (the learned
//! one), plus count-sketch signs, ROBE windows, and DHE feature hashing.

mod universal;

pub use universal::UniversalHash;

use crate::util::Rng;

/// One (feature, term, column) subtable's id→row mapping: either a random
/// universal hash (the "sketch" half of CCE, all of CE/hash-trick/hash-emb)
/// or a learned assignment table from clustering (the "clustered" half).
#[derive(Clone, Debug)]
pub enum IndexMap {
    /// `row = hash(id) % k`
    Hash(UniversalHash),
    /// `row = table[id]`; `len == vocab`, values `< k`.
    Learned(Vec<u32>),
}

impl IndexMap {
    /// Fresh random map into `[0, k)`.
    pub fn random(rng: &mut Rng, k: u32) -> IndexMap {
        IndexMap::Hash(UniversalHash::new(rng, k))
    }

    #[inline]
    pub fn map(&self, id: u32) -> u32 {
        match self {
            IndexMap::Hash(h) => h.hash(id),
            IndexMap::Learned(t) => t[id as usize],
        }
    }

    /// Whether this map came from clustering.
    pub fn is_learned(&self) -> bool {
        matches!(self, IndexMap::Learned(_))
    }

    /// Host memory the map occupies (Appendix E accounting — learned maps
    /// cost `vocab` u32s; universal hashes cost two u64s).
    pub fn host_bytes(&self, _vocab: usize) -> usize {
        match self {
            IndexMap::Hash(_) => 16,
            IndexMap::Learned(t) => t.len() * 4,
        }
    }

    /// Materialize as an assignment table (for entropy metrics).
    pub fn materialize(&self, vocab: usize) -> Vec<u32> {
        match self {
            IndexMap::Hash(h) => (0..vocab as u32).map(|v| h.hash(v)).collect(),
            IndexMap::Learned(t) => {
                assert_eq!(t.len(), vocab);
                t.clone()
            }
        }
    }
}

/// Count-sketch sign function σ: [n] → {−1, +1} (Appendix D). The paper
/// notes signs are unnecessary when M is trained directly; we keep them
/// available for the least-squares experiments where they matter.
#[derive(Clone, Debug)]
pub struct SignHash {
    h: UniversalHash,
}

impl SignHash {
    pub fn new(rng: &mut Rng) -> SignHash {
        SignHash { h: UniversalHash::new(rng, 2) }
    }

    #[inline]
    pub fn sign(&self, id: u32) -> f32 {
        if self.h.hash(id) == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

/// ROBE-style window indexing (Desai et al. 2022): each column `j` of an
/// id's embedding is a contiguous run of `dc` elements starting at a hashed
/// offset inside the feature's flat region, wrapping around the region end.
#[derive(Clone, Debug)]
pub struct RobeWindows {
    /// start hash per column
    starts: Vec<UniversalHash>,
    /// region size in elements
    pub region: u32,
    /// chunk length (d/c)
    pub dc: u32,
}

impl RobeWindows {
    pub fn new(rng: &mut Rng, region: u32, c: u32, dc: u32) -> RobeWindows {
        assert!(region >= dc, "ROBE region {region} smaller than chunk {dc}");
        RobeWindows {
            starts: (0..c).map(|_| UniversalHash::new(rng, region)).collect(),
            region,
            dc,
        }
    }

    /// Number of windows (columns) per id.
    pub fn n_columns(&self) -> usize {
        self.starts.len()
    }

    /// Start offset of column `j`'s window for one id — the hashed value a
    /// baked `ServingSnapshot` materializes per (id, column) so serving can
    /// expand windows without re-hashing.
    #[inline]
    pub fn start(&self, column: usize, id: u32) -> u32 {
        self.starts[column].hash(id)
    }

    /// Write the `c*dc` element offsets (relative to the region base) for
    /// one id into `out`.
    pub fn fill(&self, id: u32, out: &mut [u32]) {
        debug_assert_eq!(out.len(), self.starts.len() * self.dc as usize);
        for (j, h) in self.starts.iter().enumerate() {
            let s = h.hash(id);
            for e in 0..self.dc {
                out[j * self.dc as usize + e as usize] = (s + e) % self.region;
            }
        }
    }
}

/// DHE feature hashing (Kang et al. 2021): k independent hashes mapped to
/// `[-1, 1]` floats that feed the per-feature MLP.
#[derive(Clone, Debug)]
pub struct DheHasher {
    seeds: Vec<u64>,
}

impl DheHasher {
    pub fn new(rng: &mut Rng, n_hash: usize) -> DheHasher {
        DheHasher { seeds: (0..n_hash).map(|_| rng.next_u64() | 1).collect() }
    }

    /// The raw multiplier seeds — what a serving segment persists for the
    /// DHE live-fallback path (`serving::segment`).
    pub fn seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// Rebuild a hasher from persisted seeds; `fill` is then bit-identical
    /// to the hasher the seeds were taken from.
    pub fn from_seeds(seeds: Vec<u64>) -> DheHasher {
        DheHasher { seeds }
    }

    /// Fill `out` (len n_hash) with the id's hash features in `[-1, 1]`.
    pub fn fill(&self, id: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.seeds.len());
        for (o, &s) in out.iter_mut().zip(&self.seeds) {
            let mut x = (id as u64 ^ 0x9E3779B97F4A7C15).wrapping_mul(s);
            x ^= x >> 29;
            x = x.wrapping_mul(0xBF58476D1CE4E5B9);
            x ^= x >> 32;
            // map the top 24 bits to [-1, 1) — plenty of resolution, exact in f32
            *o = ((x >> 40) as f32) / (1u32 << 23) as f32 - 1.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_map_learned_roundtrip() {
        let m = IndexMap::Learned(vec![3, 1, 4, 1, 5]);
        assert_eq!(m.map(2), 4);
        assert!(m.is_learned());
        assert_eq!(m.materialize(5), vec![3, 1, 4, 1, 5]);
        assert_eq!(m.host_bytes(5), 20);
    }

    #[test]
    fn index_map_hash_in_range() {
        let mut rng = Rng::new(1);
        let m = IndexMap::random(&mut rng, 17);
        for id in 0..10_000u32 {
            assert!(m.map(id) < 17);
        }
        assert!(!m.is_learned());
    }

    #[test]
    fn sign_hash_is_pm_one_and_balanced() {
        let mut rng = Rng::new(2);
        let s = SignHash::new(&mut rng);
        let pos: usize = (0..100_000u32).filter(|&i| s.sign(i) > 0.0).count();
        assert!((pos as i64 - 50_000).abs() < 2_000, "pos={pos}");
    }

    #[test]
    fn robe_windows_wrap() {
        let mut rng = Rng::new(3);
        let w = RobeWindows::new(&mut rng, 10, 2, 4);
        let mut out = vec![0u32; 8];
        // find an id whose window wraps
        let mut wrapped = false;
        for id in 0..1000 {
            w.fill(id, &mut out);
            assert!(out.iter().all(|&e| e < 10));
            // consecutive within a chunk modulo region
            for j in 0..2 {
                for e in 1..4 {
                    assert_eq!(out[j * 4 + e], (out[j * 4] + e as u32) % 10);
                }
            }
            if out[1] < out[0] {
                wrapped = true;
            }
        }
        assert!(wrapped, "no window ever wrapped — region too small to test");
    }

    #[test]
    fn dhe_seed_roundtrip_is_bit_identical() {
        let mut rng = Rng::new(9);
        let h = DheHasher::new(&mut rng, 8);
        let h2 = DheHasher::from_seeds(h.seeds().to_vec());
        let (mut a, mut b) = (vec![0f32; 8], vec![0f32; 8]);
        h.fill(77, &mut a);
        h2.fill(77, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn dhe_features_in_unit_ball_and_deterministic() {
        let mut rng = Rng::new(4);
        let h = DheHasher::new(&mut rng, 16);
        let mut a = vec![0f32; 16];
        let mut b = vec![0f32; 16];
        h.fill(12345, &mut a);
        h.fill(12345, &mut b);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (-1.0..=1.0).contains(&x)));
        h.fill(12346, &mut b);
        assert_ne!(a, b);
        // roughly centered
        let mean: f32 = (0..1000u32)
            .map(|id| {
                h.fill(id, &mut a);
                a.iter().sum::<f32>() / 16.0
            })
            .sum::<f32>()
            / 1000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }
}
