//! Multiply-shift universal hashing (Dietzfelbinger et al. 1997) — the
//! paper's Appendix D recommendation: as strong as needed for count-sketch
//! guarantees and two instructions per hash.

use crate::util::Rng;

/// `h(x) = ((a*x + b) >> 32) % k` over u64 arithmetic with odd `a`.
#[derive(Clone, Debug)]
pub struct UniversalHash {
    a: u64,
    b: u64,
    k: u32,
}

impl UniversalHash {
    pub fn new(rng: &mut Rng, k: u32) -> UniversalHash {
        assert!(k > 0);
        UniversalHash { a: rng.next_u64() | 1, b: rng.next_u64(), k }
    }

    /// Construct with explicit parameters (for tests / serialization).
    pub fn from_params(a: u64, b: u64, k: u32) -> UniversalHash {
        UniversalHash { a: a | 1, b, k }
    }

    #[inline]
    pub fn hash(&self, x: u32) -> u32 {
        let m = (self.a.wrapping_mul(x as u64).wrapping_add(self.b)) >> 32;
        // multiply-shift gives 32 uniform bits; reduce by multiply-shift
        // again instead of `%` (no division on the hot path)
        ((m * self.k as u64) >> 32) as u32
    }

    pub fn range(&self) -> u32 {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_in_range() {
        let mut rng = Rng::new(0);
        for k in [1u32, 2, 7, 1000, u32::MAX / 2] {
            let h = UniversalHash::new(&mut rng, k);
            for x in (0..50_000u32).step_by(7) {
                assert!(h.hash(x) < k);
            }
        }
    }

    #[test]
    fn distribution_is_roughly_uniform() {
        let mut rng = Rng::new(1);
        let k = 64u32;
        let h = UniversalHash::new(&mut rng, k);
        let mut counts = vec![0u32; k as usize];
        let n = 640_000u32;
        for x in 0..n {
            counts[h.hash(x) as usize] += 1;
        }
        let expect = (n / k) as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < expect * 0.2, "{counts:?}");
        }
    }

    #[test]
    fn pairwise_collision_rate_near_universal_bound() {
        // collision probability for x≠y should be ≈ 1/k over random draws
        let k = 128u32;
        let mut rng = Rng::new(2);
        let trials = 3_000;
        let mut collisions = 0u32;
        for _ in 0..trials {
            let h = UniversalHash::new(&mut rng, k);
            let x = rng.next_u32() >> 8;
            let mut y = rng.next_u32() >> 8;
            if y == x {
                y ^= 1;
            }
            if h.hash(x) == h.hash(y) {
                collisions += 1;
            }
        }
        let rate = collisions as f64 / trials as f64;
        assert!(rate < 3.0 / k as f64, "rate {rate} vs 1/k {}", 1.0 / k as f64);
    }

    #[test]
    fn deterministic_given_params() {
        let h1 = UniversalHash::from_params(123456789, 42, 1000);
        let h2 = UniversalHash::from_params(123456789, 42, 1000);
        for x in 0..1000 {
            assert_eq!(h1.hash(x), h2.hash(x));
        }
    }
}
