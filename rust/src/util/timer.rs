//! Timing utilities for the custom bench harness (criterion is unavailable
//! offline): warmup + repeated measurement with robust summary statistics.

use std::time::{Duration, Instant};

/// Summary statistics over a set of timed runs.
#[derive(Debug, Clone, Copy)]
pub struct TimingStats {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub max_ns: f64,
}

impl TimingStats {
    /// Zeroed stats with `n == 0`: the honest summary of a run that produced
    /// no samples (e.g. a fully-shed serving run where every request was
    /// rejected or expired before execution).
    pub fn empty() -> TimingStats {
        TimingStats {
            n: 0,
            mean_ns: 0.0,
            std_ns: 0.0,
            min_ns: 0.0,
            p50_ns: 0.0,
            p95_ns: 0.0,
            p99_ns: 0.0,
            max_ns: 0.0,
        }
    }

    pub fn from_samples(mut ns: Vec<f64>) -> TimingStats {
        if ns.is_empty() {
            return TimingStats::empty();
        }
        // total_cmp: a NaN sample (e.g. a 0/0 rate fed back as a sample)
        // must not panic the reporter; NaNs sort to the top and only
        // perturb max/p99 instead of killing the run.
        ns.sort_by(f64::total_cmp);
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        TimingStats {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: percentile(&ns, 0.50),
            p95_ns: percentile(&ns, 0.95),
            p99_ns: percentile(&ns, 0.99),
            max_ns: ns[n - 1],
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }

    /// Human-readable "mean ± std [min, p50, p95, p99]" line.
    pub fn display(&self) -> String {
        format!(
            "{} ± {} (min {}, p50 {}, p95 {}, p99 {}, n={})",
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.p99_ns),
            self.n
        )
    }
}

/// `percentile` over a sorted slice with linear interpolation.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Format nanoseconds with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark a closure: `warmup` unmeasured runs, then `iters` measured.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    TimingStats::from_samples(samples)
}

/// Benchmark for a minimum duration instead of a fixed iteration count.
pub fn bench_for<F: FnMut()>(warmup: usize, min_time: Duration, mut f: F) -> TimingStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < min_time || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if samples.len() > 1_000_000 {
            break;
        }
    }
    TimingStats::from_samples(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_samples() {
        let s = TimingStats::from_samples(vec![100.0; 10]);
        assert_eq!(s.mean_ns, 100.0);
        assert_eq!(s.std_ns, 0.0);
        assert_eq!(s.p95_ns, 100.0);
        assert_eq!(s.p99_ns, 100.0);
    }

    #[test]
    fn empty_samples_yield_zeroed_stats() {
        let s = TimingStats::from_samples(Vec::new());
        assert_eq!(s.n, 0);
        assert_eq!(s.mean_ns, 0.0);
        assert_eq!(s.p99_ns, 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on NaN
        let s = TimingStats::from_samples(vec![100.0, f64::NAN, 50.0]);
        assert_eq!(s.n, 3);
        // NaN totals-order above every number: min and p50 stay finite
        assert_eq!(s.min_ns, 50.0);
        assert!(s.p50_ns.is_finite());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![0.0, 10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 1.0), 30.0);
        assert!((percentile(&xs, 0.5) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50µs");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.2e9), "3.200s");
    }
}
