//! Deterministic pseudo-random number generation.
//!
//! The whole system (synthetic data, initialization, hashing seeds,
//! K-means seeding) is driven from explicit seeds so every experiment is
//! reproducible bit-for-bit. The generator is xoshiro256++ seeded through
//! SplitMix64 — the standard, well-tested combination (Blackman & Vigna).

/// SplitMix64: used to expand a single `u64` seed into generator state and
/// to derive independent stream seeds (`Rng::fork`).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Not cryptographic; fast and statistically strong for
/// simulation workloads.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create from a seed; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (stable given `tag`).
    pub fn fork(&self, tag: u64) -> Rng {
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli draw.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fill a slice with N(0, std) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(0.0, std as f64) as f32;
        }
    }

    /// Fill a slice with U(-limit, limit) f32 values.
    pub fn fill_uniform(&mut self, out: &mut [f32], limit: f32) {
        for v in out.iter_mut() {
            *v = self.uniform_in(-(limit as f64), limit as f64) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm for
    /// small k, shuffle prefix otherwise).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            let pick = if seen.contains(&t) { j } else { t };
            seen.insert(pick);
            out.push(pick);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_streams_differ() {
        let root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 500, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(6);
        for (n, k) in [(100, 5), (100, 80), (10, 10), (1000, 1)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k);
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
