//! Foundation utilities built from scratch (the usual crates — rand, serde,
//! rayon, clap, criterion — are unavailable in this offline environment; see
//! DESIGN.md §3).

pub mod args;
pub mod json;
pub mod logger;
pub mod mmap;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use args::Args;
pub use json::Json;
pub use rng::Rng;
