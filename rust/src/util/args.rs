//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! `--key=value` is also accepted. Unknown flags are an error, which keeps
//! typos from silently running the wrong experiment.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().insert(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key)
            .and_then(|s| s.replace('_', "").parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key)
            .and_then(|s| s.replace('_', "").parse().ok())
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.str_opt(key).map(|v| v != "false").unwrap_or(false)
    }

    /// Comma-separated list.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.str_opt(key) {
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Error if any flag was provided that no accessor ever looked at.
    pub fn reject_unknown(&self) -> Result<(), String> {
        let seen = self.seen.borrow();
        let unknown: Vec<_> = self.flags.keys().filter(|k| !seen.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --preset kaggle_small --seed 7 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("preset", ""), "kaggle_small");
        assert_eq!(a.u64_or("seed", 0), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form_and_underscores() {
        let a = parse("bench --steps=10_000 --lr=0.05");
        assert_eq!(a.usize_or("steps", 0), 10_000);
        assert!((a.f64_or("lr", 0.0) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn lists() {
        let a = parse("sweep --methods hash,cce , --caps 64,256");
        assert_eq!(a.list_or("methods", &[]), vec!["hash", "cce"]);
        assert_eq!(a.list_or("caps", &[]), vec!["64", "256"]);
        assert_eq!(a.list_or("missing", &["x"]), vec!["x"]);
    }

    #[test]
    fn unknown_flags_rejected() {
        let a = parse("run --real-flag 1 --typo-flag 2");
        let _ = a.str_opt("real-flag");
        assert!(a.reject_unknown().is_err());
        let _ = a.str_opt("typo-flag");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn positional_after_doubledash() {
        let a = parse("run --x 1 -- --not-a-flag pos2");
        let _ = a.str_opt("x");
        assert_eq!(a.positional, vec!["--not-a-flag", "pos2"]);
    }
}
