//! Minimal scoped thread pool (rayon/tokio are unavailable offline).
//!
//! Supports the patterns the system needs:
//!   * `scope_chunks` — data-parallel map over index ranges (K-means,
//!     synthetic data generation, linalg).
//!   * `par_for_each_dynamic` / `par_map` / `par_map_with` — dynamic work
//!     queues for uneven item costs (per-feature K-means jobs).
//!   * `BackgroundWorker` — a long-lived worker thread with a
//!     submit/`try_join` handle API, used by the trainer to run clustering
//!     events concurrently with training (ROADMAP "persistent worker
//!     pool"; heavy jobs fan out internally through `par_map_with`).
//!   * long-lived worker threads with bounded channels live in
//!     `coordinator::pipeline`, built on std primitives directly.
//!
//! §Perf log, opt L3-2: `par_map` used to take a `Mutex` per ELEMENT —
//! one lock acquisition for every item, plus a `Vec<Mutex<&mut T>>` of
//! guards built up front. Items are claimed exactly once off the atomic
//! queue, so the slots are disjoint by construction; results now go
//! through a [`SharedSlice`] disjoint-claim write with zero synchronization
//! beyond the queue counter and the scope join (the debug-build claim
//! ledger asserts the disjointness instead of trusting it).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Wrapper that lets a raw pointer cross a scoped-thread boundary. Safe to
/// use only when the parallel writers touch disjoint ranges (each index
/// claimed by exactly one worker). The accessor method forces closures to
/// capture the whole wrapper, not the raw-pointer field — edition-2021
/// disjoint capture would otherwise grab the `!Sync` pointer.
pub struct SyncPtr<T>(*mut T);
// SAFETY: SyncPtr is a plain pointer wrapper with no interior access of its
// own; every dereference happens inside an `unsafe` block whose contract is
// that concurrent users touch disjoint elements. Moving/sharing the wrapper
// therefore only requires the pointee type to be sendable between threads.
unsafe impl<T: Send> Sync for SyncPtr<T> {}
// SAFETY: see the Sync impl above — same disjoint-use contract.
unsafe impl<T: Send> Send for SyncPtr<T> {}
impl<T> SyncPtr<T> {
    pub fn new(p: *mut T) -> SyncPtr<T> {
        SyncPtr(p)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// The audited funnel for disjoint parallel writes into one `&mut [T]`.
///
/// Wraps the buffer behind a [`SyncPtr`] so scoped worker threads can write
/// concurrently, but narrows every access to an explicit, bounds-checked
/// claim: [`write`](SharedSlice::write) for single slots,
/// [`range_mut`](SharedSlice::range_mut) for contiguous chunks. In debug
/// builds a claim ledger asserts that no two claims overlap for the lifetime
/// of the wrapper, turning an aliasing bug into a deterministic panic
/// instead of silent UB; release builds compile the ledger out.
pub struct SharedSlice<'a, T> {
    ptr: SyncPtr<T>,
    len: usize,
    #[cfg(debug_assertions)]
    claims: std::sync::Mutex<Vec<(usize, usize)>>,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: SharedSlice hands out only exclusive, caller-disjoint access to
// the underlying elements (each element reached by at most one thread at a
// time, per the unsafe-method contracts below), so sharing the wrapper only
// ever mutates a `T` from one thread at once — `T: Send` suffices and
// `T: Sync` is not required.
unsafe impl<T: Send> Sync for SharedSlice<'_, T> {}

impl<'a, T> SharedSlice<'a, T> {
    pub fn new(data: &'a mut [T]) -> SharedSlice<'a, T> {
        SharedSlice {
            len: data.len(),
            ptr: SyncPtr::new(data.as_mut_ptr()),
            #[cfg(debug_assertions)]
            claims: std::sync::Mutex::new(Vec::new()),
            _borrow: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Debug-only claim ledger: panics on out-of-bounds or overlapping
    /// claims. Kept sorted by start so each claim costs one binary search
    /// plus two neighbor checks, not a scan of every prior claim.
    #[cfg(debug_assertions)]
    fn claim(&self, start: usize, len: usize) {
        if len == 0 {
            return;
        }
        let end = start + len;
        assert!(end <= self.len, "claim {start}..{end} out of bounds (len {})", self.len);
        let mut claims = self.claims.lock().unwrap();
        let i = claims.partition_point(|&(s, _)| s < start);
        if i > 0 {
            let (ps, pe) = claims[i - 1];
            assert!(pe <= start, "claim {start}..{end} overlaps earlier claim {ps}..{pe}");
        }
        if i < claims.len() {
            let (ns, ne) = claims[i];
            assert!(end <= ns, "claim {start}..{end} overlaps claim {ns}..{ne}");
        }
        claims.insert(i, (start, end));
    }

    /// Claim `start..start + len` as an exclusive chunk.
    ///
    /// # Safety
    ///
    /// The range must be in bounds and must not overlap any other claim on
    /// this wrapper that is still in use (one claimant per element). Debug
    /// builds verify both; release builds trust the caller.
    // disjointness is the caller contract above, ledger-checked in debug
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, start: usize, len: usize) -> &mut [T] {
        #[cfg(debug_assertions)]
        self.claim(start, len);
        // SAFETY: in bounds and non-overlapping per the caller contract, so
        // this exclusive slice aliases no other live reference.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.get().add(start), len) }
    }

    /// Claim slot `i` and assign `v` into it (the previous value is dropped
    /// in place — intended for pre-initialized output buffers such as the
    /// `T::default()`-filled vector in [`par_map_with`]).
    ///
    /// # Safety
    ///
    /// `i` must be in bounds, the slot must hold a valid `T`, and it must be
    /// claimed by exactly one caller across the wrapper's lifetime. Debug
    /// builds verify bounds and exclusivity.
    pub unsafe fn write(&self, i: usize, v: T) {
        #[cfg(debug_assertions)]
        self.claim(i, 1);
        // SAFETY: in bounds and exclusively claimed per the caller contract.
        unsafe { *self.ptr.get().add(i) = v };
    }
}

/// Run `f(chunk_index, start, end)` in parallel over `n` items divided into
/// `n_chunks` contiguous ranges. `f` runs on borrowed state — this is a
/// scoped fork-join, no 'static bound needed.
pub fn scope_chunks<F>(n: usize, n_chunks: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    if n_chunks == 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(n_chunks);
    std::thread::scope(|s| {
        for c in 0..n_chunks {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(c, start, end));
        }
    });
}

/// Parallel map over items with a dynamic work queue (better balance than
/// fixed chunks when item costs vary, e.g. per-feature K-means with very
/// different k).
pub fn par_for_each_dynamic<F>(n: usize, n_threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let n_threads = n_threads.clamp(1, n);
    if n_threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let (next, f) = (&next, &f);
            s.spawn(move || loop {
                // ORDERING: Relaxed suffices for the work-queue ticket — the
                // RMW is atomic (each index handed out once) and any writes
                // done by `f` are published by the scope join, not by this
                // counter.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map collecting results in order. Each index is claimed exactly
/// once off the dynamic queue, so results are written through disjoint
/// [`SharedSlice`] slots — no per-element locking.
pub fn par_map<T, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send + Default,
    F: Fn(usize) -> T + Sync,
{
    par_map_with(n, n_threads, || (), |(), i| f(i))
}

/// `par_map` with a per-WORKER scratch value built by `init` once per
/// thread and threaded through every item that worker claims. This is how
/// the clustering event reuses its `vocab × dc` materialization arenas
/// across `(f, j)` jobs instead of allocating them per job.
pub fn par_map_with<S, T, I, F>(n: usize, n_threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send + Default,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let mut out: Vec<T> = Vec::with_capacity(n);
    out.resize_with(n, T::default);
    if n == 0 {
        return out;
    }
    let n_threads = n_threads.clamp(1, n);
    if n_threads == 1 {
        let mut scratch = init();
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(&mut scratch, i);
        }
        return out;
    }
    let next = AtomicUsize::new(0);
    let shared = SharedSlice::new(&mut out);
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let (next, init, f, shared) = (&next, &init, &f, &shared);
            s.spawn(move || {
                let mut scratch = init();
                loop {
                    // ORDERING: Relaxed suffices for the work-queue ticket —
                    // the RMW is atomic (each index claimed exactly once) and
                    // the slot writes are published by the scope join, not by
                    // this counter.
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let v = f(&mut scratch, i);
                    // SAFETY: index i was claimed by exactly one worker off
                    // the atomic queue and i < n == shared.len(), so every
                    // write targets a distinct in-bounds slot.
                    unsafe { shared.write(i, v) };
                }
            });
        }
    });
    drop(shared); // end the borrow of `out` (the scope has joined)
    out
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A long-lived background worker thread with a submit/`try_join` API.
///
/// Jobs run in submission order on one persistent OS thread; a heavy job
/// (e.g. a clustering event's compute phase) may itself fan out through
/// `par_map_with`/`scope_chunks`. This is the seed of the ROADMAP
/// "persistent worker pool" item: one thread, zero per-job spawn cost,
/// results delivered through per-job [`JobHandle`]s. Dropping the worker
/// closes the queue and joins the thread after in-flight jobs finish.
pub struct BackgroundWorker {
    tx: Option<Sender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BackgroundWorker {
    pub fn new(name: &str) -> BackgroundWorker {
        let (tx, rx) = channel::<Job>();
        let handle = std::thread::Builder::new()
            .name(format!("bg-{name}"))
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    job();
                }
            })
            .expect("spawning background worker thread");
        BackgroundWorker { tx: Some(tx), handle: Some(handle) }
    }

    /// Queue a job; the returned handle yields its result exactly once
    /// (via `try_join` or `join`). Abandoning the handle is fine — the
    /// job still runs, its result is dropped.
    pub fn submit<T, F>(&self, f: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = channel();
        let job: Job = Box::new(move || {
            // the handle may have been dropped; ignore the send error
            let _ = tx.send(f());
        });
        self.tx
            .as_ref()
            .expect("background worker already shut down")
            .send(job)
            .expect("background worker thread died");
        JobHandle { rx, finished: false }
    }
}

impl Drop for BackgroundWorker {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the queue so the loop exits
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Result slot of one [`BackgroundWorker::submit`] call.
pub struct JobHandle<T> {
    rx: Receiver<T>,
    /// set once the result has been taken, so further polls return
    /// `None` instead of misreading the closed channel as a dead job
    finished: bool,
}

impl<T> JobHandle<T> {
    /// Non-blocking poll: `Some(result)` exactly once when the job has
    /// finished, `None` while it is still queued or running (and on any
    /// poll after the result was taken). Panics if the job itself
    /// panicked (its result can never arrive).
    pub fn try_join(&mut self) -> Option<T> {
        if self.finished {
            return None;
        }
        match self.rx.try_recv() {
            Ok(v) => {
                self.finished = true;
                Some(v)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                panic!("background job died before producing a result")
            }
        }
    }

    /// Block until the job finishes and return its result. Panics if the
    /// job panicked or its result was already taken via `try_join`.
    pub fn join(self) -> T {
        assert!(!self.finished, "job result already taken via try_join");
        self.rx.recv().expect("background job died before producing a result")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_chunks_covers_all_items_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 7, |_, s, e| {
            for i in s..e {
                // ORDERING: Relaxed test counter, read only after the join
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        // ORDERING: Relaxed — scope_chunks joined, writes already visible
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scope_chunks_handles_edge_sizes() {
        for (n, c) in [(0, 4), (1, 4), (3, 8), (8, 3)] {
            let count = AtomicU64::new(0);
            scope_chunks(n, c, |_, s, e| {
                // ORDERING: Relaxed test counter, read only after the join
                count.fetch_add((e - s) as u64, Ordering::Relaxed);
            });
            // ORDERING: Relaxed — scope joined, writes already visible
            assert_eq!(count.load(Ordering::Relaxed), n as u64);
        }
    }

    #[test]
    fn dynamic_queue_processes_everything() {
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        par_for_each_dynamic(257, 5, |i| {
            // ORDERING: Relaxed test counter, read only after the join
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        // ORDERING: Relaxed — the dynamic scope joined before this read
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_handles_non_clone_payloads() {
        // the old Mutex-slot implementation required Clone; heap payloads
        // must come back in order with no item lost or duplicated
        let out = par_map(257, 6, |i| vec![i; 3]);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v, &vec![i; 3]);
        }
    }

    #[test]
    fn par_map_with_reuses_worker_scratch() {
        // scratch is per worker: the sum of per-item scratch generations
        // equals the item count, and every slot is filled in order
        let inits = AtomicUsize::new(0);
        let out = par_map_with(
            200,
            4,
            || {
                // ORDERING: Relaxed test counter, read only after the join
                inits.fetch_add(1, Ordering::Relaxed);
                Vec::<usize>::new()
            },
            |scratch, i| {
                scratch.push(i); // arena grows, never reallocated per item
                i * 2
            },
        );
        assert_eq!(out, (0..200).map(|i| i * 2).collect::<Vec<_>>());
        // ORDERING: Relaxed — par_map_with joined, writes already visible
        assert!(inits.load(Ordering::Relaxed) <= 4, "scratch built per worker, not per item");
    }

    #[test]
    fn par_map_with_bit_identical_across_thread_sweep() {
        // arena-reuse stress: ragged n across the full thread sweep must be
        // bit-identical with the single-threaded result (ordered output,
        // disjoint SharedSlice writes, per-worker scratch reuse)
        for n in [1usize, 13, 97, 257] {
            let payload = |i: usize| {
                let x = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
                vec![x, x ^ 0xdead_beef, x.rotate_left(17)]
            };
            let want: Vec<Vec<u64>> = (0..n).map(payload).collect();
            for threads in [1usize, 2, 3, 7, 16] {
                let got = par_map_with(n, threads, Vec::<u64>::new, |scratch, i| {
                    scratch.push(i as u64); // arena grows across claimed items
                    payload(i)
                });
                assert_eq!(got, want, "n={n} threads={threads}");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overlaps")]
    fn shared_slice_overlapping_claims_panic_in_debug() {
        let mut data = vec![0u32; 8];
        let s = SharedSlice::new(&mut data);
        // SAFETY: 0..4 is in bounds and unclaimed.
        let _a = unsafe { s.range_mut(0, 4) };
        // SAFETY: never materializes — the overlapping claim is the point of
        // the test; the ledger panics before any aliasing reference exists.
        let _b = unsafe { s.range_mut(2, 4) };
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of bounds")]
    fn shared_slice_out_of_bounds_claim_panics_in_debug() {
        let mut data = vec![0u32; 8];
        let s = SharedSlice::new(&mut data);
        // SAFETY: never materializes — the ledger rejects the range first
        let _a = unsafe { s.range_mut(4, 8) };
    }

    #[test]
    fn par_map_result_independent_of_thread_count() {
        let want: Vec<usize> = (0..123).map(|i| i + 7).collect();
        for threads in [1, 2, 5, 16] {
            assert_eq!(par_map(123, threads, |i| i + 7), want);
        }
    }

    #[test]
    fn background_worker_returns_results_per_job() {
        let w = BackgroundWorker::new("test");
        let h1 = w.submit(|| 6 * 7);
        let h2 = w.submit(|| "done".to_string());
        assert_eq!(h1.join(), 42);
        assert_eq!(h2.join(), "done");
    }

    #[test]
    fn background_worker_try_join_polls_without_blocking() {
        let w = BackgroundWorker::new("test");
        // gate the job on a channel so the first poll observes "running"
        let (gate_tx, gate_rx) = channel::<()>();
        let mut h = w.submit(move || {
            gate_rx.recv().unwrap();
            123usize
        });
        assert!(h.try_join().is_none(), "job cannot finish before the gate opens");
        gate_tx.send(()).unwrap();
        // poll until the result lands (deadline only to bound a deadlock)
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        let mut got = None;
        while got.is_none() && std::time::Instant::now() < deadline {
            got = h.try_join();
            std::thread::yield_now();
        }
        assert_eq!(got, Some(123));
        // polling again after the result was taken is a no-op, not a panic
        assert!(h.try_join().is_none());
    }

    #[test]
    fn background_worker_runs_jobs_in_submission_order() {
        let w = BackgroundWorker::new("test");
        let log = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let log = log.clone();
                w.submit(move || log.lock().unwrap().push(i))
            })
            .collect();
        for h in handles {
            h.join();
        }
        assert_eq!(*log.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn background_worker_drop_joins_cleanly_with_abandoned_handle() {
        let w = BackgroundWorker::new("test");
        let _ = w.submit(|| vec![0u8; 64]); // handle dropped immediately
        drop(w); // must not hang or panic
    }
}
