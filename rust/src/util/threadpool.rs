//! Minimal scoped thread pool (rayon/tokio are unavailable offline).
//!
//! Supports the two patterns the system needs:
//!   * `scope_chunks` — data-parallel map over index ranges (K-means,
//!     synthetic data generation, linalg).
//!   * long-lived worker threads with bounded channels live in
//!     `coordinator::pipeline`, built on std primitives directly.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of worker threads to use by default (cores, capped).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(chunk_index, start, end)` in parallel over `n` items divided into
/// `n_chunks` contiguous ranges. `f` runs on borrowed state — this is a
/// scoped fork-join, no 'static bound needed.
pub fn scope_chunks<F>(n: usize, n_chunks: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let n_chunks = n_chunks.clamp(1, n);
    if n_chunks == 1 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(n_chunks);
    std::thread::scope(|s| {
        for c in 0..n_chunks {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(c, start, end));
        }
    });
}

/// Parallel map over items with a dynamic work queue (better balance than
/// fixed chunks when item costs vary, e.g. per-feature K-means with very
/// different k).
pub fn par_for_each_dynamic<F>(n: usize, n_threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let n_threads = n_threads.clamp(1, n);
    if n_threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let next = Arc::clone(&next);
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map collecting results in order.
pub fn par_map<T, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        par_for_each_dynamic(n, n_threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = f(i);
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn scope_chunks_covers_all_items_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        scope_chunks(1000, 7, |_, s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn scope_chunks_handles_edge_sizes() {
        for (n, c) in [(0, 4), (1, 4), (3, 8), (8, 3)] {
            let count = AtomicU64::new(0);
            scope_chunks(n, c, |_, s, e| {
                count.fetch_add((e - s) as u64, Ordering::Relaxed);
            });
            assert_eq!(count.load(Ordering::Relaxed), n as u64);
        }
    }

    #[test]
    fn dynamic_queue_processes_everything() {
        let hits: Vec<AtomicUsize> = (0..257).map(|_| AtomicUsize::new(0)).collect();
        par_for_each_dynamic(257, 5, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_preserves_order() {
        let out = par_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }
}
