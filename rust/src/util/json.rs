//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar the artifact manifests use: objects,
//! arrays, strings (with escapes), numbers, booleans, null. Numbers are
//! stored as f64 (the manifests clamp anything larger than 2^40, well
//! inside f64's exact-integer range).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that fails loudly with the key name.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a number"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a number"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not a string"))
    }

    pub fn usize_array(&self, key: &str) -> anyhow::Result<Vec<usize>> {
        Ok(self
            .req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("key {key:?} is not an array"))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect())
    }

    // -- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building JSON output.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].str_field("b").unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":false,"n":null,"big":1099511627776}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 3, "s": "x", "a": [1,2]}"#).unwrap();
        assert_eq!(j.usize_field("n").unwrap(), 3);
        assert_eq!(j.str_field("s").unwrap(), "x");
        assert_eq!(j.usize_array("a").unwrap(), vec![1, 2]);
        assert!(j.usize_field("missing").is_err());
    }
}
