//! Stderr logger for the `log` crate facade, with wall-clock timestamps
//! relative to process start (useful when reading training logs).

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5}] {}", record.level(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Level comes from `CCE_LOG`
/// (error|warn|info|debug|trace), defaulting to `info`.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let level = match std::env::var("CCE_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }
}
