//! Stderr logger for the `log` crate facade, with wall-clock timestamps
//! relative to process start (useful when reading training logs).

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5}] {}", record.level(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Map a `CCE_LOG` value to a level; `None` for unrecognized values.
fn parse_level(v: &str) -> Option<log::LevelFilter> {
    match v {
        "error" => Some(log::LevelFilter::Error),
        "warn" => Some(log::LevelFilter::Warn),
        "info" => Some(log::LevelFilter::Info),
        "debug" => Some(log::LevelFilter::Debug),
        "trace" => Some(log::LevelFilter::Trace),
        _ => None,
    }
}

/// Install the logger (idempotent). Level comes from `CCE_LOG`
/// (error|warn|info|debug|trace), defaulting to `info`; an unrecognized
/// value warns once instead of silently meaning `info`.
pub fn init() {
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now() });
    let var = std::env::var("CCE_LOG").ok();
    let parsed = var.as_deref().map(parse_level);
    let level = parsed.flatten().unwrap_or(log::LevelFilter::Info);
    let _ = log::set_logger(logger);
    log::set_max_level(level);
    if let (Some(raw), Some(None)) = (var.as_deref().filter(|v| !v.is_empty()), parsed) {
        // after set_logger so the warning itself goes through the
        // timestamped format; OnceLock-guarded so repeated init() calls
        // (tests, library embedders) warn only once
        static WARNED: OnceLock<()> = OnceLock::new();
        WARNED.get_or_init(|| {
            log::warn!(
                "unknown CCE_LOG level {raw:?}; accepted: error|warn|info|debug|trace \
                 (falling back to info)"
            );
        });
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke test");
    }

    #[test]
    fn recognizes_exactly_the_documented_levels() {
        for (v, want) in [
            ("error", log::LevelFilter::Error),
            ("warn", log::LevelFilter::Warn),
            ("info", log::LevelFilter::Info),
            ("debug", log::LevelFilter::Debug),
            ("trace", log::LevelFilter::Trace),
        ] {
            assert_eq!(super::parse_level(v), Some(want));
        }
        for v in ["INFO", "verbose", "warning", "", "2"] {
            assert_eq!(super::parse_level(v), None, "{v:?} should be unrecognized");
        }
    }
}
