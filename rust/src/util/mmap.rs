//! Read-only memory-mapped files for zero-copy snapshot loading.
//!
//! Serving cold start must not scale with table size (ROADMAP: "millisecond
//! cold start"), so segment files are `mmap(2)`ed and served straight off the
//! page cache instead of being copied into heap vectors. No crate deps: the
//! two syscalls are declared via `extern "C"` against the libc that `std`
//! already links on unix targets. When `mmap` is unavailable (non-unix, or a
//! filesystem that refuses it) we fall back to ONE buffered read into an
//! 8-byte-aligned heap buffer — correctness is identical, only cold-start
//! latency and memory residency differ.
//!
//! Alignment contract: the mapping base is page-aligned (mmap) or 8-byte
//! aligned (heap fallback backed by `Vec<u64>`), and segment sections are
//! 64-byte aligned within the file, so the `as_u32s`/`as_i32s`/`as_f32s`/
//! `as_u64s` reinterpretation helpers below are always in-bounds and aligned
//! for section slices. They assert both properties rather than trusting the
//! caller.

use anyhow::{Context, Result};
use std::fs::File;
use std::io::Read;
use std::path::Path;

#[cfg(unix)]
mod sys {
    use core::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

enum Backing {
    /// Kernel mapping; unmapped on drop.
    #[cfg(unix)]
    Mmap,
    /// Heap fallback. The vec is the allocation `ptr` points into; `u64`
    /// elements guarantee 8-byte base alignment.
    Heap(#[allow(dead_code)] Vec<u64>),
}

/// A whole file exposed as one immutable byte slice.
pub struct MappedFile {
    ptr: *const u8,
    len: usize,
    backing: Backing,
}

// SAFETY: the mapping is read-only for the lifetime of the struct and the
// backing (kernel pages or an owned Vec) cannot move, so the owner can change
// threads freely.
unsafe impl Send for MappedFile {}
// SAFETY: all access goes through `&self` methods over immutable memory —
// concurrent readers never race (same read-only/pinned argument as Send).
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// Map `path` read-only, falling back to a single buffered read.
    pub fn open(path: &Path) -> Result<MappedFile> {
        let mut file =
            File::open(path).with_context(|| format!("open {} for mapping", path.display()))?;
        let len = file.metadata()?.len() as usize;
        // Miri has no mmap shim: skip the syscall attempt so `cargo miri
        // test` deterministically exercises the heap fallback below.
        #[cfg(all(unix, not(miri)))]
        if len > 0 {
            use std::os::unix::io::AsRawFd;
            // SAFETY: a fresh anonymous-address PROT_READ/MAP_PRIVATE
            // mapping of a file we hold open; len > 0 and offset 0 are
            // valid for the fd, and the result is checked against
            // MAP_FAILED before use.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_PRIVATE,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr != sys::map_failed() {
                return Ok(MappedFile { ptr: ptr as *const u8, len, backing: Backing::Mmap });
            }
            log::warn!("mmap({}) failed; falling back to a buffered read", path.display());
        }
        // Fallback: one read into an 8-byte-aligned buffer.
        let mut buf = vec![0u64; len.div_ceil(8)];
        if len > 0 {
            let ptr = buf.as_mut_ptr() as *mut u8;
            // SAFETY: the Vec allocation holds div_ceil(len, 8) u64s ≥ len
            // bytes, u8 has no alignment requirement, and `bytes` is the
            // only live reference to the buffer while it is written.
            let bytes = unsafe { std::slice::from_raw_parts_mut(ptr, len) };
            file.read_exact(bytes)
                .with_context(|| format!("read {} into fallback buffer", path.display()))?;
        }
        Ok(MappedFile { ptr: buf.as_ptr() as *const u8, len, backing: Backing::Heap(buf) })
    }

    pub fn bytes(&self) -> &[u8] {
        if self.len == 0 {
            return &[];
        }
        // SAFETY: ptr/len describe the live backing (kernel mapping or the
        // owned heap Vec), immutable and pinned until Drop.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Whether the fast path (true zero-copy kernel mapping) was taken.
    pub fn is_mmap(&self) -> bool {
        #[cfg(unix)]
        return matches!(self.backing, Backing::Mmap);
        #[cfg(not(unix))]
        false
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(unix)]
        if matches!(self.backing, Backing::Mmap) {
            // SAFETY: ptr/len are exactly what mmap returned for this
            // struct, unmapped exactly once here; no slice into the
            // mapping can outlive the struct that owns it.
            unsafe { sys::munmap(self.ptr as *mut core::ffi::c_void, self.len) };
        }
    }
}

macro_rules! cast_helper {
    ($name:ident, $ty:ty) => {
        /// Reinterpret aligned raw bytes as a typed slice. All bit patterns
        /// are valid for the target type, so given the asserted alignment
        /// and length this is sound.
        pub fn $name(bytes: &[u8]) -> &[$ty] {
            let size = std::mem::size_of::<$ty>();
            assert_eq!(bytes.len() % size, 0, "byte length {} not /{size}", bytes.len());
            assert_eq!(bytes.as_ptr() as usize % size, 0, "misaligned {} slice", stringify!($ty));
            // SAFETY: length divisibility and pointer alignment were just
            // asserted, the target type accepts all bit patterns, and the
            // borrow keeps the bytes immutable for the slice's lifetime.
            unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const $ty, bytes.len() / size) }
        }
    };
}

cast_helper!(as_u32s, u32);
cast_helper!(as_i32s, i32);
cast_helper!(as_f32s, f32);
cast_helper!(as_u64s, u64);

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("cce_mmap_{}_{tag}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_contents_exactly() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let p = tmp("contents", &data);
        let m = MappedFile::open(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(m.bytes(), &data[..]);
        #[cfg(all(target_os = "linux", not(miri)))]
        assert!(m.is_mmap(), "linux should take the mmap fast path");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_file_maps_to_empty_slice() {
        let p = tmp("empty", &[]);
        let m = MappedFile::open(&p).unwrap();
        assert!(m.is_empty());
        assert_eq!(m.bytes(), &[] as &[u8]);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn cast_helpers_roundtrip_le_values() {
        let vals = [1u32, 0xDEAD_BEEF, u32::MAX];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = tmp("cast", &bytes);
        let m = MappedFile::open(&p).unwrap();
        assert_eq!(as_u32s(m.bytes()), &vals[..]);
        std::fs::remove_file(&p).ok();
    }

    /// An 8-byte-aligned byte view of `words`, starting `offset` bytes in.
    /// Misaligning is the point: the cast helpers must reject it, never
    /// build the typed slice.
    fn view(words: &[u64], offset: usize, len: usize) -> &[u8] {
        assert!(offset + len <= words.len() * 8);
        // SAFETY: in bounds of the u64 allocation per the assert above; u8
        // views have no alignment requirement and the borrow of `words`
        // keeps the bytes alive and immutable.
        unsafe { std::slice::from_raw_parts((words.as_ptr() as *const u8).add(offset), len) }
    }

    #[test]
    #[should_panic(expected = "not /4")]
    fn cast_rejects_ragged_length() {
        let buf = vec![0u64; 1];
        let _ = as_u32s(view(&buf, 0, 7));
    }

    #[test]
    #[should_panic(expected = "not /4")]
    fn cast_i32_rejects_truncated_tail() {
        let buf = vec![0u64; 1];
        let _ = as_i32s(view(&buf, 0, 5));
    }

    #[test]
    #[should_panic(expected = "not /4")]
    fn cast_f32_rejects_truncated_tail() {
        let buf = vec![0u64; 1];
        let _ = as_f32s(view(&buf, 0, 6));
    }

    #[test]
    #[should_panic(expected = "not /8")]
    fn cast_u64_rejects_truncated_tail() {
        let buf = vec![0u64; 2];
        let _ = as_u64s(view(&buf, 0, 12));
    }

    #[test]
    #[should_panic(expected = "misaligned u32 slice")]
    fn cast_u32_rejects_misaligned_offset() {
        let buf = vec![0u64; 2];
        let _ = as_u32s(view(&buf, 1, 12));
    }

    #[test]
    #[should_panic(expected = "misaligned i32 slice")]
    fn cast_i32_rejects_misaligned_offset() {
        let buf = vec![0u64; 2];
        let _ = as_i32s(view(&buf, 2, 8));
    }

    #[test]
    #[should_panic(expected = "misaligned f32 slice")]
    fn cast_f32_rejects_misaligned_offset() {
        let buf = vec![0u64; 2];
        let _ = as_f32s(view(&buf, 3, 12));
    }

    #[test]
    #[should_panic(expected = "misaligned u64 slice")]
    fn cast_u64_rejects_misaligned_offset() {
        let buf = vec![0u64; 3];
        let _ = as_u64s(view(&buf, 4, 16));
    }
}
