//! Batch assembly over the synthetic stream: splits, epochs, shuffling,
//! and last-batch padding (batch size is baked into each HLO artifact).

use crate::data::synthetic::SyntheticDataset;
use crate::util::Rng;

/// Which partition of the stream to read. Mirrors the paper: train on the
/// first days, validate and test on disjoint halves of the final day.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Val,
    Test,
}

/// One host-side batch, ready for index generation + upload.
#[derive(Clone, Debug)]
pub struct Batch {
    pub dense: Vec<f32>,
    pub cats: Vec<u32>,
    pub labels: Vec<f32>,
    pub batch_size: usize,
    /// number of real (non-padding) samples; < batch_size only on the
    /// final batch of a split
    pub real: usize,
}

/// Iterator producing fixed-size batches from a split, optionally shuffled
/// per epoch (sample order is a permutation of the split's index range).
pub struct BatchIter<'a> {
    ds: &'a SyntheticDataset,
    order: Vec<u32>,
    pos: usize,
    batch_size: usize,
    base: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(
        ds: &'a SyntheticDataset,
        split: Split,
        batch_size: usize,
        shuffle_seed: Option<u64>,
    ) -> BatchIter<'a> {
        let (base, len) = split_range(ds, split);
        let mut order: Vec<u32> = (0..len as u32).collect();
        if let Some(seed) = shuffle_seed {
            Rng::new(seed).shuffle(&mut order);
        }
        BatchIter { ds, order, pos: 0, batch_size, base }
    }

    pub fn n_batches(&self) -> usize {
        self.order.len().div_ceil(self.batch_size)
    }

    pub fn n_samples(&self) -> usize {
        self.order.len()
    }

    /// Fill the next batch into `out`; returns false at end of split.
    /// Padding repeats the last real sample of the batch — padded rows are
    /// EXCLUDED from metrics via `Batch::real`.
    pub fn next_into(&mut self, out: &mut Batch) -> bool {
        if self.pos >= self.order.len() {
            return false;
        }
        let f_n = self.ds.n_features();
        let n_dense = self.ds.spec.n_dense;
        debug_assert_eq!(out.batch_size, self.batch_size);
        let real = (self.order.len() - self.pos).min(self.batch_size);
        for b in 0..self.batch_size {
            let src = self.base + self.order[self.pos + b.min(real - 1)] as usize;
            let dense = &mut out.dense[b * n_dense..(b + 1) * n_dense];
            let cats = &mut out.cats[b * f_n..(b + 1) * f_n];
            out.labels[b] = self.ds.sample_into(src, dense, cats);
        }
        out.real = real;
        self.pos += real;
        true
    }

    /// Skip the next `n` batches without generating them (used by striped
    /// pipeline workers so each worker only pays for its own stripe).
    pub fn skip_batches(&mut self, n: usize) {
        self.pos = (self.pos + n * self.batch_size).min(self.order.len());
    }

    pub fn alloc_batch(&self) -> Batch {
        Batch {
            dense: vec![0.0; self.batch_size * self.ds.spec.n_dense],
            cats: vec![0; self.batch_size * self.ds.n_features()],
            labels: vec![0.0; self.batch_size],
            batch_size: self.batch_size,
            real: 0,
        }
    }
}

fn split_range(ds: &SyntheticDataset, split: Split) -> (usize, usize) {
    let s = &ds.spec;
    match split {
        Split::Train => (0, s.train_samples),
        Split::Val => (s.train_samples, s.val_samples),
        Split::Test => (s.train_samples + s.val_samples, s.test_samples),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::DatasetSpec;

    fn ds() -> SyntheticDataset {
        SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50],
            n_dense: 3,
            train_samples: 100,
            val_samples: 37,
            test_samples: 20,
            latent_clusters: 4,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: 1,
        })
    }

    #[test]
    fn covers_split_exactly_once_unshuffled() {
        let ds = ds();
        let mut it = BatchIter::new(&ds, Split::Val, 16, None);
        assert_eq!(it.n_batches(), 3);
        assert_eq!(it.n_samples(), 37);
        let mut b = it.alloc_batch();
        let mut total = 0;
        while it.next_into(&mut b) {
            total += b.real;
            assert!(b.real <= 16);
        }
        assert_eq!(total, 37);
    }

    #[test]
    fn final_batch_padding_repeats_real_sample() {
        let ds = ds();
        let mut it = BatchIter::new(&ds, Split::Test, 16, None);
        let mut b = it.alloc_batch();
        it.next_into(&mut b); // 16 real
        it.next_into(&mut b); // 4 real + 12 pad
        assert_eq!(b.real, 4);
        // padded rows copy the last real row of the batch
        assert_eq!(b.labels[4], b.labels[3]);
        assert_eq!(b.cats[4 * 2..5 * 2], b.cats[3 * 2..4 * 2]);
        assert_eq!(b.labels[15], b.labels[3]);
    }

    #[test]
    fn shuffle_is_permutation_and_seed_dependent() {
        let ds = ds();
        let collect = |seed: Option<u64>| {
            let mut it = BatchIter::new(&ds, Split::Train, 10, seed);
            let mut b = it.alloc_batch();
            let mut all = Vec::new();
            while it.next_into(&mut b) {
                all.extend_from_slice(&b.labels[..b.real]);
            }
            all
        };
        let plain = collect(None);
        let sh1 = collect(Some(5));
        let sh2 = collect(Some(5));
        let sh3 = collect(Some(6));
        assert_eq!(sh1, sh2);
        assert_eq!(plain.len(), sh1.len());
        assert_ne!(plain, sh3); // overwhelmingly likely
        // same multiset of labels
        let count = |v: &[f32]| v.iter().filter(|&&x| x > 0.5).count();
        assert_eq!(count(&plain), count(&sh1));
    }

    #[test]
    fn splits_are_disjoint() {
        // val and test read different underlying sample indices: compare
        // the first sample of each against direct generation
        let ds = ds();
        let mut itv = BatchIter::new(&ds, Split::Val, 1, None);
        let mut itt = BatchIter::new(&ds, Split::Test, 1, None);
        let mut bv = itv.alloc_batch();
        let mut bt = itt.alloc_batch();
        itv.next_into(&mut bv);
        itt.next_into(&mut bt);
        let mut d = vec![0f32; 3];
        let mut c = vec![0u32; 2];
        let yv = ds.sample_into(100, &mut d, &mut c);
        assert_eq!(bv.labels[0], yv);
        let yt = ds.sample_into(137, &mut d, &mut c);
        assert_eq!(bt.labels[0], yt);
    }
}
