//! Zipf-distributed sampling over `[0, n)` by rejection-inversion
//! (Hörmann & Derflinger 1996) — O(1) per draw with no per-vocabulary
//! tables, which matters because the terabyte-sim preset has vocabularies
//! over a million values × 26 features.
//!
//! P(X = k) ∝ (k + 1)^(−s), so value 0 is the most frequent — matching the
//! head-heavy id distribution of real click logs.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    q: f64,
    // rejection-inversion constants (Hörmann & Derflinger, as in rand_distr)
    h_x1: f64,
    h_n: f64,
    s_accept: f64,
    dense: Option<Vec<f64>>, // CDF for tiny n (faster + exact)
}

impl Zipf {
    pub fn new(n: u64, q: f64) -> Zipf {
        assert!(n >= 1);
        assert!(q > 0.0 && (q - 1.0).abs() > 1e-9, "q=1 needs the harmonic special case");
        if n <= 64 {
            // tiny vocab: exact CDF inversion
            let mut cdf = Vec::with_capacity(n as usize);
            let mut acc = 0.0;
            for k in 0..n {
                acc += ((k + 1) as f64).powf(-q);
                cdf.push(acc);
            }
            let total = acc;
            for c in cdf.iter_mut() {
                *c /= total;
            }
            return Zipf { n, q, h_x1: 0.0, h_n: 0.0, s_accept: 0.0, dense: Some(cdf) };
        }
        let h = |x: f64| x.powf(1.0 - q) / (1.0 - q);
        let h_inv = |u: f64| (u * (1.0 - q)).powf(1.0 / (1.0 - q));
        let h_x1 = h(1.5) - 1.0;
        let h_n = h(n as f64 + 0.5);
        let s_accept = 2.0 - h_inv(h(2.5) - 2f64.powf(-q));
        Zipf { n, q, h_x1, h_n, s_accept, dense: None }
    }

    /// Draw one value in `[0, n)`; rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if let Some(cdf) = &self.dense {
            let u = rng.uniform();
            return cdf.partition_point(|&c| c < u).min(self.n as usize - 1) as u64;
        }
        let q = self.q;
        let h = |x: f64| x.powf(1.0 - q) / (1.0 - q);
        let h_inv = |u: f64| (u * (1.0 - q)).powf(1.0 / (1.0 - q));
        loop {
            let u = self.h_n + rng.uniform() * (self.h_x1 - self.h_n);
            let x = h_inv(u);
            let k = x.round().clamp(1.0, self.n as f64);
            if (k - x).abs() <= self.s_accept || u >= h(k + 0.5) - k.powf(-q) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_in_range() {
        let mut rng = Rng::new(0);
        for n in [1u64, 5, 100, 100_000] {
            let z = Zipf::new(n, 1.05);
            for _ in 0..2_000 {
                assert!(z.sample(&mut rng) < n);
            }
        }
    }

    #[test]
    fn head_is_heavier_than_tail() {
        let mut rng = Rng::new(1);
        let z = Zipf::new(10_000, 1.1);
        let mut head = 0u32;
        let mut tail = 0u32;
        for _ in 0..50_000 {
            let v = z.sample(&mut rng);
            if v < 10 {
                head += 1;
            }
            if v >= 5_000 {
                tail += 1;
            }
        }
        assert!(head > tail * 3, "head={head} tail={tail}");
    }

    #[test]
    fn tiny_vocab_matches_exact_distribution() {
        let mut rng = Rng::new(2);
        let n = 5u64;
        let s = 1.3;
        let z = Zipf::new(n, s);
        let mut counts = [0u64; 5];
        let draws = 200_000;
        for _ in 0..draws {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        for k in 0..n {
            let want = ((k + 1) as f64).powf(-s) / norm;
            let got = counts[k as usize] as f64 / draws as f64;
            assert!((got - want).abs() < 0.01, "k={k}: got {got}, want {want}");
        }
    }

    #[test]
    fn rank_one_frequency_roughly_zipfian_for_large_n() {
        let mut rng = Rng::new(3);
        let n = 50_000u64;
        let s = 1.05;
        let z = Zipf::new(n, s);
        let draws = 100_000;
        let mut top = 0u64;
        for _ in 0..draws {
            if z.sample(&mut rng) == 0 {
                top += 1;
            }
        }
        // expected P(0) = 1 / (Σ k^-s); for n=5e4, s=1.05, Σ ≈ 12.9 → ~7.7%
        let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let want = 1.0 / norm;
        let got = top as f64 / draws as f64;
        assert!((got - want).abs() < want * 0.25, "got {got}, want {want}");
    }
}
