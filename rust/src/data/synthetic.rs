//! Synthetic Criteo-like click logs with *planted cluster structure*.
//!
//! Substitution rationale (DESIGN.md §3): CCE's advantage over random
//! hashing comes from the fact that real categorical values have a latent
//! similarity structure — many distinct ids behave near-identically, so a
//! learned clustering of the sketch wastes less capacity than a random one.
//! The generator plants exactly that structure:
//!
//!   * every categorical value `v` of feature `f` carries a latent vector
//!     `z(f, v) = μ(f, g) + σ·ε(f, v)` where `g = cluster(f, v)` is one of
//!     `K` per-feature mixture components — ids in the same component are
//!     near-duplicates, the CCE-compressible redundancy;
//!   * value frequencies are Zipf-distributed (head/tail skew of click ids);
//!   * labels come from a DLRM-shaped ground-truth scorer: a dense linear
//!     term, per-feature projections of the latent vectors, and a sparse
//!     set of pairwise interactions `⟨z_f, z_g⟩` — plus logit noise.
//!
//! All of it is generated lazily and deterministically from (seed, sample
//! index), so a "dataset" costs no storage and any sample range can be
//! re-streamed (epochs, shuffles, validation replays) bit-identically.

use crate::data::zipf::Zipf;
use crate::util::rng::splitmix64;
use crate::util::Rng;

/// Latent embedding dimension of the ground-truth model.
const LATENT_DIM: usize = 8;

/// Configuration of a synthetic dataset (mirrors `specs.DATASETS`).
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub vocabs: Vec<usize>,
    pub n_dense: usize,
    pub train_samples: usize,
    pub val_samples: usize,
    pub test_samples: usize,
    pub latent_clusters: usize,
    pub zipf_exponent: f64,
    pub label_noise: f64,
    pub seed: u64,
}

/// The generator: holds the ground-truth model parameters.
pub struct SyntheticDataset {
    pub spec: DatasetSpec,
    zipf: Vec<Zipf>,
    /// per-feature mixture means `μ[f][g][e]`
    mu: Vec<Vec<[f32; LATENT_DIM]>>,
    /// per-feature projection `u[f][e]` (how much this feature matters)
    proj: Vec<[f32; LATENT_DIM]>,
    /// dense-feature weights
    dense_w: Vec<f32>,
    /// sparse pairwise interactions: (f, g, weight)
    pairs: Vec<(usize, usize, f32)>,
    bias: f32,
    /// within-cluster noise scale
    sigma: f32,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(spec: DatasetSpec) -> SyntheticDataset {
        let rng = Rng::new(spec.seed ^ 0xD47A_5E7_1);
        let f_n = spec.vocabs.len();
        let zipf = spec
            .vocabs
            .iter()
            .map(|&v| Zipf::new(v as u64, spec.zipf_exponent))
            .collect();
        let mut mu = Vec::with_capacity(f_n);
        for f in 0..f_n {
            let mut frng = rng.fork(f as u64 + 1000);
            // fewer effective clusters for tiny vocabularies
            let k = spec.latent_clusters.min(spec.vocabs[f]);
            let mut ms = Vec::with_capacity(k);
            for _ in 0..k {
                let mut m = [0f32; LATENT_DIM];
                frng.fill_normal(&mut m, 1.0);
                ms.push(m);
            }
            mu.push(ms);
        }
        let mut proj = Vec::with_capacity(f_n);
        for f in 0..f_n {
            let mut p = [0f32; LATENT_DIM];
            rng.fork(f as u64 + 2000).fill_normal(&mut p, 1.0 / (LATENT_DIM as f32).sqrt());
            proj.push(p);
        }
        let mut dense_w = vec![0f32; spec.n_dense];
        rng.fork(3000).fill_normal(&mut dense_w, 0.3);
        // ~1.5 interactions per feature, weights at interaction scale
        let mut prng = rng.fork(4000);
        let n_pairs = (f_n * 3 / 2).max(1);
        let mut pairs = Vec::with_capacity(n_pairs);
        for _ in 0..n_pairs {
            let f = prng.below(f_n as u64) as usize;
            let mut g = prng.below(f_n as u64) as usize;
            if g == f {
                g = (g + 1) % f_n;
            }
            let w = prng.normal_ms(0.0, 0.4) as f32;
            pairs.push((f, g, w));
        }
        // bias chosen for a ~25-30% positive rate, Criteo-like
        SyntheticDataset {
            zipf,
            mu,
            proj,
            dense_w,
            pairs,
            bias: -1.1,
            sigma: 0.25,
            seed: spec.seed,
            spec,
        }
    }

    pub fn n_features(&self) -> usize {
        self.spec.vocabs.len()
    }

    pub fn total_samples(&self) -> usize {
        self.spec.train_samples + self.spec.val_samples + self.spec.test_samples
    }

    /// Ground-truth cluster of a value (what CCE should rediscover).
    #[inline]
    pub fn true_cluster(&self, feature: usize, value: u32) -> usize {
        let mut s = self.seed ^ (feature as u64) << 32 ^ value as u64;
        (splitmix64(&mut s) % self.mu[feature].len() as u64) as usize
    }

    /// Latent vector of a categorical value (deterministic).
    pub fn latent(&self, feature: usize, value: u32) -> [f32; LATENT_DIM] {
        let g = self.true_cluster(feature, value);
        let mut z = self.mu[feature][g];
        let mut vrng = Rng::new(
            self.seed ^ 0xBEEF ^ ((feature as u64) << 40) ^ ((value as u64) << 8),
        );
        for e in z.iter_mut() {
            *e += self.sigma * vrng.normal() as f32;
        }
        z
    }

    /// Generate sample `i` into the provided slices.
    /// `dense`: len n_dense; `cats`: len F. Returns the label.
    pub fn sample_into(&self, i: usize, dense: &mut [f32], cats: &mut [u32]) -> f32 {
        let mut rng = Rng::new(self.seed ^ 0xA11CE ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // categorical draws
        for (f, c) in cats.iter_mut().enumerate() {
            *c = self.zipf[f].sample(&mut rng) as u32;
        }
        // dense draws
        for d in dense.iter_mut() {
            *d = rng.normal() as f32;
        }
        // ground-truth logit
        let mut logit = self.bias;
        for (w, x) in self.dense_w.iter().zip(dense.iter()) {
            logit += w * x;
        }
        let zs: Vec<[f32; LATENT_DIM]> = (0..self.n_features())
            .map(|f| self.latent(f, cats[f]))
            .collect();
        for f in 0..self.n_features() {
            logit += dot(&self.proj[f], &zs[f]);
        }
        for &(f, g, w) in &self.pairs {
            logit += w * dot(&zs[f], &zs[g]);
        }
        logit += (self.spec.label_noise * rng.normal()) as f32;
        // Bernoulli draw so labels carry irreducible uncertainty, like clicks
        let p = 1.0 / (1.0 + (-logit).exp());
        if rng.bernoulli(p as f64) {
            1.0
        } else {
            0.0
        }
    }

    /// Bayes-optimal BCE estimate on a sample range (the loss floor a
    /// perfect model could reach) — useful to sanity-check experiments.
    pub fn bayes_bce(&self, n: usize) -> f64 {
        let mut dense = vec![0f32; self.spec.n_dense];
        let mut cats = vec![0u32; self.n_features()];
        let mut acc = 0f64;
        for i in 0..n {
            let y = self.sample_into(i, &mut dense, &mut cats);
            // recompute p from the ground truth (same derivation, no noise term)
            // cheap approximation: re-derive logit via a second pass
            let p = self.true_prob(i);
            let p = p.clamp(1e-6, 1.0 - 1e-6);
            acc -= if y > 0.5 { p.ln() } else { (1.0 - p).ln() };
        }
        acc / n as f64
    }

    /// The ground-truth click probability of sample `i` (pre-noise).
    pub fn true_prob(&self, i: usize) -> f64 {
        let mut dense = vec![0f32; self.spec.n_dense];
        let mut cats = vec![0u32; self.n_features()];
        let mut rng = Rng::new(self.seed ^ 0xA11CE ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        for (f, c) in cats.iter_mut().enumerate() {
            *c = self.zipf[f].sample(&mut rng) as u32;
        }
        for d in dense.iter_mut() {
            *d = rng.normal() as f32;
        }
        let mut logit = self.bias;
        for (w, x) in self.dense_w.iter().zip(dense.iter()) {
            logit += w * x;
        }
        let zs: Vec<[f32; LATENT_DIM]> = (0..self.n_features())
            .map(|f| self.latent(f, cats[f]))
            .collect();
        for f in 0..self.n_features() {
            logit += dot(&self.proj[f], &zs[f]);
        }
        for &(f, g, w) in &self.pairs {
            logit += w * dot(&zs[f], &zs[g]);
        }
        1.0 / (1.0 + (-logit as f64).exp())
    }
}

#[inline]
fn dot(a: &[f32; LATENT_DIM], b: &[f32; LATENT_DIM]) -> f32 {
    let mut s = 0.0;
    for e in 0..LATENT_DIM {
        s += a[e] * b[e];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SyntheticDataset {
        SyntheticDataset::new(DatasetSpec {
            name: "t".into(),
            vocabs: vec![11, 50, 200, 1000],
            n_dense: 13,
            train_samples: 4096,
            val_samples: 512,
            test_samples: 512,
            latent_clusters: 8,
            zipf_exponent: 1.05,
            label_noise: 0.05,
            seed: 7,
        })
    }

    #[test]
    fn samples_are_deterministic() {
        let ds = tiny();
        let mut d1 = vec![0f32; 13];
        let mut c1 = vec![0u32; 4];
        let mut d2 = vec![0f32; 13];
        let mut c2 = vec![0u32; 4];
        for i in [0usize, 17, 4095] {
            let y1 = ds.sample_into(i, &mut d1, &mut c1);
            let y2 = ds.sample_into(i, &mut d2, &mut c2);
            assert_eq!((y1, &d1, &c1), (y2, &d2, &c2));
        }
    }

    #[test]
    fn values_within_vocab() {
        let ds = tiny();
        let mut d = vec![0f32; 13];
        let mut c = vec![0u32; 4];
        for i in 0..2000 {
            ds.sample_into(i, &mut d, &mut c);
            for (f, &v) in c.iter().enumerate() {
                assert!((v as usize) < ds.spec.vocabs[f], "f={f} v={v}");
            }
        }
    }

    #[test]
    fn positive_rate_in_click_range() {
        let ds = tiny();
        let mut d = vec![0f32; 13];
        let mut c = vec![0u32; 4];
        let pos: usize = (0..5000)
            .filter(|&i| ds.sample_into(i, &mut d, &mut c) > 0.5)
            .count();
        let rate = pos as f64 / 5000.0;
        assert!((0.1..0.6).contains(&rate), "positive rate {rate}");
    }

    #[test]
    fn same_cluster_values_have_close_latents() {
        let ds = tiny();
        let f = 3; // vocab 1000
        // group values by true cluster, compare within vs across distances
        let mut groups: std::collections::HashMap<usize, Vec<u32>> = Default::default();
        for v in 0..1000u32 {
            groups.entry(ds.true_cluster(f, v)).or_default().push(v);
        }
        let within = {
            let g = groups.values().find(|g| g.len() >= 2).unwrap();
            let (a, b) = (ds.latent(f, g[0]), ds.latent(f, g[1]));
            dist(&a, &b)
        };
        let mut keys = groups.keys();
        let (k1, k2) = (keys.next().unwrap(), keys.next().unwrap());
        let across = dist(&ds.latent(f, groups[k1][0]), &ds.latent(f, groups[k2][0]));
        assert!(within < across, "within {within} across {across}");
    }

    #[test]
    fn labels_are_learnable_from_latents() {
        // ground-truth prob must beat chance BCE by a clear margin
        let ds = tiny();
        let bayes = ds.bayes_bce(3000);
        // chance = entropy of base rate
        let mut d = vec![0f32; 13];
        let mut c = vec![0u32; 4];
        let pos: usize = (0..3000)
            .filter(|&i| ds.sample_into(i, &mut d, &mut c) > 0.5)
            .count();
        let p = pos as f64 / 3000.0;
        let chance = -(p * p.ln() + (1.0 - p) * (1.0 - p).ln());
        assert!(bayes < chance * 0.9, "bayes {bayes} vs chance {chance}");
    }

    fn dist(a: &[f32; LATENT_DIM], b: &[f32; LATENT_DIM]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
    }
}
