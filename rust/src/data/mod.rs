//! Synthetic Criteo-like click-log generation (the paper's datasets are
//! proprietary-scale downloads; see DESIGN.md §3 for why this substitution
//! preserves the comparison structure).

pub mod batch;
pub mod synthetic;
pub mod zipf;

pub use batch::{Batch, BatchIter, Split};
pub use synthetic::{DatasetSpec, SyntheticDataset};
pub use zipf::Zipf;
