//! `cce` — the coordinator CLI (leader entrypoint).
//!
//! Subcommands:
//!   train    train one artifact (method × budget) with optional clustering
//!   sweep    fig4-style sweep over methods × caps × seeds
//!   lsq      least-squares CCE demos (Algorithms 1 & 2, Theorem 3.1)
//!   entropy  Appendix-H entropy diagnostics (CCE vs circular clustering)
//!   serve    batched-inference serving loop over a trained artifact
//!   snapshot write / inspect on-disk serving segments (.cceseg)
//!   info     inspect artifacts / dataset presets

use anyhow::{bail, Result};
use cce::config::{ServeConfig, TrainConfig};
use cce::experiments::report::Table;
use cce::runtime::ArtifactStore;
use cce::util::{logger, Args};

fn main() {
    logger::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("lsq") => cmd_lsq(&args),
        Some("entropy") => cmd_entropy(&args),
        Some("serve") => cmd_serve(&args),
        Some("snapshot") => cmd_snapshot(&args),
        Some("info") => cmd_info(&args),
        other => {
            bail!(
                "unknown subcommand {other:?}; expected one of \
                 train | sweep | lsq | entropy | serve | snapshot | info"
            )
        }
    }
}

fn store(args: &Args) -> Result<ArtifactStore> {
    let dir = args.str_or("artifacts-dir", ArtifactStore::default_dir().to_str().unwrap());
    ArtifactStore::open(dir)
}

/// The observability sinks a run asked for (`--metrics-addr`,
/// `--stats-out`, `--trace-out`): started before the run, stopped — and
/// the trace ring dumped — after it. See docs/OBSERVABILITY.md.
struct ObsSinks {
    server: Option<cce::obs::MetricsServer>,
    emitter: Option<cce::obs::StatsEmitter>,
    trace_out: String,
}

fn start_obs(
    metrics_addr: &str,
    stats_out: &str,
    stats_interval_ms: u64,
    trace_out: &str,
) -> Result<ObsSinks> {
    // enable tracing BEFORE the run so the ring's epoch precedes every span
    if !trace_out.is_empty() {
        cce::obs::trace::enable(cce::obs::trace::DEFAULT_RING_CAP);
    }
    let server = if metrics_addr.is_empty() {
        None
    } else {
        let s = cce::obs::MetricsServer::start(metrics_addr)?;
        // port 0 binds an ephemeral port; this line is how callers learn it
        log::info!("metrics endpoint listening on http://{}/metrics", s.addr);
        Some(s)
    };
    let emitter = if stats_out.is_empty() {
        None
    } else {
        Some(cce::obs::StatsEmitter::start(
            stats_out.into(),
            std::time::Duration::from_millis(stats_interval_ms),
        )?)
    };
    Ok(ObsSinks { server, emitter, trace_out: trace_out.to_string() })
}

impl ObsSinks {
    fn finish(self) -> Result<()> {
        if let Some(e) = self.emitter {
            e.stop();
        }
        if let Some(s) = self.server {
            s.stop();
        }
        if !self.trace_out.is_empty() {
            let n = cce::obs::trace::dump(std::path::Path::new(&self.trace_out))?;
            log::info!("wrote {n} trace events to {}", self.trace_out);
        }
        Ok(())
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let store = store(args)?;
    let mut cfg = TrainConfig::default();
    if let Some(path) = args.str_opt("config") {
        cfg = TrainConfig::from_toml(&cce::config::TomlDoc::load(std::path::Path::new(path))?)?;
    }
    let cfg = cfg.apply_args(args);
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    cfg.validate()?;
    let obs = start_obs("", &cfg.stats_out, cfg.stats_interval_ms, &cfg.trace_out)?;
    let out = cce::coordinator::train(&store, &cfg)?;
    let mut t = Table::new(
        &format!("train {} (seed {})", out.artifact, out.seed),
        &["metric", "value"],
    );
    t.row(vec!["test BCE".into(), format!("{:.5}", out.test_bce)]);
    t.row(vec!["test AUC".into(), format!("{:.5}", out.test_auc)]);
    t.row(vec!["best val BCE".into(), format!("{:.5}", out.best_val_bce)]);
    t.row(vec!["epochs".into(), out.epochs_run.to_string()]);
    t.row(vec!["steps".into(), out.steps_run.to_string()]);
    t.row(vec!["samples trained".into(), out.samples_trained.to_string()]);
    t.row(vec!["clusterings".into(), out.clusterings_run.to_string()]);
    if cfg.cluster_overlap && !out.cluster_stale_steps.is_empty() {
        t.row(vec![
            "stale steps / event".into(),
            out.cluster_stale_steps
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(", "),
        ]);
    }
    t.row(vec!["embedding params".into(), out.embedding_params.to_string()]);
    t.row(vec!["compression (total)".into(), format!("{:.1}x", out.compression_total)]);
    t.row(vec!["compression (largest)".into(), format!("{:.1}x", out.compression_largest)]);
    t.row(vec!["throughput".into(), format!("{:.0} samples/s", out.throughput)]);
    t.row(vec![
        "cluster time".into(),
        format!("{:.2}s stalled / {:.2}s total", out.cluster_secs, out.cluster_event_secs),
    ]);
    t.row(vec![
        "state transfer".into(),
        format!(
            "{:.1} KiB down / {:.1} KiB up ({:.1} KiB down / {:.1} KiB up on events; \
             pool buffer {:.1} KiB)",
            out.bytes_downloaded as f64 / 1024.0,
            out.bytes_uploaded as f64 / 1024.0,
            out.event_bytes_downloaded as f64 / 1024.0,
            out.event_bytes_uploaded as f64 / 1024.0,
            out.pool_bytes as f64 / 1024.0,
        ),
    ]);
    if !out.snapshot_files.is_empty() {
        t.row(vec![
            "snapshots".into(),
            format!(
                "{} generations in {:.2}s (last: {})",
                out.snapshot_files.len(),
                out.snapshot_write_secs,
                out.snapshot_files.last().unwrap()
            ),
        ]);
    }
    t.print();
    obs.finish()?;
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let store = store(args)?;
    let dataset = args.str_or("dataset", "kaggle_small");
    let methods = args.list_or("methods", &["hash", "ce", "cce"]);
    let caps: Vec<usize> = args
        .list_or("caps", &["64", "256", "1024", "4096"])
        .iter()
        .map(|s| s.parse().expect("caps must be integers"))
        .collect();
    let seeds: Vec<u64> = args
        .list_or("seeds", &["0"])
        .iter()
        .map(|s| s.parse().expect("seeds must be integers"))
        .collect();
    let base = TrainConfig::default().apply_args(args);
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let spec = cce::experiments::SweepSpec { dataset, methods: methods.clone(), caps, seeds, base };
    let points = cce::experiments::run_sweep(&store, &spec)?;
    let mut t = Table::new("sweep results (test BCE)", &["method", "params", "mean", "min", "max"]);
    for m in &methods {
        for (params, mean, min, max) in cce::experiments::sweep::curve_for(&points, m) {
            t.row(vec![
                m.clone(),
                format!("{params:.0}"),
                format!("{mean:.5}"),
                format!("{min:.5}"),
                format!("{max:.5}"),
            ]);
        }
    }
    t.print();
    t.save_csv("sweep");
    Ok(())
}

fn cmd_lsq(args: &Args) -> Result<()> {
    use cce::cce::*;
    use cce::linalg::Matrix;
    use cce::util::Rng;
    let n = args.usize_or("n", 2000);
    let d1 = args.usize_or("d1", 300);
    let d2 = args.usize_or("d2", 10);
    let k = args.usize_or("k", 40);
    let iters = args.usize_or("iters", 20);
    let seed = args.u64_or("seed", 0);
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let mut rng = Rng::new(seed);
    let x = Matrix::randn(&mut rng, n, d1);
    let y = Matrix::randn(&mut rng, n, d2);
    let opt = optimal_loss(&x, &y);
    let bp = theory::bound_params(&x, &y);
    let dense = dense_cce(
        &x,
        &y,
        &DenseCceOptions { k, iterations: iters, noise: NoiseKind::Iid, half_update: false, seed },
    );
    let sparse = sparse_cce(
        &x,
        &y,
        &SparseCceOptions {
            k,
            sketch_width: k / 3,
            iterations: iters,
            kmeans_iters: 25,
            signs: false,
            seed,
        },
    );
    let mut t = Table::new(
        &format!("least squares CCE (n={n}, d1={d1}, d2={d2}, k={k})"),
        &["iter", "dense excess", "sparse excess", "theory bound excess"],
    );
    for i in 0..=iters {
        t.row(vec![
            i.to_string(),
            format!("{:.4e}", dense.losses[i] - opt),
            format!("{:.4e}", sparse.losses[i] - opt),
            format!("{:.4e}", bp.bound_at(i, k, d2, false) - bp.floor),
        ]);
    }
    t.print();
    println!("optimal loss: {opt:.6e}, rho = {:.3e} (1/d1 = {:.3e})", bp.rho, bp.rho_smart);
    Ok(())
}

fn cmd_entropy(args: &Args) -> Result<()> {
    use cce::baselines::circular_cluster_event;
    use cce::coordinator::cluster::{cluster_event, ClusterConfig};
    use cce::metrics::entropy::{h1, h2, max_h1};
    use cce::runtime::manifest::{FieldDesc, InitSpec};
    use cce::tables::indexer::Indexer;
    use cce::tables::layout::{SubtableId, TablePlan};
    use cce::util::Rng;
    let vocab = args.usize_or("vocab", 4096);
    let k = args.usize_or("k", 64);
    let c = args.usize_or("c", 4);
    let seed = args.u64_or("seed", 0);
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;

    let setup = || {
        let plan = TablePlan::new(&[vocab], k, 2, c, 4);
        let mut rng = Rng::new(seed);
        let ix = Indexer::new_rowwise(&mut rng, plan.clone());
        let size = plan.total_rows * plan.dc;
        let mut state = vec![0f32; size];
        Rng::new(seed ^ 1).fill_normal(&mut state, 0.5);
        let field = FieldDesc {
            name: "pool".into(),
            shape: vec![plan.total_rows, plan.dc],
            offset: 0,
            size,
            init: InitSpec::Zeros,
            group: "pool".into(),
        };
        (state, field, ix)
    };
    let cfg = ClusterConfig { kmeans_iters: 30, points_per_centroid: 256, seed, n_threads: 0 };
    let tables = |ix: &Indexer| -> Vec<Vec<u32>> {
        (0..c).map(|j| ix.materialize(SubtableId { feature: 0, term: 0, column: j })).collect()
    };

    let mut t = Table::new(
        &format!("Appendix H entropies (vocab={vocab}, k={k}, c={c}; max H1={:.2})", max_h1(k)),
        &["method", "H1", "H2", "collapse?"],
    );
    let (_, _, ix) = setup();
    let tb = tables(&ix);
    t.row(vec![
        "random hash (CE)".into(),
        format!("{:.3}", h1(&tb)),
        format!("{:.3}", h2(&tb)),
        "no".into(),
    ]);
    let (mut s, f, mut ix) = setup();
    cluster_event(&mut s, &f, &mut ix, &cfg);
    let tb = tables(&ix);
    t.row(vec![
        "CCE clustering".into(),
        format!("{:.3}", h1(&tb)),
        format!("{:.3}", h2(&tb)),
        "no".into(),
    ]);
    let (mut s, f, mut ix) = setup();
    circular_cluster_event(&mut s, &f, &mut ix, &cfg);
    let tb = tables(&ix);
    let (h1c, h2c) = (h1(&tb), h2(&tb));
    t.row(vec![
        "circular clustering".into(),
        format!("{h1c:.3}"),
        format!("{h2c:.3}"),
        if h2c - h1c < 0.1 { "YES (pairwise)".into() } else { "no".into() },
    ]);
    t.print();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let store = store(args)?;
    let mut cfg = ServeConfig::default();
    if let Some(path) = args.str_opt("config") {
        cfg = ServeConfig::from_toml(&cce::config::TomlDoc::load(std::path::Path::new(path))?)?;
    }
    let cfg = cfg.apply_args(args);
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    cfg.validate()?;
    let obs = start_obs(&cfg.metrics_addr, &cfg.stats_out, cfg.stats_interval_ms, &cfg.trace_out)?;
    let mut session = cce::runtime::DlrmSession::open(&store, &cfg.artifact)?;
    let m = session.manifest.clone();
    let ds = cce::data::SyntheticDataset::new(store.dataset(&m.dataset, cfg.seed)?);
    // --train-steps N: train first and serve the best-validation
    // checkpoint (state + contemporaneous index maps); 0 keeps the old
    // random-initialized serving path for pure serving benchmarks
    let mut watch_rep = None;
    let (rep, served) = if cfg.train_steps > 0 {
        let tcfg = TrainConfig {
            artifact: cfg.artifact.clone(),
            seed: cfg.seed,
            max_batches: cfg.train_steps,
            ..Default::default()
        };
        let out = cce::coordinator::train(&store, &tcfg)?;
        let ckpt = out.best_checkpoint.expect("train always returns a checkpoint");
        log::info!(
            "serving trained checkpoint: {} steps, best val BCE {:.5}",
            out.steps_run,
            out.best_val_bce
        );
        let rep = cce::coordinator::serve::serve_trained(&mut session, &ckpt, &ds, &cfg)?;
        (rep, format!("trained ({} steps)", out.steps_run))
    } else if !cfg.snapshot_path.is_empty() {
        // boot from an on-disk segment: zero-copy mmap load, no bake. The
        // segment carries index maps only, so the device state is still
        // random-initialized (see ROADMAP "unified checkpoint").
        let mut rng = cce::util::Rng::new(cfg.seed ^ 0x57A7E);
        let state = cce::tables::init::init_state(&m.layout, m.state_size, &mut rng);
        session.set_state(&state)?;
        let path = std::path::Path::new(&cfg.snapshot_path);
        let rep = cce::coordinator::serve::serve_snapshot(&session, path, &ds, &cfg)?;
        (rep, format!("segment {}", cfg.snapshot_path))
    } else if !cfg.snapshot_dir.is_empty() {
        // boot from the newest verified segment and follow the directory:
        // a concurrent `cce train --snapshot-dir` run's new generations are
        // hot-swapped in by the watcher (corrupt files skipped, not fatal)
        let mut rng = cce::util::Rng::new(cfg.seed ^ 0x57A7E);
        let state = cce::tables::init::init_state(&m.layout, m.state_size, &mut rng);
        session.set_state(&state)?;
        let dir = std::path::Path::new(&cfg.snapshot_dir);
        let (rep, wrep) = cce::coordinator::serve::serve_watch(&session, dir, &ds, &cfg)?;
        watch_rep = Some(wrep);
        (rep, format!("watched dir {}", cfg.snapshot_dir))
    } else {
        log::warn!("serving a random-initialized model; pass --train-steps N to train first");
        let indexer = cce::coordinator::trainer::build_indexer(&m, cfg.seed)?;
        let mut rng = cce::util::Rng::new(cfg.seed ^ 0x57A7E);
        let state = cce::tables::init::init_state(&m.layout, m.state_size, &mut rng);
        session.set_state(&state)?;
        let rep = cce::coordinator::serve::serve(&session, &indexer, &ds, &cfg)?;
        (rep, "random init".to_string())
    };
    let mut t = Table::new(
        &format!("serving {} (zipf skew {}, {} workers)", cfg.artifact, cfg.zipf_skew, cfg.workers),
        &["metric", "value"],
    );
    t.row(vec!["model".into(), served]);
    t.row(vec!["admission".into(), cfg.admission.clone()]);
    t.row(vec!["offered".into(), rep.offered.to_string()]);
    t.row(vec!["served".into(), rep.requests.to_string()]);
    if rep.rejected + rep.expired > 0 || cfg.admission == "shed" {
        t.row(vec![
            "shed".into(),
            format!(
                "{} rejected + {} expired ({:.2}% of offered)",
                rep.rejected,
                rep.expired,
                rep.shed_rate * 100.0
            ),
        ]);
        t.row(vec![
            "deadline misses".into(),
            format!("{} ({:.2}% of served)", rep.deadline_misses, rep.deadline_miss_rate * 100.0),
        ]);
        t.row(vec!["goodput".into(), format!("{:.0} req/s", rep.goodput_rps)]);
    }
    t.row(vec!["batches".into(), rep.batches.to_string()]);
    t.row(vec!["padded rows".into(), rep.padded_rows.to_string()]);
    t.row(vec!["throughput".into(), format!("{:.0} req/s", rep.throughput_rps)]);
    t.row(vec!["latency e2e".into(), rep.latency.display()]);
    t.row(vec!["queue wait".into(), rep.queue_wait.display()]);
    t.row(vec!["index time".into(), format!("{:.3}s (summed over workers)", rep.index_secs)]);
    t.row(vec!["exec time".into(), format!("{:.3}s", rep.exec_secs)]);
    if rep.load_secs > 0.0 {
        t.row(vec![
            "snapshot".into(),
            format!("{} KiB loaded in {:.3} ms", rep.snapshot_bytes / 1024, rep.load_secs * 1e3),
        ]);
    } else {
        t.row(vec![
            "snapshot".into(),
            format!(
                "{} KiB baked in {:.3}s ({:.1} KiB device transfer at bake)",
                rep.snapshot_bytes / 1024,
                rep.bake_secs,
                rep.bake_transfer_bytes as f64 / 1024.0
            ),
        ]);
    }
    if rep.snapshot_swaps > 0 {
        t.row(vec![
            "hot swaps".into(),
            format!("{} (final generation {})", rep.snapshot_swaps, rep.generation),
        ]);
    }
    if let Some(w) = watch_rep {
        t.row(vec![
            "watcher".into(),
            format!(
                "{} polls, {} installs (generation {}), {} retries, \
                 {} corrupt + {} incompatible skipped",
                w.polls, w.installs, w.generation, w.retries, w.skipped_corrupt,
                w.skipped_incompatible
            ),
        ]);
    }
    t.print();
    obs.finish()?;
    Ok(())
}

fn cmd_snapshot(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("write") => cmd_snapshot_write(args),
        Some("inspect") => cmd_snapshot_inspect(args),
        other => bail!("unknown snapshot verb {other:?}; expected write | inspect"),
    }
}

/// `cce snapshot write [--artifact A] [--seed S] [--train-steps N] [--out P]`
/// — bake an artifact's index maps (optionally training first) and persist
/// them as a generation-0 segment file.
fn cmd_snapshot_write(args: &Args) -> Result<()> {
    let store = store(args)?;
    let artifact = args.str_or("artifact", "quick_cce");
    let seed = args.u64_or("seed", 0);
    let train_steps = args.usize_or("train-steps", 0);
    let out_path = args.str_or("out", &format!("{artifact}.cceseg"));
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let snap = if train_steps > 0 {
        let tcfg = TrainConfig {
            artifact: artifact.clone(),
            seed,
            max_batches: train_steps,
            ..Default::default()
        };
        let out = cce::coordinator::train(&store, &tcfg)?;
        let ckpt = out.best_checkpoint.expect("train always returns a checkpoint");
        log::info!(
            "baking trained index maps ({} steps; {:.1} KiB state down / {:.1} KiB up \
             during training, 0 at bake — the bake reads host-side maps)",
            out.steps_run,
            out.bytes_downloaded as f64 / 1024.0,
            out.bytes_uploaded as f64 / 1024.0
        );
        cce::serving::ServingSnapshot::bake(&ckpt.indexer)
    } else {
        let m = store.manifest(&artifact)?;
        let indexer = cce::coordinator::trainer::build_indexer(&m, seed)?;
        cce::serving::ServingSnapshot::bake(&indexer)
    };
    let path = std::path::Path::new(&out_path);
    let bytes = cce::serving::write_segment(&snap, 0, path)?;
    println!("wrote {} ({:.1} MB, generation 0)", path.display(), bytes as f64 / 1e6);
    Ok(())
}

/// `cce snapshot inspect <path> [--verify]` — print a segment's header and
/// section table; `--verify` additionally checks every section checksum.
fn cmd_snapshot_inspect(args: &Args) -> Result<()> {
    let path = match args.str_opt("path") {
        Some(p) => p.to_string(),
        None => args
            .positional
            .get(1)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("usage: cce snapshot inspect <path> [--verify]"))?,
    };
    let verify = args.flag("verify");
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let info = cce::serving::segment::inspect(std::path::Path::new(&path), verify)?;
    let h = &info.header;
    let mut t = Table::new(&format!("segment {path}"), &["field", "value"]);
    t.row(vec!["kind".into(), format!("{:?}", h.kind)]);
    t.row(vec!["generation".into(), h.generation.to_string()]);
    t.row(vec!["features".into(), h.n_features.to_string()]);
    t.row(vec!["stride".into(), h.stride.to_string()]);
    t.row(vec!["c / dc / dim".into(), format!("{} / {} / {}", h.c, h.dc, h.dim)]);
    t.row(vec!["n_hash".into(), h.n_hash.to_string()]);
    t.row(vec!["dhe live fallback".into(), h.dhe_live.to_string()]);
    t.row(vec!["file bytes".into(), info.file_bytes.to_string()]);
    t.print();
    let mut s = Table::new("sections", &["name", "offset", "bytes", "checksum"]);
    for sec in &info.sections {
        s.row(vec![
            sec.name.into(),
            sec.offset.to_string(),
            sec.bytes.to_string(),
            match sec.checksum_ok {
                None => "(not checked)".into(),
                Some(true) => "OK".into(),
                Some(false) => "MISMATCH".into(),
            },
        ]);
    }
    s.print();
    if info.sections.iter().any(|sec| sec.checksum_ok == Some(false)) {
        bail!("checksum verification failed for {path}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let store = store(args)?;
    args.reject_unknown().map_err(|e| anyhow::anyhow!(e))?;
    let mut t = Table::new(
        "artifacts",
        &["name", "method", "dataset", "B", "state", "emb params", "impl"],
    );
    for name in store.artifact_names() {
        if !store.has(&name) {
            continue;
        }
        let m = store.manifest(&name)?;
        t.row(vec![
            m.name.clone(),
            m.method.clone(),
            m.dataset.clone(),
            m.spec.batch.to_string(),
            m.state_size.to_string(),
            m.spec.embedding_params.to_string(),
            m.spec.impl_name.clone(),
        ]);
    }
    t.print();
    Ok(())
}
