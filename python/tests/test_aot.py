"""AOT pipeline tests: HLO text emission, manifest consistency, op stats."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, specs


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    spec = specs.ArtifactSpec(
        "aot_t", "smoke", "cce", cap=16, batch=32, eval_batch=64,
        dim=8, bot_mlp=(16,), top_mlp=(16,),
    )
    manifest = aot.lower_artifact(spec, out, dump_stats=False)
    return out, spec, manifest


def test_hlo_files_exist_and_are_text(built):
    out, spec, manifest = built
    for kind, fname in manifest["executables"].items():
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        head = open(path).read(200)
        assert "HloModule" in head, f"{kind}: not HLO text"


def test_manifest_layout_covers_state(built):
    _, _, manifest = built
    total = sum(f["size"] for f in manifest["layout"])
    assert total == manifest["state_size"]
    # offsets contiguous and ordered
    off = 0
    for f in manifest["layout"]:
        assert f["offset"] == off
        off += f["size"]


def test_manifest_metrics_location(built):
    _, _, manifest = built
    m = manifest["metrics"]
    last = manifest["layout"][-1]
    assert last["name"] == "metrics"
    assert m["offset"] == last["offset"]
    assert m["names"] == ["loss_sum", "examples", "steps", "last_loss"]


def test_manifest_input_shapes(built):
    _, spec, manifest = built
    tr = {i["name"]: i for i in manifest["inputs"]["train"]}
    bufs = {b["name"]: b for b in manifest["buffers"]}
    for g in ("pool", "dense", "metrics"):
        assert tr[f"state.{g}"]["shape"] == [bufs[g]["size"]]
    assert tr["dense"]["shape"] == [spec.batch, spec.n_dense]
    assert tr["emb"]["shape"] == [spec.batch, spec.n_features, spec.t, spec.c]
    assert tr["emb"]["dtype"] == "i32"
    # train's tuple root: one result per state buffer, in buffer order
    shapes = manifest["outputs"]["train"]["tuple_shapes"]
    assert shapes == [[b["size"]] for b in manifest["buffers"]]
    assert sum(s[0] for s in shapes) == manifest["state_size"]


def test_manifest_buffers_tile_state(built):
    _, _, manifest = built
    assert manifest["schema_version"] == 2
    bufs = manifest["buffers"]
    assert [b["name"] for b in bufs] == ["pool", "dense", "metrics"]
    off = 0
    for b in bufs:
        assert b["offset"] == off
        off += b["size"]
    assert off == manifest["state_size"]
    # every layout field carries a group tag and fits inside that buffer
    by_name = {b["name"]: b for b in bufs}
    for f in manifest["layout"]:
        b = by_name[f["group"]]
        assert b["offset"] <= f["offset"]
        assert f["offset"] + f["size"] <= b["offset"] + b["size"], f["name"]


def test_hlo_stats_finds_ops():
    spec = specs.ArtifactSpec(
        "aot_s", "smoke", "hash", cap=8, batch=32, eval_batch=32,
        dim=8, bot_mlp=(8,), top_mlp=(8,), impl="reference",
    )
    lo = model.build_layout(spec)
    gs = {g: jax.ShapeDtypeStruct((size,), jnp.float32) for g, _, size in lo.buffers()}
    d = jax.ShapeDtypeStruct((32, 13), jnp.float32)
    e = jax.ShapeDtypeStruct((32, 4, 1, 1), jnp.int32)
    l = jax.ShapeDtypeStruct((32,), jnp.float32)
    lowered = jax.jit(model.make_train_step(spec, lo)).lower(
        gs["pool"], gs["dense"], gs["metrics"], d, e, l
    )
    text = aot.to_hlo_text(lowered, return_tuple=True)
    stats = aot.hlo_stats(text)
    assert "dot" in stats and stats["dot"] >= 4  # fwd+bwd MLP matmuls
    assert any(k.startswith("scatter") for k in stats), stats  # embedding grad


def test_train_root_is_tuple_of_buffers(built):
    """Per-buffer convention: train's entry root is a tuple with one f32
    array per state buffer; predict keeps a plain array root."""
    out, _, manifest = built
    text = open(os.path.join(out, manifest["executables"]["train"])).read()
    entry_root = [ln for ln in text.splitlines() if "ROOT" in ln][-1]
    rhs = entry_root.split("=")[1].strip()
    assert rhs.startswith("(f32["), entry_root
    shape = rhs[: rhs.index(")")]
    assert shape.count("f32[") == len(manifest["buffers"]), entry_root
    ptext = open(os.path.join(out, manifest["executables"]["predict"])).read()
    proot = [ln for ln in ptext.splitlines() if "ROOT" in ln][-1]
    pshape = proot.split("=")[1].strip().split(" ")[0]
    assert pshape.startswith("f32["), proot


def test_index_json_merging(tmp_path):
    # two aot runs must merge their artifact lists
    idx = {"artifacts": ["a"], "kmeans": [], "datasets": {}}
    p = tmp_path / "index.json"
    p.write_text(json.dumps(idx))
    loaded = json.loads(p.read_text())
    merged = sorted(set(loaded["artifacts"]) | {"b"})
    assert merged == ["a", "b"]


def test_dataset_presets_complete():
    for name, ds in specs.DATASETS.items():
        assert len(ds["vocabs"]) >= 4, name
        assert ds["train_samples"] > 0
        assert all(v > 0 for v in ds["vocabs"])


def test_sweep_specs_cover_methods_and_caps():
    names = {s.name for s in specs.sweep_specs()}
    for m in specs.SWEEP_METHODS:
        for cap in specs.SWEEP_CAPS:
            assert f"sweep_kaggle_small_{m}_{cap}" in names
    assert "sweep_kaggle_small_full_0" in names
