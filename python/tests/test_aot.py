"""AOT pipeline tests: HLO text emission, manifest consistency, op stats."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model, specs


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    spec = specs.ArtifactSpec(
        "aot_t", "smoke", "cce", cap=16, batch=32, eval_batch=64,
        dim=8, bot_mlp=(16,), top_mlp=(16,),
    )
    manifest = aot.lower_artifact(spec, out, dump_stats=False)
    return out, spec, manifest


def test_hlo_files_exist_and_are_text(built):
    out, spec, manifest = built
    for kind, fname in manifest["executables"].items():
        path = os.path.join(out, fname)
        assert os.path.exists(path), fname
        head = open(path).read(200)
        assert "HloModule" in head, f"{kind}: not HLO text"


def test_manifest_layout_covers_state(built):
    _, _, manifest = built
    total = sum(f["size"] for f in manifest["layout"])
    assert total == manifest["state_size"]
    # offsets contiguous and ordered
    off = 0
    for f in manifest["layout"]:
        assert f["offset"] == off
        off += f["size"]


def test_manifest_metrics_location(built):
    _, _, manifest = built
    m = manifest["metrics"]
    last = manifest["layout"][-1]
    assert last["name"] == "metrics"
    assert m["offset"] == last["offset"]
    assert m["names"] == ["loss_sum", "examples", "steps", "last_loss"]


def test_manifest_input_shapes(built):
    _, spec, manifest = built
    tr = {i["name"]: i for i in manifest["inputs"]["train"]}
    assert tr["state"]["shape"] == [manifest["state_size"]]
    assert tr["dense"]["shape"] == [spec.batch, spec.n_dense]
    assert tr["emb"]["shape"] == [spec.batch, spec.n_features, spec.t, spec.c]
    assert tr["emb"]["dtype"] == "i32"
    assert manifest["outputs"]["train"]["shape"] == [manifest["state_size"]]


def test_hlo_stats_finds_ops():
    spec = specs.ArtifactSpec(
        "aot_s", "smoke", "hash", cap=8, batch=32, eval_batch=32,
        dim=8, bot_mlp=(8,), top_mlp=(8,), impl="reference",
    )
    lo = model.build_layout(spec)
    s = jax.ShapeDtypeStruct((lo.size,), jnp.float32)
    d = jax.ShapeDtypeStruct((32, 13), jnp.float32)
    e = jax.ShapeDtypeStruct((32, 4, 1, 1), jnp.int32)
    l = jax.ShapeDtypeStruct((32,), jnp.float32)
    text = aot.to_hlo_text(jax.jit(model.make_train_step(spec, lo)).lower(s, d, e, l))
    stats = aot.hlo_stats(text)
    assert "dot" in stats and stats["dot"] >= 4  # fwd+bwd MLP matmuls
    assert any(k.startswith("scatter") for k in stats), stats  # embedding grad


def test_single_array_root(built):
    """The packed-state convention requires a non-tuple root (DESIGN.md §7)."""
    out, _, manifest = built
    text = open(os.path.join(out, manifest["executables"]["train"])).read()
    root_lines = [ln for ln in text.splitlines() if "ROOT" in ln]
    entry_root = root_lines[-1]
    assert "f32[" in entry_root and "(f32" not in entry_root.split("=")[1].split(" ")[1], entry_root


def test_index_json_merging(tmp_path):
    # two aot runs must merge their artifact lists
    idx = {"artifacts": ["a"], "kmeans": [], "datasets": {}}
    p = tmp_path / "index.json"
    p.write_text(json.dumps(idx))
    loaded = json.loads(p.read_text())
    merged = sorted(set(loaded["artifacts"]) | {"b"})
    assert merged == ["a", "b"]


def test_dataset_presets_complete():
    for name, ds in specs.DATASETS.items():
        assert len(ds["vocabs"]) >= 4, name
        assert ds["train_samples"] > 0
        assert all(v > 0 for v in ds["vocabs"])


def test_sweep_specs_cover_methods_and_caps():
    names = {s.name for s in specs.sweep_specs()}
    for m in specs.SWEEP_METHODS:
        for cap in specs.SWEEP_CAPS:
            assert f"sweep_kaggle_small_{m}_{cap}" in names
    assert "sweep_kaggle_small_full_0" in names
