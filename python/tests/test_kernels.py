"""Kernel-vs-reference correctness: hypothesis sweeps shapes/dtypes.

This is the CORE L1 correctness signal: every Pallas kernel must agree with
its pure-jnp oracle in ``ref.py`` on arbitrary valid shapes.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gather_sum import (
    gather_elements,
    gather_elements_ad,
    gather_sum,
    gather_sum_ad,
)
from compile.kernels.interaction import interaction, interaction_ad
from compile.kernels.kmeans import kmeans_assign, kmeans_step

import jax

SETTINGS = dict(max_examples=25, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# gather_sum
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([8, 32, 64]),
    f=st.integers(1, 6),
    t=st.integers(1, 3),
    c=st.sampled_from([1, 2, 4]),
    dc=st.sampled_from([1, 2, 4, 8]),
    r=st.integers(5, 300),
    seed=st.integers(0, 2**31),
)
def test_gather_sum_matches_ref(b, f, t, c, dc, r, seed):
    rng = _rng(seed)
    pool = jnp.asarray(rng.normal(size=(r, dc)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, r, size=(b, f, t, c)).astype(np.int32))
    got = gather_sum(pool, idx)
    want = ref.gather_sum_ref(pool, idx)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_gather_sum_tile_divisibility():
    pool = jnp.zeros((4, 2))
    idx = jnp.zeros((10, 1, 1, 1), dtype=jnp.int32)
    with pytest.raises(ValueError, match="divisible"):
        gather_sum(pool, idx, tile_b=4)


def test_gather_sum_grad_is_scatter_add():
    rng = _rng(0)
    pool = jnp.asarray(rng.normal(size=(20, 4)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 20, size=(8, 3, 2, 2)).astype(np.int32))

    def f_ad(p):
        return jnp.sum(gather_sum_ad(p, idx) ** 2)

    def f_ref(p):
        return jnp.sum(ref.gather_sum_ref(p, idx) ** 2)

    g_ad = jax.grad(f_ad)(pool)
    g_ref = jax.grad(f_ref)(pool)
    np.testing.assert_allclose(g_ad, g_ref, rtol=1e-5)


def test_gather_sum_duplicate_indices_accumulate():
    # same row referenced by both terms → embedding is 2x the row
    pool = jnp.asarray(np.arange(12, dtype=np.float32).reshape(6, 2))
    idx = jnp.full((8, 1, 2, 1), 3, dtype=jnp.int32)
    out = gather_sum(pool, idx)
    np.testing.assert_allclose(out[0, 0], 2 * pool[3])


# ---------------------------------------------------------------------------
# gather_elements (ROBE)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([8, 32]),
    f=st.integers(1, 5),
    d=st.integers(1, 16),
    r=st.integers(4, 500),
    seed=st.integers(0, 2**31),
)
def test_gather_elements_matches_ref(b, f, d, r, seed):
    rng = _rng(seed)
    pool = jnp.asarray(rng.normal(size=(r,)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, r, size=(b, f, d)).astype(np.int32))
    np.testing.assert_allclose(
        gather_elements(pool, idx), ref.gather_elements_ref(pool, idx), rtol=1e-6
    )


def test_gather_elements_grad():
    rng = _rng(1)
    pool = jnp.asarray(rng.normal(size=(50,)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 50, size=(8, 2, 4)).astype(np.int32))
    g_ad = jax.grad(lambda p: jnp.sum(gather_elements_ad(p, idx) ** 2))(pool)
    g_ref = jax.grad(lambda p: jnp.sum(ref.gather_elements_ref(p, idx) ** 2))(pool)
    np.testing.assert_allclose(g_ad, g_ref, rtol=1e-5)


# ---------------------------------------------------------------------------
# interaction
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.sampled_from([8, 16, 32]),
    n=st.integers(2, 28),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31),
)
def test_interaction_matches_ref(b, n, d, seed):
    rng = _rng(seed)
    z = jnp.asarray(rng.normal(size=(b, n, d)).astype(np.float32))
    np.testing.assert_allclose(interaction(z), ref.interaction_ref(z), rtol=1e-4, atol=1e-5)


def test_interaction_output_count():
    z = jnp.zeros((8, 27, 16))
    assert interaction(z).shape == (8, 27 * 26 // 2)


def test_interaction_grad_matches_ref():
    rng = _rng(2)
    z = jnp.asarray(rng.normal(size=(8, 5, 4)).astype(np.float32))
    g_ad = jax.grad(lambda x: jnp.sum(jnp.sin(interaction_ad(x))))(z)
    g_ref = jax.grad(lambda x: jnp.sum(jnp.sin(ref.interaction_ref(x))))(z)
    np.testing.assert_allclose(g_ad, g_ref, rtol=1e-4, atol=1e-5)


def test_interaction_symmetric_pairs():
    # dot(z_i, z_j) must appear exactly once, for i > j
    z = jnp.asarray(np.eye(3, 4, dtype=np.float32))[None].repeat(8, axis=0)
    out = interaction(z)
    # e_i · e_j = 0 for i ≠ j
    np.testing.assert_allclose(out, np.zeros((8, 3)))


# ---------------------------------------------------------------------------
# kmeans
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([256, 512]),
    d=st.integers(1, 16),
    k=st.integers(1, 32),
    seed=st.integers(0, 2**31),
)
def test_kmeans_assign_matches_ref(n, d, k, seed):
    rng = _rng(seed)
    pts = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
    got = kmeans_assign(pts, cen)
    want = ref.kmeans_assign_ref(pts, cen)
    # ties can differ only when two centroids are at equal distance, which
    # has measure zero under gaussian draws
    np.testing.assert_array_equal(got, want)


def test_kmeans_step_matches_ref():
    rng = _rng(3)
    pts = jnp.asarray(rng.normal(size=(512, 8)).astype(np.float32))
    cen = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    packed = kmeans_step(pts, cen)
    new_c, counts = ref.kmeans_update_ref(pts, cen)
    np.testing.assert_allclose(packed[:, :8], new_c, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(packed[:, 8], counts)


def test_kmeans_empty_cluster_keeps_centroid():
    pts = jnp.asarray(np.full((256, 2), 5.0, dtype=np.float32))
    cen = jnp.asarray(np.array([[5.0, 5.0], [-100.0, -100.0]], dtype=np.float32))
    packed = kmeans_step(pts, cen)
    np.testing.assert_allclose(packed[1, :2], cen[1])  # empty keeps old
    np.testing.assert_allclose(packed[0, :2], [5.0, 5.0])
    assert packed[0, 2] == 256 and packed[1, 2] == 0
