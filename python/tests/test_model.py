"""Model-level tests: layout round-trips, forward semantics, impl parity,
and train-step behaviour (loss decreases) — all in pure JAX before AOT."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, specs
from compile.layout import BUFFER_GROUPS, METRIC_NAMES, Layout, mlp_fields


def tiny_spec(method="cce", impl="pallas", **kw):
    defaults = dict(
        name="t", dataset="smoke", method=method, cap=16, batch=32, eval_batch=64,
        dim=8, bot_mlp=(16,), top_mlp=(16,), impl=impl,
    )
    defaults.update(kw)
    return specs.ArtifactSpec(**defaults)


def init_state(layout: Layout, seed=0) -> jnp.ndarray:
    """Python mirror of the Rust initializer (rust/src/tables/init.rs)."""
    rng = np.random.default_rng(seed)
    out = np.zeros(layout.size, dtype=np.float32)
    for f in layout.fields:
        if f.init[0] == "normal":
            out[f.offset : f.offset + f.size] = rng.normal(0, f.init[1], f.size)
        elif f.init[0] == "uniform":
            out[f.offset : f.offset + f.size] = rng.uniform(-f.init[1], f.init[1], f.size)
    return jnp.asarray(out)


def split_state(lo: Layout, state):
    """Flat host state → per-group device buffers (the runtime's split)."""
    return {g: state[off : off + size] for g, off, size in lo.buffers()}


def run_step(step, lo: Layout, state, dense, emb, labels):
    """Drive a per-group train step from a flat state; return flat state'."""
    gs = split_state(lo, state)
    pool, dense_p, metrics = step(gs["pool"], gs["dense"], gs["metrics"], dense, emb, labels)
    return jnp.concatenate([pool, dense_p, metrics])


def random_inputs(spec, batch, seed=0):
    rng = np.random.default_rng(seed)
    dense = jnp.asarray(rng.normal(size=(batch, spec.n_dense)).astype(np.float32))
    shape, dtype = model.emb_input_shape(spec, batch)
    if dtype == "int32":
        hi = max(spec.pool_rows, 1)
        emb = jnp.asarray(rng.integers(0, hi, size=shape).astype(np.int32))
    else:
        emb = jnp.asarray(rng.uniform(-1, 1, size=shape).astype(np.float32))
    labels = jnp.asarray((rng.uniform(size=batch) < 0.3).astype(np.float32))
    return dense, emb, labels


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


def test_layout_offsets_contiguous():
    lo = Layout()
    lo.add("a", (3, 4), ("zeros",), "pool")
    lo.add("b", (5,), ("normal", 0.1), "dense")
    assert lo["a"].offset == 0 and lo["b"].offset == 12 and lo.size == 17


def test_layout_groups_must_stay_contiguous():
    lo = Layout()
    lo.add("a", (2,), ("zeros",), "dense")
    with pytest.raises(ValueError, match="contiguous"):
        lo.add("b", (2,), ("zeros",), "pool")
    with pytest.raises(ValueError, match="unknown group"):
        lo.add("c", (2,), ("zeros",), "emb")


def test_layout_buffers_tile_state():
    for method in ["hash", "cce", "robe", "dhe"]:
        lo = model.build_layout(tiny_spec(method=method))
        bufs = lo.buffers()
        assert [g for g, _, _ in bufs] == list(BUFFER_GROUPS)
        off = 0
        for _, b_off, b_size in bufs:
            assert b_off == off
            off += b_size
        assert off == lo.size
        for f in lo.fields:
            g_off, g_size = dict((g, (o, s)) for g, o, s in bufs)[f.group]
            assert g_off <= f.offset and f.offset + f.size <= g_off + g_size


def test_group_pack_unpack_matches_flat():
    spec = tiny_spec()
    lo = model.build_layout(spec)
    state = init_state(lo, seed=2)
    flat = lo.unpack(state)
    grouped = lo.unpack_groups(**split_state(lo, state))
    assert set(flat) == set(grouped)
    for k in flat:
        np.testing.assert_array_equal(flat[k], grouped[k])
    back = jnp.concatenate([lo.pack_group(g, grouped) for g in BUFFER_GROUPS])
    np.testing.assert_array_equal(state, back)


def test_layout_pack_unpack_roundtrip():
    spec = tiny_spec()
    lo = model.build_layout(spec)
    state = init_state(lo, seed=1)
    tensors = lo.unpack(state)
    back = lo.pack(tensors)
    np.testing.assert_array_equal(state, back)


def test_layout_rejects_duplicates():
    lo = Layout()
    lo.add("a", (2,), ("zeros",), "pool")
    with pytest.raises(ValueError, match="duplicate"):
        lo.add("a", (2,), ("zeros",), "pool")


def test_layout_pack_shape_mismatch():
    lo = Layout()
    lo.add("a", (2, 2), ("zeros",), "pool")
    with pytest.raises(ValueError, match="expected"):
        lo.pack_group("pool", {"a": jnp.zeros((4,))})


def test_metrics_is_last_field():
    for method in ["hash", "cce", "robe", "dhe"]:
        lo = model.build_layout(tiny_spec(method=method))
        assert lo.fields[-1].name == "metrics"
        assert lo.fields[-1].offset + lo.fields[-1].size == lo.size
        assert lo.fields[-1].shape == (len(METRIC_NAMES),)


def test_mlp_fields_sizes():
    lo = Layout()
    mlp_fields(lo, "m", [13, 64, 32, 16])
    assert lo["m_w0"].shape == (13, 64)
    assert lo["m_w2"].shape == (32, 16)
    assert lo["m_b2"].shape == (16,)


# ---------------------------------------------------------------------------
# spec arithmetic (must mirror tables/layout.rs)
# ---------------------------------------------------------------------------


def test_rows_for_caps():
    assert specs.rows_for([10, 100], cap=50, t=2, c=4) == 2 * 4 * (10 + 50)
    assert specs.rows_for([10, 100], cap=specs.NO_CAP, t=1, c=1) == 110


def test_dhe_hidden_budget():
    for cap in [64, 1024, 16384]:
        for dim in [8, 16]:
            h = specs.dhe_hidden_for(cap, dim)
            params = 2 * h * h + (2 + dim) * h + dim
            budget = cap * dim
            assert params <= budget * 1.15  # within 15% of the budget
            assert params >= budget * 0.5 or h == 4


def test_embedding_params_accounting():
    s = tiny_spec(method="cce")
    assert s.embedding_params() == s.pool_rows * s.dc
    s = tiny_spec(method="robe")
    assert s.embedding_params() == s.pool_rows
    s = tiny_spec(method="dhe")
    h, d = s.dhe_hidden, s.dim
    assert s.embedding_params() == s.n_features * (2 * h * h + 2 * h + h * d + d)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["hash", "hashemb", "ce", "cce", "robe", "dhe"])
def test_forward_shape_and_finite(method):
    spec = tiny_spec(method=method)
    lo = model.build_layout(spec)
    state = init_state(lo)
    dense, emb, _ = random_inputs(spec, spec.batch)
    params = lo.unpack(state)
    params.pop("metrics")
    logits = model.forward_logits(spec, params, dense, emb)
    assert logits.shape == (spec.batch,)
    assert np.all(np.isfinite(logits))


@pytest.mark.parametrize("method", ["cce", "robe"])
def test_pallas_and_reference_impl_agree(method):
    sp, sr = tiny_spec(method=method), tiny_spec(method=method, impl="reference")
    lo = model.build_layout(sp)
    state = init_state(lo, seed=7)
    dense, emb, _ = random_inputs(sp, sp.batch, seed=7)
    params = lo.unpack(state)
    params.pop("metrics")
    lp = model.forward_logits(sp, params, dense, emb)
    lr_ = model.forward_logits(sr, params, dense, emb)
    np.testing.assert_allclose(lp, lr_, rtol=1e-4, atol=1e-5)


def test_bce_matches_closed_form():
    logits = jnp.asarray([0.0, 2.0, -2.0])
    labels = jnp.asarray([1.0, 1.0, 0.0])
    want = np.mean(
        [-np.log(0.5), -np.log(1 / (1 + np.exp(-2.0))), -np.log(1 - 1 / (1 + np.exp(2.0)))]
    )
    np.testing.assert_allclose(model.bce_from_logits(logits, labels), want, rtol=1e-6)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["hash", "cce", "dhe"])
def test_train_step_decreases_loss(method):
    spec = tiny_spec(method=method, impl="reference")
    lo = model.build_layout(spec)
    step = jax.jit(model.make_train_step(spec, lo))
    state = init_state(lo, seed=3)
    dense, emb, labels = random_inputs(spec, spec.batch, seed=3)
    losses = []
    for _ in range(30):
        state = run_step(step, lo, state, dense, emb, labels)
        losses.append(float(state[lo["metrics"].offset + 3]))
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]


def test_train_step_metrics_accumulate():
    spec = tiny_spec(impl="reference")
    lo = model.build_layout(spec)
    step = jax.jit(model.make_train_step(spec, lo))
    state = init_state(lo)
    dense, emb, labels = random_inputs(spec, spec.batch)
    for _ in range(5):
        state = run_step(step, lo, state, dense, emb, labels)
    m = lo["metrics"]
    metrics = np.asarray(state[m.offset : m.offset + m.size])
    assert metrics[1] == 5 * spec.batch  # examples
    assert metrics[2] == 5  # steps
    assert metrics[0] > 0  # loss_sum


def test_train_step_only_touched_rows_change():
    """SGD must leave un-gathered pool rows untouched (sparse grads)."""
    spec = tiny_spec(method="hash", impl="reference")
    lo = model.build_layout(spec)
    step = jax.jit(model.make_train_step(spec, lo))
    state0 = init_state(lo, seed=5)
    dense, _, labels = random_inputs(spec, spec.batch, seed=5)
    emb = jnp.zeros((spec.batch, spec.n_features, 1, 1), dtype=jnp.int32)  # only row 0
    state1 = run_step(step, lo, state0, dense, emb, labels)
    pool_f = lo["pool"]
    p0 = np.asarray(state0[pool_f.offset : pool_f.offset + pool_f.size]).reshape(pool_f.shape)
    p1 = np.asarray(state1[pool_f.offset : pool_f.offset + pool_f.size]).reshape(pool_f.shape)
    assert not np.allclose(p0[0], p1[0])  # row 0 trained
    np.testing.assert_array_equal(p0[1:], p1[1:])  # everything else frozen


def test_predict_in_unit_interval():
    spec = tiny_spec(impl="reference")
    lo = model.build_layout(spec)
    predict = jax.jit(model.make_predict(spec, lo))
    state = init_state(lo)
    dense, emb, _ = random_inputs(spec, spec.eval_batch)
    gs = split_state(lo, state)
    p = predict(gs["pool"], gs["dense"], dense, emb)
    assert p.shape == (spec.eval_batch,)
    assert np.all((np.asarray(p) >= 0) & (np.asarray(p) <= 1))


def test_readout_slices_metrics():
    spec = tiny_spec()
    lo = model.build_layout(spec)
    ro = jax.jit(model.make_readout(lo))
    np.testing.assert_array_equal(
        ro(jnp.asarray(np.array([1, 2, 3, 4], dtype=np.float32))), [1, 2, 3, 4]
    )
