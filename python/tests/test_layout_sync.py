"""Layout ↔ runtime manifest sync: what `Layout.to_manifest` /
`Layout.buffers_manifest` emit must stay parseable by
`rust/src/runtime/manifest.rs` — same JSON keys, same schema version,
same structural invariants the Rust cross-validation enforces. A key
rename on either side fails here before it fails at artifact load."""

import os
import re

import pytest

from compile import model, specs
from compile.layout import BUFFER_GROUPS, SCHEMA_VERSION

RUST_MANIFEST = os.path.join(
    os.path.dirname(__file__), "..", "..", "rust", "src", "runtime", "manifest.rs"
)


@pytest.fixture(scope="module")
def rust_src():
    with open(RUST_MANIFEST) as f:
        return f.read()


def test_schema_version_matches_rust(rust_src):
    """The version python stamps is the version rust requires."""
    m = re.search(r"SCHEMA_VERSION:\s*u64\s*=\s*(\d+)", rust_src)
    assert m, "manifest.rs must declare SCHEMA_VERSION"
    assert int(m.group(1)) == SCHEMA_VERSION


def test_rust_parses_every_emitted_key(rust_src):
    """Every JSON key aot.py writes per field/buffer must be read by
    the Rust parser (as a string literal in manifest.rs)."""
    field_keys = ["name", "shape", "offset", "size", "init", "group"]
    buffer_keys = ["name", "offset", "size"]
    top_keys = ["schema_version", "buffers", "layout", "state_size", "tuple_shapes"]
    for key in set(field_keys + buffer_keys + top_keys):
        assert f'"{key}"' in rust_src, f"manifest.rs never reads {key!r}"


@pytest.mark.parametrize("spec", specs.base_specs(), ids=lambda s: s.name)
def test_buffer_manifest_invariants(spec):
    """The invariants rust's Manifest::parse cross-validates, checked at
    emit time for every base artifact (all MethodKinds)."""
    lo = model.build_layout(spec)
    bufs = lo.buffers_manifest()
    fields = lo.to_manifest()

    assert [b["name"] for b in bufs] == list(BUFFER_GROUPS)
    off = 0
    for b in bufs:
        assert b["offset"] == off, f"{spec.name}: buffer {b['name']} not contiguous"
        assert b["size"] > 0
        off += b["size"]
    assert off == lo.size, f"{spec.name}: buffers cover {off} of {lo.size}"

    by_name = {b["name"]: b for b in bufs}
    foff = 0
    for f in fields:
        assert f["offset"] == foff, f"{spec.name}: field {f['name']} not contiguous"
        foff += f["size"]
        b = by_name[f["group"]]
        assert b["offset"] <= f["offset"]
        assert f["offset"] + f["size"] <= b["offset"] + b["size"], (
            f"{spec.name}: field {f['name']} leaks out of buffer {f['group']}"
        )
    # the metrics buffer is exactly the metrics field (the runtime reads
    # it wholesale instead of executing readout)
    mf = [f for f in fields if f["group"] == "metrics"]
    assert len(mf) == 1 and mf[0]["name"] == "metrics"
    assert by_name["metrics"]["size"] == mf[0]["size"]
