"""AOT pipeline: lower every artifact to HLO text + JSON manifest.

Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per artifact `name`:
    artifacts/<name>.train.hlo.txt
    artifacts/<name>.predict.hlo.txt
    artifacts/<name>.readout.hlo.txt
    artifacts/<name>.json              (manifest: shapes, layout, hyperparams)
plus a top-level artifacts/index.json with the artifact list and the
dataset presets (the coordinator's single source of truth).

Usage:
    python -m compile.aot --out ../artifacts --set base
    python -m compile.aot --out ../artifacts --set sweep
    python -m compile.aot --dump-stats        # HLO op histograms (perf pass)
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, specs
from .kernels.kmeans import kmeans_step
from .layout import METRIC_NAMES, SCHEMA_VERSION


def to_hlo_text(lowered, return_tuple: bool = False) -> str:
    """StableHLO → XlaComputation → HLO text.

    ``return_tuple=True`` keeps a tuple root for multi-result functions
    (``train_step`` returns one buffer per state group; PJRT untuples the
    root into independent re-feedable buffers — docs/CALLING_CONVENTION.md).
    Single-result functions lower with a plain array root.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def hlo_stats(text: str) -> dict[str, int]:
    """Crude op histogram from HLO text (perf-pass fusion review)."""
    import re

    ops: dict[str, int] = {}
    for m in re.finditer(r"=\s+\S+\s+([a-z][a-z0-9\-]*)\(", text):
        ops[m.group(1)] = ops.get(m.group(1), 0) + 1
    return dict(sorted(ops.items(), key=lambda kv: -kv[1]))


def _input_desc(name: str, dtype: str, shape: tuple[int, ...]) -> dict:
    return {"name": name, "dtype": dtype, "shape": list(shape)}


def lower_artifact(spec: specs.ArtifactSpec, out_dir: str, dump_stats: bool) -> dict:
    """Lower train/predict/readout for one spec; return its manifest."""
    lo = model.build_layout(spec)
    bufs = lo.buffers()  # [(group, offset, size)] in pool/dense/metrics order
    group_s = {
        g: jax.ShapeDtypeStruct((size,), jnp.float32) for g, _, size in bufs
    }
    dense_t = jax.ShapeDtypeStruct((spec.batch, spec.n_dense), jnp.float32)
    dense_e = jax.ShapeDtypeStruct((spec.eval_batch, spec.n_dense), jnp.float32)
    emb_shape_t, emb_dtype = model.emb_input_shape(spec, spec.batch)
    emb_shape_e, _ = model.emb_input_shape(spec, spec.eval_batch)
    emb_t = jax.ShapeDtypeStruct(emb_shape_t, getattr(jnp, emb_dtype))
    emb_e = jax.ShapeDtypeStruct(emb_shape_e, getattr(jnp, emb_dtype))
    labels_t = jax.ShapeDtypeStruct((spec.batch,), jnp.float32)

    files = {}
    stats = {}
    for kind, fn, args, tuple_root in [
        (
            "train",
            model.make_train_step(spec, lo),
            (group_s["pool"], group_s["dense"], group_s["metrics"], dense_t, emb_t, labels_t),
            True,
        ),
        (
            "predict",
            model.make_predict(spec, lo),
            (group_s["pool"], group_s["dense"], dense_e, emb_e),
            False,
        ),
        ("readout", model.make_readout(lo), (group_s["metrics"],), False),
    ]:
        text = to_hlo_text(jax.jit(fn).lower(*args), return_tuple=tuple_root)
        fname = f"{spec.name}.{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        files[kind] = fname
        if dump_stats:
            stats[kind] = hlo_stats(text)

    emb_mdt = emb_dtype.replace("int32", "i32").replace("float32", "f32")
    state_inputs = {g: _input_desc(f"state.{g}", "f32", (size,)) for g, _, size in bufs}
    manifest = {
        "name": spec.name,
        "schema_version": SCHEMA_VERSION,
        "family": "dlrm",
        "kind": spec.kind,
        "dataset": spec.dataset,
        "method": spec.method,
        "spec": {
            "batch": spec.batch,
            "eval_batch": spec.eval_batch,
            "dim": spec.dim,
            "dc": spec.dc if spec.kind == "rowwise" else spec.dim,
            "t": spec.t,
            "c": spec.c,
            "cap": min(spec.cap, 1 << 40),
            "lr": spec.lr,
            "n_features": spec.n_features,
            "n_dense": spec.n_dense,
            "pool_rows": spec.pool_rows,
            "dhe_hidden": spec.dhe_hidden,
            "n_hash": spec.n_hash,
            "bot_mlp": list(spec.bot_mlp),
            "top_mlp": list(spec.top_mlp),
            "impl": spec.impl,
            "embedding_params": spec.embedding_params(),
        },
        "vocabs": spec.vocabs,
        "state_size": lo.size,
        "layout": lo.to_manifest(),
        "buffers": lo.buffers_manifest(),
        "metrics": {"offset": lo["metrics"].offset, "names": list(METRIC_NAMES)},
        "executables": files,
        "inputs": {
            "train": [
                state_inputs["pool"],
                state_inputs["dense"],
                state_inputs["metrics"],
                _input_desc("dense", "f32", (spec.batch, spec.n_dense)),
                _input_desc("emb", emb_mdt, emb_shape_t),
                _input_desc("labels", "f32", (spec.batch,)),
            ],
            "predict": [
                state_inputs["pool"],
                state_inputs["dense"],
                _input_desc("dense", "f32", (spec.eval_batch, spec.n_dense)),
                _input_desc("emb", emb_mdt, emb_shape_e),
            ],
            "readout": [state_inputs["metrics"]],
        },
        "outputs": {
            # train has a tuple root: one result per state buffer, in
            # buffer order, re-fed by the runtime step-to-step
            "train": {
                "dtype": "f32",
                "tuple_shapes": [[size] for _, _, size in bufs],
            },
            "predict": {"dtype": "f32", "shape": [spec.eval_batch]},
            "readout": {"dtype": "f32", "shape": [len(METRIC_NAMES)]},
        },
    }
    with open(os.path.join(out_dir, f"{spec.name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if dump_stats:
        print(f"== {spec.name} ==")
        for k, v in stats.items():
            top = ", ".join(f"{op}:{n}" for op, n in list(v.items())[:8])
            print(f"  {k}: {top}")
    return manifest


def lower_kmeans(spec: specs.KmeansSpec, out_dir: str) -> dict:
    pts = jax.ShapeDtypeStruct((spec.n_points, spec.dim), jnp.float32)
    cen = jax.ShapeDtypeStruct((spec.k, spec.dim), jnp.float32)
    text = to_hlo_text(jax.jit(kmeans_step).lower(pts, cen))
    fname = f"{spec.name}.step.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    manifest = {
        "name": spec.name,
        "family": "kmeans",
        "spec": {"n_points": spec.n_points, "dim": spec.dim, "k": spec.k},
        "executables": {"step": fname},
        "inputs": {
            "step": [
                _input_desc("points", "f32", (spec.n_points, spec.dim)),
                _input_desc("centroids", "f32", (spec.k, spec.dim)),
            ]
        },
        "outputs": {"step": {"dtype": "f32", "shape": [spec.k, spec.dim + 1]}},
    }
    with open(os.path.join(out_dir, f"{spec.name}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", dest="which", default="base", choices=sorted(specs.ARTIFACT_SETS))
    ap.add_argument("--only", default=None, help="build only artifacts whose name contains this")
    ap.add_argument("--force", action="store_true", help="rebuild even if manifest exists")
    ap.add_argument("--dump-stats", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = specs.ARTIFACT_SETS[args.which]()
    if args.only:
        todo = [s for s in todo if args.only in s.name]

    names = []
    for spec in todo:
        names.append(spec.name)
        mpath = os.path.join(args.out, f"{spec.name}.json")
        if not args.force and os.path.exists(mpath):
            print(f"[skip] {spec.name}", file=sys.stderr)
            continue
        print(f"[lower] {spec.name} (state={model.build_layout(spec).size})", file=sys.stderr)
        lower_artifact(spec, args.out, args.dump_stats)

    km_names = []
    if args.which in ("base", "all") and not args.only:
        for kspec in specs.kmeans_specs():
            km_names.append(kspec.name)
            mpath = os.path.join(args.out, f"{kspec.name}.json")
            if args.force or not os.path.exists(mpath):
                print(f"[lower] {kspec.name}", file=sys.stderr)
                lower_kmeans(kspec, args.out)

    # merge into the index (sweep and base runs both contribute)
    index_path = os.path.join(args.out, "index.json")
    index = {"artifacts": [], "kmeans": [], "datasets": {}}
    if os.path.exists(index_path):
        with open(index_path) as f:
            index = json.load(f)
    index["artifacts"] = sorted(set(index.get("artifacts", [])) | set(names))
    index["kmeans"] = sorted(set(index.get("kmeans", [])) | set(km_names))
    index["datasets"] = specs.DATASETS
    index["methods"] = {k: v for k, v in specs.METHODS.items()}
    with open(index_path, "w") as f:
        json.dump(index, f, indent=1)
    print(f"index: {len(index['artifacts'])} artifacts", file=sys.stderr)


if __name__ == "__main__":
    main()
