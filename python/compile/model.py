"""Layer-2: the DLRM compute graph in JAX, calling the Pallas kernels.

The model follows Naumov et al. (2019): a bottom MLP over the dense
features, one embedding per categorical feature (here: the generic
compressed-embedding layer driven by Rust-computed indices), the
pairwise-dot interaction, and a top MLP producing one logit.

Everything is expressed over the per-group flat buffers from
``layout.py`` (``pool`` / ``dense`` / ``metrics``) so each executable
takes one parameter per group and ``train_step`` returns a tuple root
re-fed buffer-for-buffer by the coordinator
(docs/CALLING_CONVENTION.md):

  * ``train_step(pool, dense_p, metrics, dense, idx, labels) →
    (pool', dense_p', metrics')`` — fwd + bwd + SGD + in-graph metric
    accumulation, fused into one HLO module.
  * ``predict(pool, dense_p, dense, idx) → f32[B]`` — probabilities
    (metrics never feeds the forward pass, so it is not an input).
  * ``readout(metrics) → f32[4]`` — the metric slots.

Index semantics per method kind:
  * rowwise     — ``idx i32[B, F, T, c]`` global row ids into pool[R, d/c]
  * elementwise — ``idx i32[B, F, d]`` element ids into pool_flat[R] (ROBE)
  * dhe         — ``hashes f32[B, F, n_hash]`` in [-1, 1] (no gather at all)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layout import METRIC_NAMES, Layout, mlp_fields
from .specs import ArtifactSpec
from .kernels import ref as kref
from .kernels.gather_sum import gather_sum_ad, gather_elements_ad
from .kernels.interaction import interaction_ad as interaction_pallas


# ---------------------------------------------------------------------------
# Layout construction
# ---------------------------------------------------------------------------


def build_layout(spec: ArtifactSpec) -> Layout:
    """Parameter layout for one artifact. Mirrored by tables/layout.rs."""
    lo = Layout()
    if spec.kind == "rowwise":
        # N(0, 1/d) rows, the DLRM embedding init convention scaled to the
        # subtable width so the T-term sum keeps unit-ish variance.
        lo.add("pool", (spec.pool_rows, spec.dc), ("normal", 1.0 / spec.dim), "pool")
    elif spec.kind == "elementwise":
        lo.add("pool_flat", (spec.pool_rows,), ("normal", 1.0 / spec.dim), "pool")
    elif spec.kind == "dhe":
        h, d, f = spec.dhe_hidden, spec.dim, spec.n_features
        for i, (fi, fo) in enumerate([(spec.n_hash, h), (h, h), (h, d)]):
            limit = (6.0 / (fi + fo)) ** 0.5
            lo.add(f"dhe_w{i}", (f, fi, fo), ("uniform", limit), "pool")
            lo.add(f"dhe_b{i}", (f, fo), ("zeros",), "pool")
    else:
        raise ValueError(spec.kind)

    mlp_fields(lo, "bot", [spec.n_dense, *spec.bot_mlp, spec.dim])
    n = spec.n_features + 1
    n_inter = n * (n - 1) // 2
    mlp_fields(lo, "top", [spec.dim + n_inter, *spec.top_mlp, 1])
    lo.add("metrics", (len(METRIC_NAMES),), ("zeros",), "metrics")
    return lo


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _mlp(params: dict, prefix: str, x: jnp.ndarray, n_layers: int, *, relu_last: bool) -> jnp.ndarray:
    for i in range(n_layers):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if relu_last or i + 1 < n_layers:
            x = jax.nn.relu(x)
    return x


def embed(spec: ArtifactSpec, params: dict, emb_in: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup → ``f32[B, F, d]`` for any method kind."""
    if spec.kind == "rowwise":
        if spec.impl == "pallas":
            return gather_sum_ad(params["pool"], emb_in)
        return kref.gather_sum_ref(params["pool"], emb_in)
    if spec.kind == "elementwise":
        if spec.impl == "pallas":
            return gather_elements_ad(params["pool_flat"], emb_in)
        return kref.gather_elements_ref(params["pool_flat"], emb_in)
    if spec.kind == "dhe":
        # per-feature 2-hidden-layer MLP with Mish (Kang et al. 2021)
        x = emb_in  # [B, F, n_hash]
        for i in range(3):
            x = jnp.einsum("bfi,fio->bfo", x, params[f"dhe_w{i}"]) + params[f"dhe_b{i}"]
            if i < 2:
                x = jax.nn.mish(x)
        return x
    raise ValueError(spec.kind)


def forward_logits(
    spec: ArtifactSpec, params: dict, dense: jnp.ndarray, emb_in: jnp.ndarray
) -> jnp.ndarray:
    """Full DLRM forward: ``→ f32[B]`` logits."""
    n_bot = len(spec.bot_mlp) + 1
    n_top = len(spec.top_mlp) + 1
    bot = _mlp(params, "bot", dense, n_bot, relu_last=True)  # [B, d]
    emb = embed(spec, params, emb_in)  # [B, F, d]
    z = jnp.concatenate([emb, bot[:, None, :]], axis=1)  # [B, F+1, d]
    if spec.impl == "pallas":
        inter = interaction_pallas(z)
    else:
        inter = kref.interaction_ref(z)
    top_in = jnp.concatenate([bot, inter], axis=1)
    return _mlp(params, "top", top_in, n_top, relu_last=False)[:, 0]


def bce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean binary cross-entropy, numerically stable in logit space."""
    return jnp.mean(jax.nn.softplus(logits) - labels * logits)


# ---------------------------------------------------------------------------
# Executables
# ---------------------------------------------------------------------------


def make_train_step(spec: ArtifactSpec, layout: Layout):
    """``(pool, dense_p, metrics, dense, emb_in, labels) →
    (pool', dense_p', metrics')`` with fused SGD + metrics."""

    def train_step(pool, dense_p, metrics_buf, dense, emb_in, labels):
        tensors = layout.unpack_groups(pool=pool, dense=dense_p, metrics=metrics_buf)
        metrics = tensors.pop("metrics")

        def loss_fn(params):
            logits = forward_logits(spec, params, dense, emb_in)
            return bce_from_logits(logits, labels)

        loss, grads = jax.value_and_grad(loss_fn)(tensors)
        new = {k: v - spec.lr * grads[k] for k, v in tensors.items()}
        b = float(labels.shape[0] if hasattr(labels, "shape") else spec.batch)
        new["metrics"] = jnp.stack(
            [
                metrics[0] + loss * b,  # loss_sum
                metrics[1] + b,  # examples
                metrics[2] + 1.0,  # steps
                loss,  # last_loss
            ]
        )
        return (
            layout.pack_group("pool", new),
            layout.pack_group("dense", new),
            layout.pack_group("metrics", new),
        )

    return train_step


def make_predict(spec: ArtifactSpec, layout: Layout):
    """``(pool, dense_p, dense, emb_in) → f32[B]`` probabilities.

    Perf note (EXPERIMENTS.md §Perf #7): predict always lowers the
    reference (pure-jnp) graph. Interpret-mode Pallas re-stages the whole
    pool per batch tile, which costs ~7× on the eval path at eval_batch
    1024 while adding nothing — the kernels' correctness is pinned by the
    train path and the pytest parity suite. The two graphs are
    numerically interchangeable (tests/test_model.py::
    test_pallas_and_reference_impl_agree).
    """
    import dataclasses

    pspec = dataclasses.replace(spec, impl="reference")

    def predict(pool, dense_p, dense, emb_in):
        tensors = layout.unpack_groups(pool=pool, dense=dense_p)
        return jax.nn.sigmoid(forward_logits(pspec, tensors, dense, emb_in))

    return predict


def make_readout(layout: Layout):
    """``metrics → f32[len(METRIC_NAMES)]`` (metric slots).

    The metrics group IS the metric slots, so this is an identity kept
    only so older tooling that walks `executables` still finds a readout
    HLO; the runtime reads the metrics buffer directly instead of
    executing it. The ×1.0 keeps the lowering from collapsing to a bare
    parameter root (bit-exact for every f32 the accumulators can hold).
    """
    m = layout["metrics"]

    def readout(metrics):
        return jnp.reshape(metrics, (m.size,)) * jnp.float32(1.0)

    return readout


def emb_input_shape(spec: ArtifactSpec, batch: int) -> tuple[tuple[int, ...], str]:
    """(shape, dtype-name) of the embedding-side input for a given batch."""
    f = spec.n_features
    if spec.kind == "rowwise":
        return (batch, f, spec.t, spec.c), "int32"
    if spec.kind == "elementwise":
        return (batch, f, spec.dim), "int32"
    if spec.kind == "dhe":
        return (batch, f, spec.n_hash), "float32"
    raise ValueError(spec.kind)
