"""Pallas kernel: DLRM pairwise-dot interaction layer.

For each sample, stacks the N per-feature vectors (26 embeddings + the
bottom-MLP output) into ``Z ∈ R^{N×d}`` and emits the strictly-lower
triangle of ``Z Zᵀ`` — the feature-interaction terms fed to the top MLP
(Naumov et al. 2019, Figure 2 of the paper).

TPU adaptation: the per-sample GEMM is tiny (N=27, d=16), so the grid tiles
TILE_B samples per step and issues one batched einsum per tile — on TPU
this maps to MXU matmuls over a (TILE_B·N, d) operand; with TILE_B=8 the
operand is (216, 16), padding to the (128, 128) systolic tile at ~84%
row occupancy in bf16 (two MXU passes). The triangle extraction is a VPU
gather over a static index pattern.

VMEM per grid step: TILE_B*N*d + TILE_B*N*N floats ≈ 8*(27*16 + 729)*4 B
≈ 37 KiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _interaction_kernel(z_ref, tri_ref, out_ref, *, n: int):
    z = z_ref[...]  # [TILE_B, N, d]
    zzt = jnp.einsum("bnd,bmd->bnm", z, z)  # MXU
    tb = z.shape[0]
    flat = zzt.reshape(tb, n * n)
    # tri_ref holds the static flat triangle offsets i*n+j (i > j); the
    # gather runs on the VPU. Passed as an input because Pallas kernels may
    # not capture array constants.
    out_ref[...] = flat[:, tri_ref[...]]


def interaction(z: jnp.ndarray, *, tile_b: int | None = None) -> jnp.ndarray:
    """Pairwise-dot interaction. ``z: f32[B, N, d] → f32[B, N(N-1)/2]``."""
    b, n, d = z.shape
    if tile_b is None:
        tile_b = min(b, 8)
    if b % tile_b != 0:
        raise ValueError(f"batch {b} not divisible by tile_b {tile_b}")
    ti, tj = np.tril_indices(n, k=-1)
    tri = jnp.asarray(ti * n + tj, dtype=jnp.int32)
    n_out = len(ti)
    kernel = functools.partial(_interaction_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(b // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, n, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_out,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_out), z.dtype),
        interpret=True,
    )(z, tri)


# ---------------------------------------------------------------------------
# Autodiff: pallas_call has no VJP rule. d/dz of tril(z zᵀ) with cotangent g
# is (G + Gᵀ) z where G scatters g back into the [N, N] grid — one batched
# matmul, which XLA fuses with the surrounding graph.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def interaction_ad(z: jnp.ndarray) -> jnp.ndarray:
    """Differentiable wrapper over :func:`interaction`."""
    return interaction(z)


def _interaction_fwd(z):
    return interaction(z), z


def _interaction_bwd(z, g):
    b, n, d = z.shape
    ti, tj = np.tril_indices(n, k=-1)
    gm = jnp.zeros((b, n, n), g.dtype).at[:, ti, tj].set(g)
    dz = jnp.einsum("bnm,bmd->bnd", gm + jnp.swapaxes(gm, 1, 2), z)
    return (dz,)


interaction_ad.defvjp(_interaction_fwd, _interaction_bwd)
