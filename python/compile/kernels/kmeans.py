"""Pallas kernel: K-means assignment (distance + argmin), plus a full Lloyd
step built on top of it.

This is the accelerated inner loop of the CCE clustering event
(Algorithm 3 line 13). The default coordinator path runs K-means in Rust;
this artifact is the optional offloaded path and the subject of the
kmeans-offload ablation bench.

TPU adaptation: ``‖x − c‖² = ‖x‖² − 2·x·cᵀ + ‖c‖²`` — the cross term is an
MXU matmul tiled (TILE_N points × all k centroids, k ≤ 2048 for every
preset); norms and the argmin reduction run on the VPU. VMEM per grid step:
TILE_N·d + k·d + TILE_N·k floats; with TILE_N=256, d=16, k=2048 that is
~2.3 MiB — fits with double buffering.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(pts_ref, cen_ref, out_ref):
    pts = pts_ref[...]  # [TILE_N, d]
    cen = cen_ref[...]  # [k, d]
    # ‖x‖² is constant across centroids — omit it from the argmin operand.
    d2 = -2.0 * pts @ cen.T + jnp.sum(cen * cen, axis=1)[None, :]
    out_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)


def kmeans_assign(
    points: jnp.ndarray, centroids: jnp.ndarray, *, tile_n: int | None = None
) -> jnp.ndarray:
    """Nearest-centroid assignment. ``(f32[n,d], f32[k,d]) → i32[n]``."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, (d, d2)
    if tile_n is None:
        tile_n = min(n, 256)
    if n % tile_n != 0:
        raise ValueError(f"n {n} not divisible by tile_n {tile_n}")
    return pl.pallas_call(
        _assign_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(points, centroids)


def kmeans_step(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """One Lloyd iteration, packed for the single-output PJRT convention.

    Returns ``f32[k, d+1]``: new centroids in ``[:, :d]`` and per-cluster
    counts in ``[:, d]`` (the coordinator unpacks; empty clusters keep the
    previous centroid, mirroring the Rust repair policy).
    """
    k, d = centroids.shape
    assign = kmeans_assign(points, centroids)
    one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    counts = one_hot.sum(axis=0)
    sums = one_hot.T @ points
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], centroids)
    return jnp.concatenate([new_c, counts[:, None]], axis=1)
