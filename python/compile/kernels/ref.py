"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth for kernel correctness: every Pallas kernel in
this package has a matching ``*_ref`` here, and ``python/tests`` asserts
allclose between the two across hypothesis-generated shapes/dtypes.

They are also used directly by ``model.py`` when building the
``impl="reference"`` variant of each artifact, which gives an end-to-end
oracle for the whole lowered model.
"""

from __future__ import annotations

import jax.numpy as jnp


def gather_sum_ref(pool: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Fused multi-term compositional-embedding lookup (reference).

    Args:
      pool: ``f32[R, dc]`` row pool. Every (feature, term, column) subtable
        occupies a contiguous row range; the indices below are *global* row
        ids into this pool (offsets are applied by the caller — in
        production, the Rust coordinator).
      idx:  ``i32[B, F, T, c]`` gather indices: batch, feature, term, column.

    Returns:
      ``f32[B, F, c*dc]`` embeddings: for each (b, f) the embedding is the
      concatenation over columns of the sum over terms of pool rows —
      exactly ``concat_j sum_t pool[idx[b,f,t,j]]`` (Algorithm 3's
      ``CONCAT(M_i[h_i(id)] + M'_i[h'_i(id)])`` generalized to T terms).
    """
    rows = pool[idx]  # [B, F, T, c, dc]
    summed = rows.sum(axis=2)  # [B, F, c, dc]
    b, f, c, dc = summed.shape
    return summed.reshape(b, f, c * dc)


def gather_elements_ref(pool_flat: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Element-wise gather used by ROBE-style windowed embeddings.

    Args:
      pool_flat: ``f32[R]`` flat parameter array.
      idx: ``i32[B, F, d]`` element indices (windows with wrap-around are
        materialized by the caller).

    Returns:
      ``f32[B, F, d]``.
    """
    return pool_flat[idx]


def interaction_ref(z: jnp.ndarray) -> jnp.ndarray:
    """DLRM pairwise-dot interaction (reference).

    Args:
      z: ``f32[B, N, d]`` per-sample stack of N vectors (26 embeddings +
        bottom-MLP output in DLRM).

    Returns:
      ``f32[B, N*(N-1)/2]`` strictly-lower-triangular entries of ``z zᵀ``
      per sample, row-major over (i > j), matching Naumov et al.'s
      interaction layer.
    """
    zzt = jnp.einsum("bnd,bmd->bnm", z, z)
    n = z.shape[1]
    ti, tj = jnp.tril_indices(n, k=-1)
    return zzt[:, ti, tj]


def kmeans_assign_ref(points: jnp.ndarray, centroids: jnp.ndarray) -> jnp.ndarray:
    """K-means assignment step (reference).

    Args:
      points: ``f32[n, d]``.
      centroids: ``f32[k, d]``.

    Returns:
      ``i32[n]`` index of the nearest centroid under squared L2, ties to
      the lowest index (argmin semantics).
    """
    d2 = (
        jnp.sum(points * points, axis=1, keepdims=True)
        - 2.0 * points @ centroids.T
        + jnp.sum(centroids * centroids, axis=1)[None, :]
    )
    return jnp.argmin(d2, axis=1).astype(jnp.int32)


def kmeans_update_ref(
    points: jnp.ndarray, centroids: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One full Lloyd iteration (assignment + centroid update), reference.

    Empty clusters keep their previous centroid (same policy as the Rust
    implementation's "repair" fallback before re-seeding).

    Returns:
      ``(new_centroids f32[k, d], counts f32[k])``.
    """
    k = centroids.shape[0]
    assign = kmeans_assign_ref(points, centroids)
    one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)
    counts = one_hot.sum(axis=0)  # [k]
    sums = one_hot.T @ points  # [k, d]
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], centroids)
    return new_c, counts
