"""Pallas kernel: fused compositional-embedding gather (the lookup hot spot).

Computes, for every (batch, feature) pair, the concatenation over ``c``
columns of the sum over ``T`` terms of rows of a shared parameter pool:

    out[b, f, j*dc:(j+1)*dc] = sum_t pool[idx[b, f, t, j]]

which is Algorithm 3's ``CONCAT_i(M_i[h_i(id)] + M'_i[h'_i(id)])``
generalized to ``T`` terms, with all subtables packed into one row pool so a
single gather covers every method in the zoo (full/hash/hash-emb/CE/CCE).

TPU adaptation (paper targets A100 gathers; see DESIGN.md §8): the grid
tiles the *batch* dimension; each grid step stages a ``[TILE_B, F, T, c]``
index block and accumulates ``T`` gathered rows per (sample, feature,
column) in VMEM. On a real TPU the pool lives in HBM and rows are DMA'd per
index (scalar-prefetch style); ``interpret=True`` executes the same
schedule with jnp semantics on CPU, which is what the AOT pipeline lowers.

VMEM footprint per grid step (estimate, f32):
    idx tile:  TILE_B*F*T*c * 4 B
    out tile:  TILE_B*F*c*dc * 4 B
    row stage: T*c*dc * 4 B (double-buffered DMA target)
e.g. TILE_B=32, F=26, T=2, c=4, dc=4 → ~80 KiB ≪ 16 MiB VMEM.
MXU utilization: none (pure VPU adds) — this kernel is DMA-bound by design.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_sum_kernel(pool_ref, idx_ref, out_ref, *, t_terms: int, c_cols: int):
    """Kernel body: one batch tile.

    ``pool_ref`` maps the whole pool (HBM-resident on TPU; the fancy-index
    below is the interpret-mode stand-in for per-row DMA).
    """
    pool = pool_ref[...]  # [R, dc]
    idx = idx_ref[...]  # [TILE_B, F, T, c]
    acc = None
    # T and c are static: unrolled accumulation keeps one VMEM accumulator.
    for t in range(t_terms):
        rows = pool[idx[:, :, t, :]]  # [TILE_B, F, c, dc]
        acc = rows if acc is None else acc + rows
    tb, f, c, dc = acc.shape
    out_ref[...] = acc.reshape(tb, f, c * dc)


def gather_sum(pool: jnp.ndarray, idx: jnp.ndarray, *, tile_b: int | None = None) -> jnp.ndarray:
    """Fused embedding lookup. See module docstring.

    Args:
      pool: ``f32[R, dc]``.
      idx:  ``i32[B, F, T, c]``; ``B`` must be divisible by ``tile_b``.
      tile_b: batch tile per grid step (default: ``min(B, 32)``).

    Returns:
      ``f32[B, F, c*dc]``.
    """
    b, f, t_terms, c_cols = idx.shape
    r, dc = pool.shape
    if tile_b is None:
        tile_b = min(b, 32)
    if b % tile_b != 0:
        raise ValueError(f"batch {b} not divisible by tile_b {tile_b}")
    grid = (b // tile_b,)
    kernel = functools.partial(_gather_sum_kernel, t_terms=t_terms, c_cols=c_cols)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, dc), lambda i: (0, 0)),  # whole pool each step
            pl.BlockSpec((tile_b, f, t_terms, c_cols), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, f, c_cols * dc), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, c_cols * dc), pool.dtype),
        interpret=True,
    )(pool, idx)


# ---------------------------------------------------------------------------
# Autodiff: pallas_call has no VJP rule, so the kernels carry custom VJPs.
# The backward of a gather is a scatter-add into the pool; on TPU that is
# the embedding-gradient kernel (DMA-bound like the forward). Here it is
# expressed with jnp scatter-add, which XLA lowers to the same scatter HLO
# the reference implementation produces — so fwd uses the Pallas schedule
# while bwd matches the oracle exactly.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def gather_sum_ad(pool: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Differentiable wrapper over :func:`gather_sum` (grad wrt pool)."""
    return gather_sum(pool, idx)


def _gather_sum_fwd(pool, idx):
    return gather_sum(pool, idx), (pool.shape, idx)


def _gather_sum_bwd(res, g):
    (pool_shape, idx) = res
    b, f, t_terms, c_cols = idx.shape
    dc = pool_shape[1]
    g4 = g.reshape(b, f, c_cols, dc)  # undo the concat
    g_pool = jnp.zeros(pool_shape, g.dtype)
    for t in range(t_terms):
        g_pool = g_pool.at[idx[:, :, t, :]].add(g4)
    return g_pool, None


gather_sum_ad.defvjp(_gather_sum_fwd, _gather_sum_bwd)


@jax.custom_vjp
def gather_elements_ad(pool_flat: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Differentiable wrapper over :func:`gather_elements`."""
    return gather_elements(pool_flat, idx)


def _gather_elements_fwd(pool_flat, idx):
    return gather_elements(pool_flat, idx), (pool_flat.shape, idx)


def _gather_elements_bwd(res, g):
    (pool_shape, idx) = res
    return jnp.zeros(pool_shape, g.dtype).at[idx].add(g), None


gather_elements_ad.defvjp(_gather_elements_fwd, _gather_elements_bwd)


def _gather_elements_kernel(pool_ref, idx_ref, out_ref):
    pool = pool_ref[...]  # [R]
    out_ref[...] = pool[idx_ref[...]]


def gather_elements(
    pool_flat: jnp.ndarray, idx: jnp.ndarray, *, tile_b: int | None = None
) -> jnp.ndarray:
    """ROBE-style element gather: ``out[b,f,e] = pool_flat[idx[b,f,e]]``.

    ROBE windows (contiguous runs with wrap-around in a flat array) are
    materialized as element indices by the coordinator, so one kernel
    serves any windowing scheme.
    """
    b, f, d = idx.shape
    (r,) = pool_flat.shape
    if tile_b is None:
        tile_b = min(b, 32)
    if b % tile_b != 0:
        raise ValueError(f"batch {b} not divisible by tile_b {tile_b}")
    return pl.pallas_call(
        _gather_elements_kernel,
        grid=(b // tile_b,),
        in_specs=[
            pl.BlockSpec((r,), lambda i: (0,)),
            pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, d), pool_flat.dtype),
        interpret=True,
    )(pool_flat, idx)
