"""Per-buffer parameter layout.

Model parameters live in THREE flat ``f32`` device buffers — one per
field group — so state that never changes together never crosses the
wire together (docs/CALLING_CONVENTION.md):

  * ``pool``    — the embedding-side fields (``pool`` / ``pool_flat`` /
                  the DHE MLP stacks); what clustering events rewrite.
  * ``dense``   — the bottom/top MLP weights; untouched by events.
  * ``metrics`` — the in-graph metric accumulators (loss-sum, example
                  count, step count, last loss).

Each executable takes one input parameter per group (``state.pool``,
``state.dense``, ``state.metrics``) and ``train_step`` returns a tuple
root with one result per group, which the Rust coordinator re-feeds
buffer-for-buffer step-to-step. The *flat* view (fields at contiguous
absolute offsets, groups in pool → dense → metrics order) is still the
host-side interchange format for init vectors and checkpoints; a group
is just a contiguous range of it.

The layout (field order, group tags, offsets, init specs) is defined
here and exported verbatim into each artifact's JSON manifest
(``schema_version`` 2); the Rust side (`rust/src/runtime/manifest.rs`,
`rust/src/tables/layout.rs`) mirrors it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import jax.numpy as jnp

METRIC_NAMES = ("loss_sum", "examples", "steps", "last_loss")

#: canonical group order — groups must be added in this order so each
#: one is a contiguous range of the flat state vector
BUFFER_GROUPS = ("pool", "dense", "metrics")

#: manifest schema: 2 = per-group device buffers (top-level "buffers"
#: list + per-field "group" tags). Bump when the calling convention
#: changes shape again; rust/src/runtime/manifest.rs rejects mismatches.
SCHEMA_VERSION = 2


@dataclasses.dataclass(frozen=True)
class Field:
    """One named tensor inside the packed state vector."""

    name: str
    shape: tuple[int, ...]
    offset: int
    #: init spec, applied by the Rust coordinator: ("zeros",), ("normal",
    #: scale) or ("uniform", limit) — limit as in Glorot/LeCun fan-based init.
    init: tuple
    #: which device buffer the field lives in (one of BUFFER_GROUPS)
    group: str

    @property
    def size(self) -> int:
        return math.prod(self.shape)


class Layout:
    """Ordered collection of fields with contiguous offsets, partitioned
    into the BUFFER_GROUPS device buffers."""

    def __init__(self) -> None:
        self.fields: list[Field] = []
        self._by_name: dict[str, Field] = {}
        self.size = 0

    def add(self, name: str, shape: Iterable[int], init: tuple, group: str) -> Field:
        shape = tuple(int(s) for s in shape)
        if name in self._by_name:
            raise ValueError(f"duplicate field {name!r}")
        if group not in BUFFER_GROUPS:
            raise ValueError(f"field {name!r}: unknown group {group!r}")
        if self.fields:
            prev = BUFFER_GROUPS.index(self.fields[-1].group)
            if BUFFER_GROUPS.index(group) < prev:
                raise ValueError(
                    f"field {name!r}: group {group!r} added after "
                    f"{self.fields[-1].group!r} — groups must be contiguous "
                    f"in {BUFFER_GROUPS} order"
                )
        f = Field(name, shape, self.size, init, group)
        self.fields.append(f)
        self._by_name[name] = f
        self.size += f.size
        return f

    def __getitem__(self, name: str) -> Field:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def group_fields(self, group: str) -> list[Field]:
        return [f for f in self.fields if f.group == group]

    def buffers(self) -> list[tuple[str, int, int]]:
        """(group, offset, size) per device buffer, in BUFFER_GROUPS order.

        Every group must be non-empty: the calling convention feeds one
        parameter per group to every executable, so an artifact without
        (say) dense fields would need a different lowering.
        """
        out = []
        for g in BUFFER_GROUPS:
            fs = self.group_fields(g)
            if not fs:
                raise ValueError(f"layout has no {g!r} fields")
            out.append((g, fs[0].offset, sum(f.size for f in fs)))
        return out

    def unpack(self, state: jnp.ndarray) -> dict[str, jnp.ndarray]:
        """Slice the flat state into named tensors (trace-time, zero-copy)."""
        out = {}
        for f in self.fields:
            out[f.name] = jnp.reshape(state[f.offset : f.offset + f.size], f.shape)
        return out

    def unpack_groups(self, **groups: jnp.ndarray) -> dict[str, jnp.ndarray]:
        """Slice per-group flat buffers into named tensors.

        Only the provided groups are unpacked (``predict`` never feeds
        ``metrics``). Field offsets are absolute (flat-state) positions;
        inside its group buffer a field starts at ``offset - group_offset``.
        """
        unknown = set(groups) - set(BUFFER_GROUPS)
        if unknown:
            raise ValueError(f"unknown groups {sorted(unknown)}")
        out = {}
        for g, g_off, g_size in self.buffers():
            if g not in groups:
                continue
            buf = groups[g]
            if buf.shape != (g_size,):
                raise ValueError(f"group {g}: expected ({g_size},), got {buf.shape}")
            for f in self.group_fields(g):
                rel = f.offset - g_off
                out[f.name] = jnp.reshape(buf[rel : rel + f.size], f.shape)
        return out

    def pack_group(self, group: str, tensors: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Concatenate the group's tensors back into its flat buffer."""
        parts = []
        for f in self.group_fields(group):
            t = tensors[f.name]
            if tuple(t.shape) != f.shape:
                raise ValueError(f"field {f.name}: expected {f.shape}, got {t.shape}")
            parts.append(jnp.reshape(t, (f.size,)))
        return jnp.concatenate(parts)

    def pack(self, tensors: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Concatenate named tensors back into the flat state vector."""
        return jnp.concatenate([self.pack_group(g, tensors) for g in BUFFER_GROUPS])

    def to_manifest(self) -> list[dict]:
        return [
            {
                "name": f.name,
                "shape": list(f.shape),
                "offset": f.offset,
                "size": f.size,
                "init": list(f.init),
                "group": f.group,
            }
            for f in self.fields
        ]

    def buffers_manifest(self) -> list[dict]:
        return [
            {"name": g, "offset": off, "size": size} for g, off, size in self.buffers()
        ]


def mlp_fields(layout: Layout, prefix: str, sizes: list[int]) -> None:
    """Add weight/bias fields for an MLP with the given layer sizes.

    Uses LeCun-uniform init limits (what the DLRM reference uses for its
    MLPs): ``limit = sqrt(6 / (fan_in + fan_out))``.
    """
    for i in range(len(sizes) - 1):
        fan_in, fan_out = sizes[i], sizes[i + 1]
        limit = math.sqrt(6.0 / (fan_in + fan_out))
        layout.add(f"{prefix}_w{i}", (fan_in, fan_out), ("uniform", limit), "dense")
        layout.add(f"{prefix}_b{i}", (fan_out,), ("zeros",), "dense")
