"""Packed-state parameter layout.

All model parameters live in ONE flat ``f32[S]`` state vector so every
executable has a single array output and the Rust coordinator can chain
device buffers step-to-step (see DESIGN.md §7 — PJRT tuple outputs cannot
be re-fed). The layout (field order, offsets, init specs) is defined here
and exported verbatim into each artifact's JSON manifest; the Rust side
(`rust/src/runtime/manifest.rs`, `rust/src/tables/layout.rs`) mirrors it.

The final ``metrics`` field holds the in-graph metric accumulators
(loss-sum, example count, step count, last loss) that the tiny ``readout``
executable extracts.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import jax.numpy as jnp

METRIC_NAMES = ("loss_sum", "examples", "steps", "last_loss")


@dataclasses.dataclass(frozen=True)
class Field:
    """One named tensor inside the packed state vector."""

    name: str
    shape: tuple[int, ...]
    offset: int
    #: init spec, applied by the Rust coordinator: ("zeros",), ("normal",
    #: scale) or ("uniform", limit) — limit as in Glorot/LeCun fan-based init.
    init: tuple

    @property
    def size(self) -> int:
        return math.prod(self.shape)


class Layout:
    """Ordered collection of fields with contiguous offsets."""

    def __init__(self) -> None:
        self.fields: list[Field] = []
        self._by_name: dict[str, Field] = {}
        self.size = 0

    def add(self, name: str, shape: Iterable[int], init: tuple) -> Field:
        shape = tuple(int(s) for s in shape)
        if name in self._by_name:
            raise ValueError(f"duplicate field {name!r}")
        f = Field(name, shape, self.size, init)
        self.fields.append(f)
        self._by_name[name] = f
        self.size += f.size
        return f

    def __getitem__(self, name: str) -> Field:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def unpack(self, state: jnp.ndarray) -> dict[str, jnp.ndarray]:
        """Slice the flat state into named tensors (trace-time, zero-copy)."""
        out = {}
        for f in self.fields:
            out[f.name] = jnp.reshape(state[f.offset : f.offset + f.size], f.shape)
        return out

    def pack(self, tensors: dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Concatenate named tensors back into the flat state vector."""
        parts = []
        for f in self.fields:
            t = tensors[f.name]
            if tuple(t.shape) != f.shape:
                raise ValueError(f"field {f.name}: expected {f.shape}, got {t.shape}")
            parts.append(jnp.reshape(t, (f.size,)))
        return jnp.concatenate(parts)

    def to_manifest(self) -> list[dict]:
        return [
            {
                "name": f.name,
                "shape": list(f.shape),
                "offset": f.offset,
                "size": f.size,
                "init": list(f.init),
            }
            for f in self.fields
        ]


def mlp_fields(layout: Layout, prefix: str, sizes: list[int]) -> None:
    """Add weight/bias fields for an MLP with the given layer sizes.

    Uses LeCun-uniform init limits (what the DLRM reference uses for its
    MLPs): ``limit = sqrt(6 / (fan_in + fan_out))``.
    """
    for i in range(len(sizes) - 1):
        fan_in, fan_out = sizes[i], sizes[i + 1]
        limit = math.sqrt(6.0 / (fan_in + fan_out))
        layout.add(f"{prefix}_w{i}", (fan_in, fan_out), ("uniform", limit))
        layout.add(f"{prefix}_b{i}", (fan_out,), ("zeros",))
