//! Integration tests over the real runtime: these load the AOT artifacts
//! produced by `make artifacts` and exercise the full stack (index gen →
//! PJRT execution → metrics → clustering events).
//!
//! Run via `make test` (which builds artifacts first).

use cce::config::TrainConfig;
use cce::coordinator::cluster::{apply_cluster, cluster_event, compute_cluster, ClusterConfig};
use cce::coordinator::train;
use cce::data::batch::{BatchIter, Split};
use cce::data::SyntheticDataset;
use cce::runtime::session::EmbInput;
use cce::runtime::{ArtifactStore, DlrmSession};
use cce::tables::indexer::{Indexer, MethodKind};
use cce::tables::init::init_state;
use cce::tables::layout::TablePlan;
use cce::util::Rng;

fn store() -> ArtifactStore {
    ArtifactStore::open(ArtifactStore::default_dir())
        .expect("artifacts missing — run `make artifacts` first")
}

fn smoke_cfg(artifact: &str) -> TrainConfig {
    TrainConfig {
        artifact: artifact.into(),
        epochs: 1,
        cluster_times: 0,
        eval_every: 32,
        ..Default::default()
    }
}

/// Run `n` deterministic train steps (unshuffled train split, skipping
/// `skip` batches first) against a session + indexer pair, dispatching
/// on the indexer's method kind like the trainer does.
fn step_n(
    session: &mut DlrmSession,
    ix: &Indexer,
    ds: &SyntheticDataset,
    skip: usize,
    n: usize,
) {
    let m = session.manifest.clone();
    let mut it = BatchIter::new(ds, Split::Train, m.spec.batch, None);
    it.skip_batches(skip);
    let mut b = it.alloc_batch();
    let elems = session.emb_elems("train").unwrap();
    let mut rows = vec![0i32; elems];
    let mut hashes = vec![0f32; elems];
    for _ in 0..n {
        assert!(it.next_into(&mut b), "ran out of train batches");
        match ix.kind {
            MethodKind::RowWise => {
                ix.fill_rowwise(&b.cats, m.spec.batch, &mut rows);
                session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels).unwrap();
            }
            MethodKind::ElementWise => {
                ix.fill_elementwise(&b.cats, m.spec.batch, &mut rows);
                session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels).unwrap();
            }
            MethodKind::Dhe => {
                ix.fill_dhe(&b.cats, m.spec.batch, &mut hashes);
                session.train_step(&b.dense, EmbInput::Hashes(&hashes), &b.labels).unwrap();
            }
        }
    }
}

#[test]
fn chained_training_decreases_loss() {
    let store = store();
    let mut session = DlrmSession::open(&store, "smoke_cce").unwrap();
    let m = session.manifest.clone();
    let mut rng = Rng::new(0);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
    let plan = TablePlan::new(&m.vocabs, m.spec.cap, m.spec.t, m.spec.c, m.spec.dc);
    let ix = Indexer::new_rowwise(&mut rng, plan);
    let ds = SyntheticDataset::new(store.dataset("smoke", 0).unwrap());
    let mut it = cce::data::batch::BatchIter::new(&ds, Split::Train, m.spec.batch, None);
    let mut b = it.alloc_batch();
    let mut rows = vec![0i32; session.emb_elems("train").unwrap()];
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for _ in 0..40 {
        if !it.next_into(&mut b) {
            break;
        }
        ix.fill_rowwise(&b.cats, m.spec.batch, &mut rows);
        session.train_step(&b.dense, EmbInput::Rows(&rows), &b.labels).unwrap();
        let met = session.metrics().unwrap();
        last_loss = met[3] as f64; // last_loss slot
        if first_loss.is_none() {
            first_loss = Some(last_loss);
        }
    }
    assert!(
        last_loss < first_loss.unwrap(),
        "loss did not decrease: {first_loss:?} → {last_loss}"
    );
}

#[test]
fn pallas_and_reference_artifacts_agree() {
    // identical state + inputs through the pallas-kernel lowering and the
    // pure-jnp lowering must produce (near-)identical predictions
    let store = store();
    let mut sp = DlrmSession::open(&store, "smoke_cce").unwrap();
    let mut sr = DlrmSession::open(&store, "smoke_cce_ref").unwrap();
    assert_eq!(sp.manifest.state_size, sr.manifest.state_size);
    let m = sp.manifest.clone();
    let mut rng = Rng::new(7);
    let state = init_state(&m.layout, m.state_size, &mut rng);
    sp.set_state(&state).unwrap();
    sr.set_state(&state).unwrap();
    let eb = m.spec.eval_batch;
    let dense: Vec<f32> = (0..eb * m.spec.n_dense).map(|i| ((i % 13) as f32) / 13.0).collect();
    let rows: Vec<i32> = (0..sp.emb_elems("predict").unwrap())
        .map(|i| (i % m.spec.pool_rows) as i32)
        .collect();
    let pp = sp.predict(&dense, EmbInput::Rows(&rows)).unwrap();
    let pr = sr.predict(&dense, EmbInput::Rows(&rows)).unwrap();
    for (a, b) in pp.iter().zip(&pr) {
        assert!((a - b).abs() < 1e-4, "pallas {a} vs reference {b}");
    }
}

#[test]
fn shape_validation_errors_instead_of_aborting() {
    // PJRT aborts the process on bad shapes; the session must catch them
    let store = store();
    let mut session = DlrmSession::open(&store, "smoke_cce").unwrap();
    let m = session.manifest.clone();
    assert!(session.set_state(&vec![0.0; 10]).is_err());
    let mut rng = Rng::new(0);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
    let bad_dense = vec![0f32; 7];
    let rows = vec![0i32; session.emb_elems("train").unwrap()];
    let labels = vec![0f32; m.spec.batch];
    assert!(session.train_step(&bad_dense, EmbInput::Rows(&rows), &labels).is_err());
    // wrong emb dtype
    let hashes = vec![0f32; rows.len()];
    assert!(session
        .train_step(&vec![0f32; m.spec.batch * m.spec.n_dense], EmbInput::Hashes(&hashes), &labels)
        .is_err());
}

#[test]
fn full_train_run_is_deterministic() {
    let store = store();
    let cfg = smoke_cfg("smoke_cce");
    let a = train(&store, &cfg).unwrap();
    let b = train(&store, &cfg).unwrap();
    assert_eq!(a.test_bce, b.test_bce);
    assert_eq!(a.test_auc, b.test_auc);
    assert_eq!(a.steps_run, b.steps_run);
    let c = train(&store, &TrainConfig { seed: 1, ..cfg }).unwrap();
    assert_ne!(a.test_bce, c.test_bce); // different seed → different run
}

#[test]
fn field_ranged_transfer_round_trips_every_field() {
    // pull_field must equal the pull_state slice, and set_field must
    // patch exactly its own range, for EVERY field in the layout of
    // EVERY method kind — the contract the field-ranged clustering-event
    // path stands on, now over per-group device buffers
    let store = store();
    let cases = [
        ("smoke_cce", 0u64),
        ("smoke_cce", 7),
        ("smoke_robe", 0),
        ("smoke_dhe", 0),
        ("smoke_hash", 0),
    ];
    for (artifact, seed) in cases {
        let mut session = DlrmSession::open(&store, artifact).unwrap();
        let m = session.manifest.clone();
        let mut rng = Rng::new(seed);
        session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
        // a few real steps so the device state isn't the init vector and
        // the buffers being sliced are post-training tuple results
        let ds = SyntheticDataset::new(store.dataset(&m.dataset, seed).unwrap());
        let ix = cce::coordinator::trainer::build_indexer(&m, seed).unwrap();
        step_n(&mut session, &ix, &ds, 0, 3);

        let full = session.pull_state().unwrap();
        for f in &m.layout {
            assert_eq!(
                session.pull_field(f).unwrap(),
                full[f.offset..f.offset + f.size].to_vec(),
                "pull_field({}) != pull_state slice",
                f.name
            );
        }
        let mut expect = full.clone();
        for f in &m.layout {
            let mut patch = session.pull_field(f).unwrap();
            for (i, v) in patch.iter_mut().enumerate() {
                *v = (i % 13) as f32 * 0.125 - 0.5;
            }
            session.set_field(f, &patch).unwrap();
            expect[f.offset..f.offset + f.size].copy_from_slice(&patch);
            assert_eq!(
                session.pull_state().unwrap(),
                expect,
                "set_field({}) leaked outside its range",
                f.name
            );
        }
        // validation: unknown fields and wrong patch sizes must error
        let mut bogus = m.layout[0].clone();
        bogus.name = "nope".into();
        assert!(session.pull_field(&bogus).is_err());
        let first = m.layout[0].clone();
        assert!(session.set_field(&first, &vec![0.0; first.size + 1]).is_err());
        let mut skewed = first.clone();
        skewed.offset += 1;
        assert!(session.pull_field(&skewed).is_err(), "stale descriptor must be rejected");
        let mut regrouped = first.clone();
        regrouped.group = "metrics".into();
        assert!(session.pull_field(&regrouped).is_err(), "wrong group tag must be rejected");
    }
}

#[test]
fn field_ranged_event_path_matches_full_round_trip() {
    // the sync-mode pin: the trainer's new pool-field-only event path
    // (pull_field → compute + apply → set_field) must match the pre-PR
    // full-state path (pull_state → cluster_event → set_state)
    // state-for-state and map-for-map, before AND after further training
    let store = store();
    let seed = 3u64;
    let warm = || {
        let mut session = DlrmSession::open(&store, "smoke_cce").unwrap();
        let m = session.manifest.clone();
        let mut rng = Rng::new(seed ^ 0x57A7E);
        session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
        let ix = cce::coordinator::trainer::build_indexer(&m, seed).unwrap();
        let ds = SyntheticDataset::new(store.dataset(&m.dataset, seed).unwrap());
        step_n(&mut session, &ix, &ds, 0, 12);
        (session, ix, ds)
    };
    let (mut sa, mut ixa, dsa) = warm();
    let (mut sb, mut ixb, dsb) = warm();
    assert_eq!(sa.pull_state().unwrap(), sb.pull_state().unwrap(), "warmup diverged");

    let pf = sa.manifest.field("pool").unwrap().clone();
    let cc = ClusterConfig {
        kmeans_iters: 5,
        points_per_centroid: 32,
        seed: 0xC1C,
        n_threads: 0,
    };
    // pre-PR path: full state round trip
    let mut state = sa.pull_state().unwrap();
    cluster_event(&mut state, &pf, &mut ixa, &cc);
    sa.set_state(&state).unwrap();
    // new path: only the pool field crosses the transfer API
    let mut pool = sb.pull_field(&pf).unwrap();
    let computed = compute_cluster(&pool, &ixb, &cc);
    apply_cluster(&mut pool, &mut ixb, computed);
    sb.set_field(&pf, &pool).unwrap();

    assert_eq!(sa.pull_state().unwrap(), sb.pull_state().unwrap(), "post-event state diverged");
    for id in ixa.plan.clone().subtables() {
        assert_eq!(ixa.materialize(id), ixb.materialize(id), "map {id:?} diverged");
    }
    // keep training both on the new maps: behavior must stay identical
    step_n(&mut sa, &ixa, &dsa, 12, 5);
    step_n(&mut sb, &ixb, &dsb, 12, 5);
    assert_eq!(sa.pull_state().unwrap(), sb.pull_state().unwrap(), "post-event training diverged");
}

#[test]
fn event_round_trip_moves_pool_bytes_only() {
    // the tentpole payoff, pinned byte-for-byte: with per-group device
    // buffers a field round trip costs the field's buffer on the wire,
    // never the full state
    let store = store();
    let mut session = DlrmSession::open(&store, "smoke_cce").unwrap();
    let m = session.manifest.clone();
    let full_bytes = m.state_size as u64 * 4;
    let pool_bytes = m.buffer("pool").unwrap().bytes();
    assert!(pool_bytes < full_bytes, "smoke artifact must have a dense share");

    assert_eq!(session.transfer_bytes(), (0, 0), "fresh session has moved nothing");
    let mut rng = Rng::new(0);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
    assert_eq!(session.transfer_bytes(), (0, full_bytes), "set_state uploads each group once");

    let pf = m.field("pool").unwrap().clone();
    let pool = session.pull_field(&pf).unwrap();
    assert_eq!(
        session.transfer_bytes(),
        (pool_bytes, full_bytes),
        "pull_field downloads the pool buffer only"
    );
    session.set_field(&pf, &pool).unwrap();
    assert_eq!(
        session.transfer_bytes(),
        (pool_bytes, full_bytes + pool_bytes),
        "whole-buffer set_field is a pure upload"
    );

    // metrics is a 16-byte buffer download, not a readout execution
    let met = session.metrics().unwrap();
    assert_eq!(met.len(), m.metric_names.len());
    let mb = m.buffer("metrics").unwrap().bytes();
    assert_eq!(session.transfer_bytes(), (pool_bytes + mb, full_bytes + pool_bytes));

    // per-batch train inputs are not state: a step moves no state bytes
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, 0).unwrap());
    let ix = cce::coordinator::trainer::build_indexer(&m, 0).unwrap();
    let before = session.transfer_bytes();
    step_n(&mut session, &ix, &ds, 0, 2);
    assert_eq!(session.transfer_bytes(), before, "train_step must not move state");
}

#[test]
fn train_outcome_reports_pool_only_event_transfer() {
    // synchronous events: exactly 1 pool download + 1 pool upload each —
    // the TrainOutcome accounting the bench and verify.sh gate on
    let store = store();
    let cfg = TrainConfig {
        artifact: "smoke_cce".into(),
        epochs: 1,
        cluster_times: 2,
        cluster_every: 24,
        eval_every: 32,
        ..Default::default()
    };
    let out = train(&store, &cfg).unwrap();
    assert_eq!(out.clusterings_run, 2);
    let m = store.manifest("smoke_cce").unwrap();
    assert_eq!(out.pool_bytes, m.buffer("pool").unwrap().bytes());
    assert!(out.pool_bytes < m.state_size as u64 * 4);
    assert_eq!(out.event_bytes_downloaded, 2 * out.pool_bytes);
    assert_eq!(out.event_bytes_uploaded, 2 * out.pool_bytes);
    assert!(out.bytes_downloaded >= out.event_bytes_downloaded);
    assert!(out.bytes_uploaded >= out.event_bytes_uploaded);
}

#[test]
fn overlapped_event_transfer_stays_pool_bounded() {
    // overlapped events cost at most 2 pool downloads + 1 pool upload
    // each (snapshot pull + apply's pull/patch); an abandoned in-flight
    // event adds its snapshot download but no upload
    let store = store();
    let cfg = TrainConfig {
        artifact: "smoke_cce".into(),
        epochs: 2,
        cluster_times: 2,
        cluster_every: 24,
        eval_every: 32,
        cluster_overlap: true,
        ..Default::default()
    };
    let out = train(&store, &cfg).unwrap();
    let events = 2u64; // snapshots taken, whether or not each one landed
    assert!(out.pool_bytes > 0);
    assert!(
        out.event_bytes_downloaded <= 2 * events * out.pool_bytes,
        "event downloads {} exceed 2 pool pulls per event ({} each)",
        out.event_bytes_downloaded,
        out.pool_bytes
    );
    assert!(
        out.event_bytes_uploaded <= events * out.pool_bytes,
        "event uploads {} exceed 1 pool upload per event ({} each)",
        out.event_bytes_uploaded,
        out.pool_bytes
    );
    assert!(out.event_bytes_downloaded >= out.pool_bytes, "at least one snapshot pull");
}

#[test]
fn overlapped_clustering_trains_and_applies() {
    let store = store();
    let cfg = TrainConfig {
        artifact: "smoke_cce".into(),
        epochs: 2,
        cluster_times: 2,
        cluster_every: 24,
        eval_every: 32,
        cluster_overlap: true,
        ..Default::default()
    };
    let out = train(&store, &cfg).unwrap();
    // normally both events apply mid-run; on a badly loaded host the
    // SECOND event's background compute may outlive training, in which
    // case it is abandoned (superseded by the best checkpoint) and
    // honestly excluded from the applied count — tolerate that instead
    // of flaking. The lower bound assumes the FIRST event (snapshotted
    // ~100 device steps before the end, with a milliseconds-scale smoke
    // compute) always lands; if this ever flakes the host was starved
    // by ~3 orders of magnitude
    assert!(
        (1..=2).contains(&out.clusterings_run),
        "clusterings_run {} out of range",
        out.clusterings_run
    );
    // one staleness record per APPLIED event
    assert_eq!(out.cluster_stale_steps.len(), out.clusterings_run);
    assert!(out.test_bce.is_finite());
    assert!(out.test_bce < 0.75, "test BCE {} after overlapped clustering", out.test_bce);
    // the stall can never exceed the total event wall time
    assert!(
        out.cluster_secs <= out.cluster_event_secs + 1e-9,
        "stall {} > event wall {}",
        out.cluster_secs,
        out.cluster_event_secs
    );
    let m = store.manifest("smoke_cce").unwrap();
    assert!(out.samples_trained > 0);
    assert!(out.samples_trained <= out.steps_run * m.spec.batch);
}

#[test]
fn throughput_counts_real_samples_only() {
    // one full epoch covers the train split exactly once. NOTE: the
    // smoke split divides evenly by the batch size, so the ragged-final-
    // batch case (where the old `steps × batch` accounting overcounted
    // the padded duplicates) cannot be reached through baked artifacts —
    // `prop_batcher_covers_split_exactly_once` pins `Batch::real` on
    // ragged splits at the pipeline level; this test pins the trainer's
    // wiring of that count (no eval/padding inflation, exact coverage
    // across epochs)
    let store = store();
    let ds = store.dataset("smoke", 0).unwrap();
    let out = train(&store, &smoke_cfg("smoke_cce")).unwrap();
    assert_eq!(out.samples_trained, ds.train_samples);
    let two = train(&store, &TrainConfig { epochs: 2, ..smoke_cfg("smoke_cce") }).unwrap();
    assert_eq!(two.samples_trained, 2 * ds.train_samples);
    let m = store.manifest("smoke_cce").unwrap();
    assert!(out.samples_trained <= out.steps_run * m.spec.batch);
    assert!(out.train_secs >= 0.0, "train_secs clamped at 0, got {}", out.train_secs);
}

#[test]
fn clustering_event_mid_training_works_end_to_end() {
    let store = store();
    let cfg = TrainConfig {
        artifact: "smoke_cce".into(),
        epochs: 2,
        cluster_times: 2,
        cluster_every: 24,
        eval_every: 32,
        ..Default::default()
    };
    let out = train(&store, &cfg).unwrap();
    assert_eq!(out.clusterings_run, 2);
    assert!(out.test_bce.is_finite());
    // clustering must not destroy the model: test BCE stays below chance
    assert!(out.test_bce < 0.75, "test BCE {} after clustering", out.test_bce);
}

#[test]
fn clustering_improves_over_no_clustering_on_structured_data() {
    // the headline CCE claim at smoke scale: same budget, clustering helps
    // (or at least does not hurt) after enough epochs
    let store = store();
    let base = TrainConfig {
        artifact: "smoke_cce".into(),
        epochs: 3,
        eval_every: 32,
        ..Default::default()
    };
    let with = train(&store, &TrainConfig { cluster_times: 2, ..base.clone() }).unwrap();
    let without = train(&store, &TrainConfig { cluster_times: 0, ..base }).unwrap();
    assert!(
        with.test_bce <= without.test_bce + 0.02,
        "clustering hurt badly: with {} vs without {}",
        with.test_bce,
        without.test_bce
    );
}

#[test]
fn robe_and_dhe_artifacts_train() {
    let store = store();
    for artifact in ["smoke_robe", "smoke_dhe", "smoke_hash"] {
        let out = train(&store, &smoke_cfg(artifact)).unwrap();
        assert!(out.test_bce.is_finite(), "{artifact}");
        assert!(out.test_bce < 0.8, "{artifact}: BCE {}", out.test_bce);
    }
}

#[test]
fn kmeans_hlo_artifact_matches_rust() {
    let store = store();
    let m = store.manifest("kmeans_smoke").unwrap();
    let exe = store.compile(&m, "step").unwrap();
    let n = m.inputs["step"][0].shape[0];
    let d = m.inputs["step"][0].shape[1];
    let k = m.inputs["step"][1].shape[0];
    let mut rng = Rng::new(5);
    let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let cen: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
    let out = cce::runtime::with_client(|c| {
        let pb = c.buffer_from_host_buffer(&pts, &[n, d], None)?;
        let cb = c.buffer_from_host_buffer(&cen, &[k, d], None)?;
        let outs = exe.execute_b(&[&pb, &cb])?;
        Ok(outs[0][0].to_literal_sync()?.to_vec::<f32>()?)
    })
    .unwrap();
    // rust reference Lloyd step
    let mut asg = vec![0u32; n];
    cce::kmeans::assign(&pts, &cen, d, &mut asg);
    let mut sums = vec![0f64; k * d];
    let mut counts = vec![0f64; k];
    for i in 0..n {
        let j = asg[i] as usize;
        counts[j] += 1.0;
        for e in 0..d {
            sums[j * d + e] += pts[i * d + e] as f64;
        }
    }
    for j in 0..k {
        for e in 0..d {
            let want = if counts[j] > 0.0 {
                (sums[j * d + e] / counts[j]) as f32
            } else {
                cen[j * d + e]
            };
            let got = out[j * (d + 1) + e];
            assert!((got - want).abs() < 1e-3, "centroid ({j},{e}): {got} vs {want}");
        }
        let got_count = out[j * (d + 1) + d];
        assert!((got_count - counts[j] as f32).abs() < 0.5, "count {j}");
    }
}

#[test]
fn serve_loop_reports_sane_numbers() {
    let store = store();
    let mut session = DlrmSession::open(&store, "smoke_cce").unwrap();
    let m = session.manifest.clone();
    let mut rng = Rng::new(0);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng)).unwrap();
    let ds = SyntheticDataset::new(store.dataset("smoke", 0).unwrap());
    let ix = cce::coordinator::trainer::build_indexer(&m, 0).unwrap();
    let cfg = cce::config::ServeConfig {
        requests: 500,
        max_batch: 128,
        workers: 4,
        ..Default::default()
    };
    let rep = cce::coordinator::serve::serve(&session, &ix, &ds, &cfg).unwrap();
    assert_eq!(rep.requests, 500);
    assert!(rep.throughput_rps > 0.0);
    assert!(rep.latency.p99_ns >= rep.latency.p95_ns);
    assert!(rep.latency.p95_ns >= rep.latency.p50_ns);
    assert!(rep.queue_wait.p50_ns <= rep.latency.p50_ns);
    assert!(rep.snapshot_bytes > 0);
    // pure indexer bake: the maps are baked host-side, no device transfer
    assert_eq!(rep.bake_transfer_bytes, 0);
}

#[test]
fn pq_quantized_full_model_still_predicts() {
    let store = store();
    // smoke_hash is t=1, c=1 — a valid PQ substrate shape-wise when cap
    // covers the whole vocab is not available in smoke; quantize anyway on
    // the hash pool to exercise the write-back path with the plan it has
    let mut session = DlrmSession::open(&store, "smoke_hash").unwrap();
    let m = session.manifest.clone();
    let mut rng = Rng::new(1);
    let mut state = init_state(&m.layout, m.state_size, &mut rng);
    let plan = TablePlan::new(
        &m.vocabs.iter().map(|&v| v.min(m.spec.cap)).collect::<Vec<_>>(),
        usize::MAX,
        1,
        1,
        m.spec.dc,
    );
    let pool = m.field("pool").unwrap().clone();
    let rep = cce::baselines::pq::pq_quantize_pool(&mut state, &pool, &plan, 4, 2, 10, 0);
    assert!(rep.compression() > 1.0);
    session.set_state(&state).unwrap();
    let ds = SyntheticDataset::new(store.dataset("smoke", 0).unwrap());
    let ix = cce::coordinator::trainer::build_indexer(&m, 0).unwrap();
    let acc = cce::coordinator::eval::evaluate(&session, &ix, &ds, Split::Test).unwrap();
    assert!(acc.bce().is_finite());
}
