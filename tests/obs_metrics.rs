//! Cross-checks between the serving engine's own per-run counters (the
//! `ServeReport` contract) and the process-global metrics registry the
//! same source sites mirror into (`rust/src/obs/`, docs/OBSERVABILITY.md).
//!
//! These run in their own integration binary on purpose: the registry is
//! process-global, and the lib-test process runs dozens of engine tests
//! concurrently whose increments would contaminate any before/after delta
//! taken there. Here the only registry writers are the tests below, which
//! additionally serialize themselves through `OBS_LOCK`.

use cce::data::synthetic::{DatasetSpec, SyntheticDataset};
use cce::serving::batcher::{AdmissionPolicy, TrafficGen};
use cce::serving::engine::{
    self, CountingExecutor, EngineConfig, Executor, PreparedBatch, ServeReport, SnapshotSlot,
};
use cce::serving::ServingSnapshot;
use cce::tables::indexer::Indexer;
use cce::tables::layout::TablePlan;
use cce::util::Rng;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::sync::Mutex;
use std::time::Duration;

/// Serializes the tests in this binary: each takes before/after snapshots
/// of the process-global registry, so they must not interleave.
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn dataset() -> SyntheticDataset {
    SyntheticDataset::new(DatasetSpec {
        name: "obs".into(),
        vocabs: vec![11, 50],
        n_dense: 3,
        train_samples: 40,
        val_samples: 8,
        test_samples: 32,
        latent_clusters: 4,
        zipf_exponent: 1.05,
        label_noise: 0.0,
        seed: 1,
    })
}

fn snapshot(seed: u64) -> ServingSnapshot {
    let mut rng = Rng::new(seed);
    let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[11, 50], 8, 2, 2, 4));
    ServingSnapshot::bake(&ix)
}

/// A [`CountingExecutor`] that also sleeps per batch: backs the queue up so
/// shed-mode runs actually reject/expire, and stretches runs long enough to
/// scrape them live.
struct SlowExecutor {
    inner: CountingExecutor,
    delay: Duration,
}

impl Executor for SlowExecutor {
    fn device_batch(&self) -> usize {
        self.inner.device_batch()
    }
    fn execute(&mut self, batch: &PreparedBatch) -> Result<(), anyhow::Error> {
        std::thread::sleep(self.delay);
        self.inner.execute(batch)
    }
}

fn counters() -> BTreeMap<String, u64> {
    cce::obs::registry().counter_values()
}

fn delta(before: &BTreeMap<String, u64>, after: &BTreeMap<String, u64>, name: &str) -> u64 {
    after.get(name).copied().unwrap_or(0) - before.get(name).copied().unwrap_or(0)
}

/// Registry deltas across one engine run must equal the run's own report —
/// the two are incremented at the same source sites, and this test is what
/// keeps them from drifting apart.
fn assert_report_matches_registry(
    rep: &ServeReport,
    before: &BTreeMap<String, u64>,
    after: &BTreeMap<String, u64>,
) {
    assert_eq!(delta(before, after, "serve.requests.offered"), rep.offered as u64);
    assert_eq!(delta(before, after, "serve.requests.served"), rep.requests as u64);
    assert_eq!(delta(before, after, "serve.requests.rejected"), rep.rejected as u64);
    assert_eq!(delta(before, after, "serve.requests.expired"), rep.expired as u64);
    assert_eq!(delta(before, after, "serve.batches"), rep.batches as u64);
    assert_eq!(delta(before, after, "serve.padded_rows"), rep.padded_rows as u64);
    assert_eq!(delta(before, after, "serve.deadline_misses"), rep.deadline_misses as u64);
    // conservation, stated on the REGISTRY numbers: nothing offered is lost
    assert_eq!(
        delta(before, after, "serve.requests.served")
            + delta(before, after, "serve.requests.rejected")
            + delta(before, after, "serve.requests.expired"),
        delta(before, after, "serve.requests.offered"),
        "served + rejected + expired must equal offered"
    );
}

#[test]
fn block_mode_registry_deltas_match_report() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ds = dataset();
    let slot = SnapshotSlot::new(snapshot(0));
    let mut exec = CountingExecutor::new(16);
    let cfg = EngineConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_millis(1),
        queue_depth: 64,
        admission: AdmissionPolicy::Block,
        pace: None,
    };
    let lat_before = cce::obs::registry().histogram("serve.latency.ns").snapshot();
    let before = counters();
    let rep = engine::run(&mut exec, &slot, TrafficGen::new(&ds, 0.99, 31), &cfg, 500).unwrap();
    let after = counters();
    assert_eq!(rep.offered, 500);
    assert_eq!(rep.requests, 500, "block mode serves everything offered");
    assert_report_matches_registry(&rep, &before, &after);
    // the latency histogram saw exactly one sample per served request
    let lat_after = cce::obs::registry().histogram("serve.latency.ns").snapshot();
    assert_eq!(lat_after.count - lat_before.count, rep.requests as u64);
}

#[test]
fn shed_mode_conserves_and_matches_report() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let ds = dataset();
    let slot = SnapshotSlot::new(snapshot(0));
    // slow device + tiny queue + per-request deadline: forces both shed
    // paths (admission rejects and in-queue expiries)
    let mut exec =
        SlowExecutor { inner: CountingExecutor::new(16), delay: Duration::from_micros(400) };
    let cfg = EngineConfig {
        workers: 2,
        max_batch: 8,
        max_wait: Duration::from_micros(50),
        queue_depth: 4,
        admission: AdmissionPolicy::Shed {
            queue_depth: 4,
            deadline: Some(Duration::from_micros(300)),
        },
        pace: None,
    };
    let before = counters();
    let rep = engine::run(&mut exec, &slot, TrafficGen::new(&ds, 0.99, 31), &cfg, 800).unwrap();
    let after = counters();
    assert_eq!(rep.offered, 800);
    assert!(
        rep.rejected + rep.expired > 0,
        "overload scenario must actually shed (rejected {}, expired {})",
        rep.rejected,
        rep.expired
    );
    assert_report_matches_registry(&rep, &before, &after);
}

fn http_get(addr: std::net::SocketAddr, path: &str) -> (u16, String) {
    let mut s = std::net::TcpStream::connect(addr).expect("connect to metrics endpoint");
    write!(s, "GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    let status: u16 =
        buf.split_whitespace().nth(1).and_then(|c| c.parse().ok()).expect("HTTP status line");
    let body = buf.split_once("\r\n\r\n").map(|(_, b)| b.to_string()).unwrap_or_default();
    (status, body)
}

/// End to end over the wire: while an engine run is in flight, a scrape of
/// the live `/metrics` endpoint returns Prometheus text whose counters come
/// from THIS run; after the run, the final scrape agrees with the report.
#[test]
fn live_scrape_during_engine_run() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let server = cce::obs::MetricsServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr;
    let ds = dataset();
    let slot = SnapshotSlot::new(snapshot(0));
    let before = counters();
    let (rep, mid_body) = std::thread::scope(|s| {
        let handle = s.spawn(|| {
            let mut exec =
                SlowExecutor { inner: CountingExecutor::new(16), delay: Duration::from_micros(500) };
            let cfg = EngineConfig {
                workers: 2,
                max_batch: 8,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                admission: AdmissionPolicy::Block,
                pace: None,
            };
            engine::run(&mut exec, &slot, TrafficGen::new(&ds, 0.99, 31), &cfg, 1000).unwrap()
        });
        // scrape mid-run: the endpoint must answer while the engine works
        let mut mid = String::new();
        while !handle.is_finished() {
            let (status, body) = http_get(addr, "/metrics");
            assert_eq!(status, 200);
            if body.contains("cce_serve_requests_offered") {
                mid = body;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        (handle.join().unwrap(), mid)
    });
    assert!(
        mid_body.contains("cce_serve_requests_offered"),
        "a mid-run scrape never saw the engine's counters"
    );
    let after = counters();
    assert_report_matches_registry(&rep, &before, &after);
    // the final scrape carries the same totals the registry reports
    let (status, body) = http_get(addr, "/metrics");
    assert_eq!(status, 200);
    let total = after.get("serve.requests.offered").copied().unwrap_or(0);
    assert!(
        body.contains(&format!("cce_serve_requests_offered {total}")),
        "scrape disagrees with the registry: wanted offered={total}"
    );
    // unknown paths 404 without killing the server
    let (status, _) = http_get(addr, "/nope");
    assert_eq!(status, 404);
    server.stop();
}
