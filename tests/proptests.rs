//! Property tests over coordinator invariants (own mini-framework in
//! `cce::testutil::prop`; proptest is unavailable offline).

use cce::coordinator::cluster::{cluster_event, ClusterConfig};
use cce::data::batch::{BatchIter, Split};
use cce::data::synthetic::{DatasetSpec, SyntheticDataset};
use cce::kmeans;
use cce::metrics::extrapolate::{params_to_reach, Crossing, SweepPoint};
use cce::runtime::manifest::{FieldDesc, InitSpec};
use cce::serving::{
    load_segment, load_segment_verified, write_segment, BatchQueue, ServingSnapshot, TryPush,
};
use cce::tables::indexer::Indexer;
use cce::tables::layout::{SubtableId, TablePlan};
use cce::testutil::prop;
use cce::util::Rng;

#[test]
fn prop_rowwise_indices_always_in_their_subtable() {
    prop::check(60, |g| {
        let n_features = g.usize(1..5);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(1..500)).collect();
        let cap = g.usize(1..64);
        let t = g.usize(1..3);
        let c = *g.pick(&[1usize, 2, 4]);
        let plan = TablePlan::new(&vocabs, cap, t, c, 4);
        let mut rng = Rng::new(g.u64());
        let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
        // randomly learn some maps
        for f in 0..n_features {
            if g.bool() && vocabs[f] > plan.k[f] {
                let assignments = g.vec_u32(vocabs[f], plan.k[f] as u32);
                ix.set_learned(SubtableId { feature: f, term: 0, column: 0 }, assignments);
            }
        }
        let batch = g.usize(1..16);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut out = vec![0i32; batch * n_features * t * c];
        ix.fill_rowwise(&cats, batch, &mut out);
        let mut o = 0;
        for b in 0..batch {
            for f in 0..n_features {
                for tt in 0..t {
                    for j in 0..c {
                        let id = SubtableId { feature: f, term: tt, column: j };
                        let base = plan.subtable_base(id) as i32;
                        let rows = plan.subtable_rows(f) as i32;
                        let v = out[o];
                        prop::prop_assert!(
                            g,
                            v >= base && v < base + rows,
                            "row {v} outside subtable [{base}, {}) b={b} f={f}",
                            base + rows
                        );
                        o += 1;
                    }
                }
            }
        }
    });
}

#[test]
fn prop_snapshot_rowwise_bit_identical_to_live_indexer() {
    // the serving contract: a baked snapshot's gather must reproduce
    // `Indexer::fill_rowwise` bit-for-bit across random plans, map mixes
    // (identity / random hash / learned), and mid-run clustering events
    prop::check(60, |g| {
        let n_features = g.usize(1..5);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(1..500)).collect();
        let cap = g.usize(1..64);
        let t = g.usize(1..3);
        let c = *g.pick(&[1usize, 2, 4]);
        let plan = TablePlan::new(&vocabs, cap, t, c, 4);
        let mut rng = Rng::new(g.u64());
        let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
        // a random number of clustering events, each rewriting a random
        // subtable: term-0 columns get learned maps, term-1 fresh hashes
        for _ in 0..g.usize(0..6) {
            let f = g.usize(0..n_features);
            let tt = g.usize(0..t);
            let j = g.usize(0..c);
            let id = SubtableId { feature: f, term: tt, column: j };
            if g.bool() {
                ix.set_learned(id, g.vec_u32(vocabs[f], plan.k[f] as u32));
            } else {
                ix.set_random(id, &mut rng);
            }
        }
        let snap = ServingSnapshot::bake(&ix);
        let batch = g.usize(1..16);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0i32; batch * n_features * t * c];
        let mut baked = vec![0i32; batch * n_features * t * c];
        ix.fill_rowwise(&cats, batch, &mut live);
        snap.fill_rowwise(&cats, batch, &mut baked);
        prop::prop_assert!(g, live == baked, "rowwise snapshot diverged from live indexer");
    });
}

#[test]
fn prop_snapshot_robe_bit_identical_to_live_indexer() {
    prop::check(40, |g| {
        let n_features = g.usize(1..4);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(2..300)).collect();
        let cap = g.usize(2..100);
        let c = *g.pick(&[1usize, 2, 4]);
        let dc = g.usize(1..5);
        let dim = c * dc;
        let mut rng = Rng::new(g.u64());
        let ix = Indexer::new_robe(&mut rng, &vocabs, cap, dim, c);
        let snap = ServingSnapshot::bake(&ix);
        let batch = g.usize(1..12);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0i32; batch * n_features * dim];
        let mut baked = vec![0i32; batch * n_features * dim];
        ix.fill_elementwise(&cats, batch, &mut live);
        snap.fill_elementwise(&cats, batch, &mut baked);
        prop::prop_assert!(g, live == baked, "robe snapshot diverged from live indexer");
    });
}

#[test]
fn prop_snapshot_dhe_bit_identical_to_live_indexer() {
    prop::check(40, |g| {
        let n_features = g.usize(1..4);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(1..400)).collect();
        let n_hash = g.usize(1..32);
        let mut rng = Rng::new(g.u64());
        let ix = Indexer::new_dhe(&mut rng, &vocabs, n_hash);
        let snap = ServingSnapshot::bake(&ix);
        let batch = g.usize(1..12);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0f32; batch * n_features * n_hash];
        let mut baked = vec![0f32; batch * n_features * n_hash];
        ix.fill_dhe(&cats, batch, &mut live);
        snap.fill_dhe(&cats, batch, &mut baked);
        // f32 equality is intentional: the baked table stores the hasher's
        // exact output bits
        prop::prop_assert!(g, live == baked, "dhe snapshot diverged from live indexer");
    });
}

/// Unique temp path per iteration so parallel test binaries never collide.
fn tmp_seg(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static N: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "cce_prop_{}_{tag}_{}.cceseg",
        std::process::id(),
        // ORDERING: Relaxed — only uniqueness of the ticket matters
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

#[test]
fn prop_segment_roundtrip_rowwise_bit_identical() {
    // the persistence contract: bake → write_segment → load_segment must
    // reproduce the live indexer's fill bit-for-bit, across random plans
    // and map mixes, through the checksummed on-disk format
    prop::check(30, |g| {
        let n_features = g.usize(1..5);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(1..300)).collect();
        let cap = g.usize(1..64);
        let t = g.usize(1..3);
        let c = *g.pick(&[1usize, 2, 4]);
        let plan = TablePlan::new(&vocabs, cap, t, c, 4);
        let mut rng = Rng::new(g.u64());
        let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
        for _ in 0..g.usize(0..5) {
            let f = g.usize(0..n_features);
            let id = SubtableId { feature: f, term: g.usize(0..t), column: g.usize(0..c) };
            if g.bool() {
                ix.set_learned(id, g.vec_u32(vocabs[f], plan.k[f] as u32));
            } else {
                ix.set_random(id, &mut rng);
            }
        }
        let snap = ServingSnapshot::bake(&ix);
        let generation = g.u64();
        let path = tmp_seg("rowwise");
        write_segment(&snap, generation, &path).expect("write");
        // quick load serves; verified load must agree on an intact file
        let loaded = load_segment(&path).expect("load");
        load_segment_verified(&path).expect("verified load of intact file");
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded.generation, generation);
        let batch = g.usize(1..12);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0i32; batch * n_features * t * c];
        let mut mapped = vec![0i32; batch * n_features * t * c];
        ix.fill_rowwise(&cats, batch, &mut live);
        loaded.snapshot.fill_rowwise(&cats, batch, &mut mapped);
        prop::prop_assert!(g, live == mapped, "loaded segment diverged from live indexer");
    });
}

#[test]
fn prop_segment_roundtrip_robe_bit_identical() {
    prop::check(25, |g| {
        let n_features = g.usize(1..4);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(2..300)).collect();
        let cap = g.usize(2..100);
        let c = *g.pick(&[1usize, 2, 4]);
        let dim = c * g.usize(1..5);
        let mut rng = Rng::new(g.u64());
        let ix = Indexer::new_robe(&mut rng, &vocabs, cap, dim, c);
        let snap = ServingSnapshot::bake(&ix);
        let path = tmp_seg("robe");
        write_segment(&snap, 3, &path).expect("write");
        let loaded = load_segment(&path).expect("load");
        std::fs::remove_file(&path).ok();
        let batch = g.usize(1..12);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0i32; batch * n_features * dim];
        let mut mapped = vec![0i32; batch * n_features * dim];
        ix.fill_elementwise(&cats, batch, &mut live);
        loaded.snapshot.fill_elementwise(&cats, batch, &mut mapped);
        prop::prop_assert!(g, live == mapped, "loaded robe segment diverged from live indexer");
    });
}

#[test]
fn prop_segment_roundtrip_dhe_bit_identical_in_both_modes() {
    // DHE segments carry either a baked hash table (small vocabs) or the
    // hasher seeds for live fallback (capped bake) — both must survive the
    // disk round trip bit-for-bit
    prop::check(20, |g| {
        let n_features = g.usize(1..4);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(1..300)).collect();
        let n_hash = g.usize(1..24);
        let mut rng = Rng::new(g.u64());
        let ix = Indexer::new_dhe(&mut rng, &vocabs, n_hash);
        // cap 0 forces the live-fallback path (seeds only, no baked table)
        let live_fallback = g.bool();
        let cap = if live_fallback { 0 } else { usize::MAX };
        let snap = ServingSnapshot::bake_with_dhe_cap(&ix, cap);
        let path = tmp_seg("dhe");
        write_segment(&snap, 7, &path).expect("write");
        let loaded = load_segment(&path).expect("load");
        std::fs::remove_file(&path).ok();
        let batch = g.usize(1..10);
        let cats: Vec<u32> = (0..batch * n_features)
            .map(|i| g.u32(0..vocabs[i % n_features] as u32))
            .collect();
        let mut live = vec![0f32; batch * n_features * n_hash];
        let mut mapped = vec![0f32; batch * n_features * n_hash];
        ix.fill_dhe(&cats, batch, &mut live);
        loaded.snapshot.fill_dhe(&cats, batch, &mut mapped);
        prop::prop_assert!(
            g,
            live == mapped,
            "loaded dhe segment (live_fallback={live_fallback}) diverged from live indexer"
        );
    });
}

#[test]
fn prop_segment_rejects_random_corruption() {
    // flipping any byte inside a non-empty section must fail the verified
    // load; truncating the file anywhere must fail even the quick load
    prop::check(20, |g| {
        let vocabs: Vec<usize> = (0..g.usize(1..3)).map(|_| g.usize(2..100)).collect();
        let plan = TablePlan::new(&vocabs, g.usize(1..32), 2, 2, 4);
        let mut rng = Rng::new(g.u64());
        let ix = Indexer::new_rowwise(&mut rng, plan);
        let snap = ServingSnapshot::bake(&ix);
        let path = tmp_seg("corrupt");
        write_segment(&snap, 0, &path).expect("write");
        let bytes = std::fs::read(&path).expect("read back");

        // corrupt one byte of the rows section (always non-empty for
        // rowwise) — offsets live in the header's section table at byte 88,
        // entry 1 (rows), fields offset/len as u64 LE
        let sec = 88 + 24; // SEC_ROWS descriptor
        let off = u64::from_le_bytes(bytes[sec..sec + 8].try_into().unwrap()) as usize;
        let len = u64::from_le_bytes(bytes[sec + 8..sec + 16].try_into().unwrap()) as usize;
        assert!(len > 0, "rowwise segment must have a rows section");
        let mut corrupt = bytes.clone();
        corrupt[off + g.usize(0..len)] ^= 1 << g.usize(0..8);
        std::fs::write(&path, &corrupt).expect("write corrupt");
        prop::prop_assert!(
            g,
            load_segment_verified(&path).is_err(),
            "verified load accepted a corrupted rows section"
        );

        // truncate to a random shorter length: even quick loads must fail
        let cut = g.usize(0..bytes.len());
        std::fs::write(&path, &bytes[..cut]).expect("write truncated");
        prop::prop_assert!(
            g,
            load_segment(&path).is_err(),
            "quick load accepted a truncated file ({cut} of {} bytes)",
            bytes.len()
        );
        std::fs::remove_file(&path).ok();
    });
}

#[test]
fn prop_batch_queue_shutdown_races_conserve_every_request() {
    // the admission-control conservation contract under shutdown races:
    // across random producer/consumer splits, capacities, admission modes
    // (blocking push vs non-blocking try_push) and close() timing — including
    // close landing while producers are blocked on a full queue and while
    // sibling consumers race to drain — every accepted item is dispatched to
    // exactly one batch. Nothing lost, nothing double-dispatched.
    prop::check(20, |g| {
        let producers = g.usize(1..5);
        let consumers = g.usize(1..4);
        let cap = g.usize(1..9);
        let per_producer = g.usize(1..60);
        let max_batch = g.usize(1..17);
        let use_try = g.bool();
        let close_early = g.bool();
        let close_after_us = g.usize(0..400) as u64;
        let q: BatchQueue<usize> = BatchQueue::new(cap);
        let (mut accepted, mut drained) = std::thread::scope(|s| {
            let q = &q;
            let prod: Vec<_> = (0..producers)
                .map(|p| {
                    s.spawn(move || {
                        let mut acc = Vec::new();
                        for i in 0..per_producer {
                            let item = p * 100_000 + i;
                            if use_try {
                                match q.try_push(item) {
                                    TryPush::Pushed => acc.push(item),
                                    TryPush::Full(_) => {} // shed at the edge
                                    TryPush::Closed(_) => break,
                                }
                            } else if q.push(item) {
                                acc.push(item);
                            } else {
                                break; // closed while blocked
                            }
                        }
                        acc
                    })
                })
                .collect();
            let cons: Vec<_> = (0..consumers)
                .map(|_| {
                    s.spawn(move || {
                        let mut got = Vec::new();
                        while let Some(b) =
                            q.pop_batch(max_batch, std::time::Duration::from_micros(50))
                        {
                            assert!(!b.is_empty(), "pop_batch dispatched an empty batch");
                            got.extend(b);
                        }
                        got
                    })
                })
                .collect();
            if close_early {
                // let close land mid-flight, possibly with producers blocked
                std::thread::sleep(std::time::Duration::from_micros(close_after_us));
                q.close();
            }
            let accepted: Vec<usize> =
                prod.into_iter().flat_map(|h| h.join().unwrap()).collect();
            if !close_early {
                q.close();
            }
            let drained: Vec<usize> =
                cons.into_iter().flat_map(|h| h.join().unwrap()).collect();
            (accepted, drained)
        });
        accepted.sort_unstable();
        drained.sort_unstable();
        prop::prop_assert!(
            g,
            accepted == drained,
            "accepted {} != drained {} (producers={producers} consumers={consumers} \
             cap={cap} try={use_try} close_early={close_early})",
            accepted.len(),
            drained.len()
        );
    });
}

#[test]
fn prop_plan_rows_equal_sum_of_subtables() {
    prop::check(100, |g| {
        let n = g.usize(1..8);
        let vocabs: Vec<usize> = (0..n).map(|_| g.usize(1..100_000)).collect();
        let cap = g.usize(1..20_000);
        let t = g.usize(1..4);
        let c = g.usize(1..5);
        let plan = TablePlan::new(&vocabs, cap, t, c, 4);
        let total: usize = plan.subtables().map(|id| plan.subtable_rows(id.feature)).sum();
        assert_eq!(total, plan.total_rows);
        // mirror of specs.rows_for
        let formula: usize = vocabs.iter().map(|&v| t * c * v.min(cap)).sum();
        assert_eq!(formula, plan.total_rows);
    });
}

#[test]
fn prop_batcher_covers_split_exactly_once() {
    prop::check(25, |g| {
        let train = g.usize(1..400);
        let batch = g.usize(1..40);
        let ds = SyntheticDataset::new(DatasetSpec {
            name: "p".into(),
            vocabs: vec![7, 19],
            n_dense: 2,
            train_samples: train,
            val_samples: 3,
            test_samples: 3,
            latent_clusters: 2,
            zipf_exponent: 1.05,
            label_noise: 0.0,
            seed: g.u64(),
        });
        let shuffle = g.bool().then(|| g.u64());
        let mut it = BatchIter::new(&ds, Split::Train, batch, shuffle);
        let mut b = it.alloc_batch();
        let mut total = 0usize;
        let mut batches = 0usize;
        while it.next_into(&mut b) {
            prop::prop_assert!(g, b.real >= 1 && b.real <= batch, "real {}", b.real);
            total += b.real;
            batches += 1;
        }
        assert_eq!(total, train, "sample coverage");
        assert_eq!(batches, train.div_ceil(batch), "batch count");
    });
}

#[test]
fn prop_kmeans_assignment_is_nearest_brute_force() {
    prop::check(40, |g| {
        let n = g.usize(2..120);
        let d = g.usize(1..6);
        let k = g.usize(1..10);
        let pts = g.vec_f32(n * d, -3.0..3.0);
        let cen = g.vec_f32(k * d, -3.0..3.0);
        let mut asg = vec![0u32; n];
        kmeans::assign(&pts, &cen, d, &mut asg);
        for i in 0..n {
            let dist = |j: usize| -> f64 {
                (0..d)
                    .map(|e| (pts[i * d + e] as f64 - cen[j * d + e] as f64).powi(2))
                    .sum()
            };
            let best = (0..k)
                .min_by(|&a, &b| dist(a).partial_cmp(&dist(b)).unwrap())
                .unwrap();
            // allow exact ties
            prop::prop_assert!(
                g,
                (dist(asg[i] as usize) - dist(best)).abs() < 1e-9,
                "point {i}: assigned {} (d={}), best {best} (d={})",
                asg[i],
                dist(asg[i] as usize),
                dist(best)
            );
        }
    });
}

/// Independent scalar re-implementation of the K-means algorithm contract
/// (subsample → kmeans++ → Lloyd → full assignment), used to pin the
/// fused/parallel production path bit-for-bit. Two pieces are shared with
/// production on purpose: the `AssignStage::nearest` distance kernel
/// (whose arithmetic is separately pinned against brute force, with
/// tie tolerance, by `prop_kmeans_assignment_is_nearest_brute_force` —
/// re-deriving it naively here would make bit-comparisons flake on
/// rounding-induced argmin flips) and `kmeans::inertia` (already
/// thread-count-invariant by its fixed chunk tree). Everything the perf
/// rework restructured — the fusion of assignment with centroid
/// accumulation, the `ACC_CHUNK` partial-merge order, the cached-distance
/// empty-cluster repair, the chunk-tree kmeans++ weighting and two-level
/// pick — is re-implemented serially below.
fn kmeans_scalar_reference(points: &[f32], d: usize, cfg: &kmeans::KmeansConfig) -> KmRef {
    use cce::kmeans::{AssignStage, ACC_CHUNK, ASSIGN_BLOCK};
    let n = points.len() / d;
    let k = cfg.k.min(n);
    let mut rng = Rng::new(cfg.seed);
    // subsample (FAISS rule)
    let budget = cfg.max_points_per_centroid.max(1) * k;
    let sub_owned: Vec<f32>;
    let sub: &[f32] = if n > budget {
        let idx = rng.sample_indices(n, budget);
        let mut buf = Vec::with_capacity(budget * d);
        for &i in &idx {
            buf.extend_from_slice(&points[i * d..(i + 1) * d]);
        }
        sub_owned = buf;
        &sub_owned
    } else {
        points
    };
    let sn = sub.len() / d;
    let n_chunks = sn.div_ceil(ACC_CHUNK);
    let chunk = |ci: usize| (ci * ACC_CHUNK, ((ci + 1) * ACC_CHUNK).min(sn));
    // kmeans++ seeding with chunk-tree weight sums and two-level pick
    let mut centroids = vec![0f32; k * d];
    let first = rng.below(sn as u64) as usize;
    centroids[..d].copy_from_slice(&sub[first * d..(first + 1) * d]);
    let mut min_d2 = vec![f32::INFINITY; sn];
    let mut partials = vec![0f64; n_chunks];
    for j in 1..k {
        let c: Vec<f32> = centroids[(j - 1) * d..j * d].to_vec();
        for (ci, partial) in partials.iter_mut().enumerate() {
            let (s, e) = chunk(ci);
            let mut acc = 0f64;
            for i in s..e {
                let x = &sub[i * d..(i + 1) * d];
                let mut s2 = 0f32;
                for e2 in 0..d {
                    let diff = x[e2] - c[e2];
                    s2 += diff * diff;
                }
                if s2 < min_d2[i] {
                    min_d2[i] = s2;
                }
                acc += min_d2[i] as f64;
            }
            *partial = acc;
        }
        let total: f64 = partials.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(sn as u64) as usize
        } else {
            let mut target = rng.uniform() * total;
            let mut pick = sn - 1;
            for (ci, &p) in partials.iter().enumerate() {
                if target > p {
                    target -= p;
                    continue;
                }
                let (s, e) = chunk(ci);
                pick = e - 1;
                for (i, &w) in min_d2[s..e].iter().enumerate() {
                    target -= w as f64;
                    if target <= 0.0 {
                        pick = s + i;
                        break;
                    }
                }
                break;
            }
            pick
        };
        centroids[j * d..(j + 1) * d].copy_from_slice(&sub[pick * d..(pick + 1) * d]);
    }
    // Lloyd: chunked accumulation merged in chunk order, cached-d2 repair
    let mut asg = vec![0u32; sn];
    let mut d2 = vec![0f32; sn];
    let mut dist = [0f32; ASSIGN_BLOCK];
    let mut prev = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..cfg.n_iter {
        iterations = it + 1;
        let stage = AssignStage::new(&centroids, d);
        let mut sums = vec![0f64; k * d];
        let mut counts = vec![0u64; k];
        for ci in 0..n_chunks {
            let (s, e) = chunk(ci);
            let mut csums = vec![0f64; k * d];
            let mut ccounts = vec![0u64; k];
            for i in s..e {
                let x = &sub[i * d..(i + 1) * d];
                let (best, dd) = stage.nearest(x, &mut dist);
                asg[i] = best;
                d2[i] = dd;
                ccounts[best as usize] += 1;
                for e2 in 0..d {
                    csums[best as usize * d + e2] += x[e2] as f64;
                }
            }
            for (a, b) in counts.iter_mut().zip(&ccounts) {
                *a += b;
            }
            for (a, b) in sums.iter_mut().zip(&csums) {
                *a += b;
            }
        }
        for j in 0..k {
            if counts[j] == 0 {
                // cached-d2 repair: last-max scan, then consume the used
                // point so the next empty cluster reseeds differently
                let mut far = 0;
                for (i, &dd) in d2.iter().enumerate() {
                    if dd >= d2[far] {
                        far = i;
                    }
                }
                centroids[j * d..(j + 1) * d].copy_from_slice(&sub[far * d..(far + 1) * d]);
                d2[far] = 0.0;
            } else {
                for e2 in 0..d {
                    centroids[j * d + e2] = (sums[j * d + e2] / counts[j] as f64) as f32;
                }
            }
        }
        let cur = kmeans::inertia(sub, &centroids, d, &asg);
        if prev.is_finite() && (prev - cur) <= cfg.tol * prev.abs() {
            break;
        }
        prev = cur;
    }
    // final assignment over all input points
    let stage = AssignStage::new(&centroids, d);
    let mut assignments = vec![0u32; n];
    for (i, slot) in assignments.iter_mut().enumerate() {
        *slot = stage.nearest(&points[i * d..(i + 1) * d], &mut dist).0;
    }
    let inertia = kmeans::inertia(points, &centroids, d, &assignments);
    KmRef { centroids, assignments, inertia, iterations }
}

struct KmRef {
    centroids: Vec<f32>,
    assignments: Vec<u32>,
    inertia: f64,
    iterations: usize,
}

#[test]
fn prop_fused_lloyd_bit_identical_to_scalar_reference() {
    // the perf-rework contract: the fused, chunk-parallel Lloyd must equal
    // the scalar reference BIT-FOR-BIT at n_threads = 1 and stay invariant
    // at any other thread count
    prop::check(12, |g| {
        let n = g.usize(5..9000); // crosses the ACC_CHUNK=4096 boundary
        let d = g.usize(1..5);
        let k = g.usize(1..9);
        let pts = g.vec_f32(n * d, -3.0..3.0);
        let cfg = kmeans::KmeansConfig {
            k,
            n_iter: g.usize(1..8),
            max_points_per_centroid: g.usize(1..300),
            seed: g.u64(),
            tol: 1e-4,
            n_threads: 1,
        };
        let reference = kmeans_scalar_reference(&pts, d, &cfg);
        for threads in [1usize, 4] {
            let r = kmeans::kmeans(
                &pts,
                d,
                &kmeans::KmeansConfig { n_threads: threads, ..cfg.clone() },
            );
            prop::prop_assert!(
                g,
                r.centroids == reference.centroids,
                "centroids diverged from scalar reference at {threads} threads"
            );
            prop::prop_assert!(
                g,
                r.assignments == reference.assignments,
                "assignments diverged from scalar reference at {threads} threads"
            );
            prop::prop_assert!(
                g,
                r.inertia == reference.inertia && r.iterations == reference.iterations,
                "inertia/iterations diverged at {threads} threads: {} vs {}",
                r.inertia,
                reference.inertia
            );
        }
    });
}

#[test]
fn prop_cluster_event_invariant_across_thread_counts() {
    // the whole clustering event — flat-gather materialization, per-job
    // fused K-means, map rewrites — must be a pure function of the seed,
    // not of the worker count or the job/inner thread split
    prop::check(8, |g| {
        let n_features = g.usize(1..4);
        let vocabs: Vec<usize> = (0..n_features).map(|_| g.usize(2..300)).collect();
        let cap = g.usize(2..48);
        let c = *g.pick(&[1usize, 2]);
        let plan = TablePlan::new(&vocabs, cap, 2, c, 4);
        let seed = g.u64();
        let mk = || {
            let mut rng = Rng::new(seed);
            let ix = Indexer::new_rowwise(&mut rng, plan.clone());
            let size = plan.total_rows * plan.dc;
            let mut state = vec![0f32; size];
            Rng::new(seed ^ 1).fill_normal(&mut state, 0.4);
            let field = FieldDesc {
                name: "pool".into(),
                shape: vec![plan.total_rows, plan.dc],
                offset: 0,
                size,
                init: InitSpec::Zeros,
                group: "pool".into(),
            };
            (state, field, ix)
        };
        let cfg = |n_threads: usize| ClusterConfig {
            kmeans_iters: 5,
            points_per_centroid: 16,
            seed,
            n_threads,
        };
        let (mut s1, f1, mut i1) = mk();
        let o1 = cluster_event(&mut s1, &f1, &mut i1, &cfg(1));
        // a random thread count plus RAGGED splits derived from the job
        // count (threads % jobs != 0): the remainder spreads over the
        // first jobs and must not move a bit either
        let n_jobs = (0..n_features).filter(|&f| vocabs[f] > plan.k[f]).count() * c;
        let mut sweep = vec![g.usize(2..9)];
        if n_jobs > 0 {
            sweep.push((n_jobs + 1).min(16));
            sweep.push((2 * n_jobs + 1).min(16));
        }
        for threads in sweep {
            let (mut s2, f2, mut i2) = mk();
            let o2 = cluster_event(&mut s2, &f2, &mut i2, &cfg(threads));
            prop::prop_assert!(g, s1 == s2, "state diverged at {threads} threads");
            prop::prop_assert!(
                g,
                o1.total_inertia == o2.total_inertia
                    && o1.subtables_clustered == o2.subtables_clustered,
                "outcome diverged at {threads} threads ({n_jobs} jobs)"
            );
            for id in plan.subtables() {
                prop::prop_assert!(
                    g,
                    i1.materialize(id) == i2.materialize(id),
                    "map {id:?} diverged at {threads} threads"
                );
            }
        }
    });
}

#[test]
fn prop_kmeans_inertia_never_worse_than_random_centroids() {
    prop::check(20, |g| {
        let n = g.usize(20..200);
        let d = g.usize(1..5);
        let k = g.usize(1..8).min(n);
        let pts = g.vec_f32(n * d, -2.0..2.0);
        let res = kmeans::kmeans(
            &pts,
            d,
            &kmeans::KmeansConfig { k, n_iter: 15, seed: g.u64(), ..Default::default() },
        );
        // compare against centroids = first k points
        let naive_cen: Vec<f32> = pts[..k * d].to_vec();
        let mut naive_asg = vec![0u32; n];
        kmeans::assign(&pts, &naive_cen, d, &mut naive_asg);
        let naive = kmeans::inertia(&pts, &naive_cen, d, &naive_asg);
        prop::prop_assert!(
            g,
            res.inertia <= naive + 1e-6,
            "kmeans {} worse than naive {}",
            res.inertia,
            naive
        );
    });
}

#[test]
fn prop_extrapolation_monotone_in_baseline() {
    // a lower (harder) baseline can never need FEWER parameters
    prop::check(60, |g| {
        let n = g.usize(3..7);
        let mut params = 100.0;
        let mut bce = g.f64(0.5..0.8);
        let mut pts = Vec::new();
        for _ in 0..n {
            pts.push(SweepPoint { params, bce });
            params *= g.f64(2.0..10.0);
            bce -= g.f64(0.005..0.05); // strictly decreasing
        }
        let b1 = g.f64(0.2..0.79);
        let b2 = b1 - g.f64(0.001..0.1);
        let p = |b: f64| match params_to_reach(&pts, b) {
            Crossing::Measured(x) => x,
            Crossing::Extrapolated { linear, .. } => linear,
            Crossing::Unreachable => f64::INFINITY,
        };
        prop::prop_assert!(
            g,
            p(b2) >= p(b1) * 0.999,
            "baseline {b2} needs {} < {} for easier {b1}",
            p(b2),
            p(b1)
        );
    });
}

#[test]
fn prop_entropy_bounded_by_log_k() {
    prop::check(50, |g| {
        let k = g.usize(2..64) as u32;
        let n = g.usize(10..2000);
        let table = g.vec_u32(n, k);
        let h = cce::metrics::entropy::empirical_entropy(
            &table.iter().map(|&x| x as u64).collect::<Vec<_>>(),
        );
        prop::prop_assert!(
            g,
            h <= (k as f64).ln() + 1e-9,
            "H {h} exceeds log k {}",
            (k as f64).ln()
        );
        prop::prop_assert!(g, h >= 0.0, "negative entropy");
    });
}

#[test]
fn prop_auc_invariant_under_monotone_transform() {
    prop::check(30, |g| {
        let n = g.usize(5..200);
        let scores: Vec<(f32, bool)> =
            (0..n).map(|_| (g.f64(0.0..1.0) as f32, g.bool())).collect();
        let a1 = cce::metrics::auc(&scores);
        let transformed: Vec<(f32, bool)> =
            scores.iter().map(|&(s, y)| (s * s * 0.5 + 0.1, y)).collect(); // monotone on [0,1]
        let a2 = cce::metrics::auc(&transformed);
        prop::prop_assert!(g, (a1 - a2).abs() < 1e-9, "AUC changed: {a1} vs {a2}");
    });
}

#[test]
fn prop_dataset_values_always_in_vocab() {
    prop::check(15, |g| {
        let vocabs: Vec<usize> = (0..g.usize(1..4)).map(|_| g.usize(1..5000)).collect();
        let ds = SyntheticDataset::new(DatasetSpec {
            name: "p".into(),
            vocabs: vocabs.clone(),
            n_dense: 3,
            train_samples: 50,
            val_samples: 5,
            test_samples: 5,
            latent_clusters: g.usize(1..16),
            zipf_exponent: g.f64(1.01..1.5),
            label_noise: g.f64(0.0..0.3),
            seed: g.u64(),
        });
        let mut dense = vec![0f32; 3];
        let mut cats = vec![0u32; vocabs.len()];
        for i in 0..60 {
            let y = ds.sample_into(i, &mut dense, &mut cats);
            prop::prop_assert!(g, y == 0.0 || y == 1.0, "label {y}");
            for (f, &v) in cats.iter().enumerate() {
                prop::prop_assert!(g, (v as usize) < vocabs[f], "f={f} v={v}");
            }
        }
    });
}
