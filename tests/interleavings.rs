//! Bounded model-checking of the lock-free serving core under the
//! deterministic interleaving harness (`testutil::interleave`): every
//! program-order-preserving schedule of each scenario is enumerated and run
//! — no wall-clock sleeps, no "hope the race window opens" timing tests.
//!
//! Pinned contracts:
//! * `BatchQueue` close-while-blocked conservation: every item whose `push`
//!   returned true is drained exactly once, everything else never, and all
//!   parties terminate — under EVERY ordering of producers/closer/drainer.
//! * `SnapshotSlot` generation-mirror coherence: `generation()` never leads
//!   `current().0` (the mirror may lag, never lead — the audit verdict the
//!   ORDERING comments in `engine.rs` document).
//! * `WatcherState::tick` racing a direct `install`: the watcher installs
//!   its file exactly once and the slot's swap count is exact, regardless
//!   of which side swaps first.
//! * Concurrent `par_map_with` instances never interfere (bit-identical
//!   outputs while overlapping).

use cce::serving::batcher::BatchQueue;
use cce::serving::engine::SnapshotSlot;
use cce::serving::segment;
use cce::serving::snapshot::ServingSnapshot;
use cce::serving::watcher::{WatcherConfig, WatcherState};
use cce::tables::indexer::Indexer;
use cce::tables::layout::TablePlan;
use cce::testutil::interleave::{blocking_step, explore, step, Plan};
use cce::testutil::TempDir;
use cce::util::threadpool::par_map_with;
use cce::util::Rng;
use std::sync::{Arc, Mutex};
use std::time::Duration;

fn snap(seed: u64) -> ServingSnapshot {
    let mut rng = Rng::new(seed);
    let ix = Indexer::new_rowwise(&mut rng, TablePlan::new(&[11, 50], 8, 2, 2, 4));
    ServingSnapshot::bake(&ix)
}

/// Close fires under every ordering relative to two producers blocked on a
/// capacity-1 queue and a drainer: conservation (accepted == drained, as
/// multisets) and termination must hold on all 24 schedules.
#[test]
fn batch_queue_close_while_blocked_conserves_items() {
    let n = explore(100, || {
        let q = Arc::new(BatchQueue::new(1));
        assert!(q.push(0u32), "pre-fill on a fresh queue cannot fail");
        let accepted = Arc::new(Mutex::new(vec![0u32]));
        let drained: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(Vec::new()));

        let mut threads = Vec::new();
        for item in [1u32, 2] {
            let (q, acc) = (q.clone(), accepted.clone());
            // may park on the full queue until the drainer or close() acts
            threads.push(vec![blocking_step("push", move || {
                if q.push(item) {
                    acc.lock().unwrap().push(item);
                }
            })]);
        }
        let qc = q.clone();
        threads.push(vec![step("close", move || qc.close())]);
        let (qd, dr) = (q.clone(), drained.clone());
        // parks on the empty queue between trickled items; terminates only
        // once close() lands — exactly the shutdown path under test
        threads.push(vec![blocking_step("drain", move || {
            while let Some(b) = qd.pop_batch(16, Duration::ZERO) {
                assert!(!b.is_empty(), "empty batch dispatched");
                dr.lock().unwrap().extend(b);
            }
        })]);

        Plan::new(threads, move || {
            let mut a = accepted.lock().unwrap().clone();
            let mut d = drained.lock().unwrap().clone();
            a.sort_unstable();
            d.sort_unstable();
            assert_eq!(a, d, "accepted and drained items must match exactly");
        })
    });
    assert_eq!(n, 24, "4 single-step threads = 4! schedules, all exhausted");
}

/// The lock-free generation mirror may lag the locked pair but never lead
/// it: sampling `generation()`, `current().0`, `generation()` in that order
/// is non-decreasing under every interleaving with a double installer.
#[test]
fn snapshot_slot_generation_mirror_never_leads_current() {
    let base = snap(0);
    let n = explore(100, || {
        let slot = Arc::new(SnapshotSlot::new(base.clone()));
        let mut threads = Vec::new();

        let mut installs = Vec::new();
        for _ in 0..2 {
            let (s, next) = (slot.clone(), base.clone());
            installs.push(step("install", move || {
                s.install(next).expect("same-shape snapshot must install");
            }));
        }
        threads.push(installs);

        let mut probes = Vec::new();
        for _ in 0..2 {
            let s = slot.clone();
            probes.push(step("probe", move || {
                let g1 = s.generation();
                let g2 = s.current().0;
                let g3 = s.generation();
                assert!(
                    g1 <= g2 && g2 <= g3,
                    "mirror incoherence: generation {g1} / current {g2} / generation {g3}"
                );
            }));
        }
        threads.push(probes);

        Plan::new(threads, move || {
            assert_eq!(slot.generation(), 2, "both installs must be published");
            assert_eq!(slot.current().0, 2);
        })
    });
    assert_eq!(n, 6, "[2,2] step threads = C(4,2) schedules, all exhausted");
}

/// A watcher tick racing a direct `install` (the `--cluster-overlap`
/// trainer pushing a snapshot while the directory watcher polls): the
/// watcher installs its file exactly once, the slot's swap count is exact,
/// and no ordering panics or rolls a generation back.
#[test]
fn watcher_tick_races_direct_install() {
    let dir = TempDir::new("interleave_watcher");
    let file = dir.path().join("a-gen5.cceseg");
    segment::write_segment(&snap(1), 5, &file).unwrap();
    let base = snap(0);

    let n = explore(100, || {
        let slot = Arc::new(SnapshotSlot::new(base.clone()));
        let cfg = WatcherConfig {
            dir: dir.path().to_path_buf(),
            poll: Duration::from_millis(1),
            max_retries: 2,
            backoff: Duration::ZERO,
        };
        let watcher = Arc::new(Mutex::new(WatcherState::new(cfg, None)));

        let mut ticks = Vec::new();
        for _ in 0..2 {
            let (w, s) = (watcher.clone(), slot.clone());
            ticks.push(step("tick", move || w.lock().unwrap().tick(&s)));
        }
        let (si, next) = (slot.clone(), base.clone());
        Plan::new(
            vec![
                ticks,
                vec![step("install", move || {
                    si.install(next).expect("compatible snapshot must install");
                })],
            ],
            move || {
                let w = watcher.lock().unwrap();
                assert_eq!(w.report().installs, 1, "file installed exactly once");
                assert_eq!(w.report().generation, 5, "header generation recorded");
                assert_eq!(slot.generation(), 2, "one watcher swap + one direct swap");
                assert_eq!(slot.current().0, 2);
            },
        )
    });
    assert_eq!(n, 3, "[2,1] step threads = 3 schedules, all exhausted");
}

/// Concurrent metric recording racing a scraper: under every interleaving
/// of two incrementers and a prober, scraped counter values are monotone
/// snapshots in `[0, 4]` and the final merge across shards loses nothing
/// and double-counts nothing — the shard-merge contract the Relaxed
/// ORDERING comments in `obs/registry.rs` claim.
#[test]
fn obs_counter_record_and_scrape_never_loses_counts() {
    // one fixed registry metric: the registry is process-global, so every
    // explored schedule accumulates into the same counter — the checks
    // below are therefore phrased as per-schedule DELTAS
    let counter = cce::obs::registry().counter("test.interleave.obs_counts");
    let n = explore(100, || {
        let base = counter.value();
        let mut threads = Vec::new();
        for _ in 0..2 {
            let c = counter.clone();
            threads.push(vec![step("inc", move || c.inc()), {
                let c = counter.clone();
                step("inc", move || c.inc())
            }]);
        }
        let (c, last) = (counter.clone(), Arc::new(Mutex::new(0u64)));
        let l2 = last.clone();
        threads.push(vec![
            step("scrape", move || {
                let v = c.value() - base;
                assert!(v <= 4, "scrape observed more than was ever recorded: {v}");
                *l2.lock().unwrap() = v;
            }),
            {
                let c = counter.clone();
                step("scrape", move || {
                    let v = c.value() - base;
                    let prev = *last.lock().unwrap();
                    assert!(v >= prev, "counter went backwards: {prev} then {v}");
                    assert!(v <= 4);
                })
            },
        ]);
        let c = counter.clone();
        Plan::new(threads, move || {
            assert_eq!(c.value() - base, 4, "a recorded increment was lost");
        })
    });
    assert_eq!(n, 90, "[2,2,2] step threads = 6!/(2!2!2!) schedules, all exhausted");
}

/// Two overlapping `par_map_with` fan-outs (their blocking steps both start
/// before either finishes in some schedules) must produce bit-identical,
/// fully-initialized outputs — shared pools and SharedSlice claims are
/// per-call, so instances cannot interfere.
#[test]
fn concurrent_par_map_with_instances_are_independent() {
    let n = explore(10, || {
        let mut threads = Vec::new();
        for salt in [0xDEAD_BEEFu64, 0x5EED_CAFE] {
            threads.push(vec![blocking_step("par_map", move || {
                let got = par_map_with(
                    257,
                    4,
                    || (),
                    move |_, i| (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ salt,
                );
                for (i, &v) in got.iter().enumerate() {
                    let want = (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ salt;
                    assert_eq!(v, want, "slot {i} diverged under concurrency");
                }
            })]);
        }
        Plan::new(threads, || {})
    });
    assert_eq!(n, 2);
}
