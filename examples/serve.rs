//! Serving demo: train briefly, bake a `ServingSnapshot`, then drive the
//! multi-worker engine with Zipf-skewed traffic and report per-request
//! latency/throughput — the deployment shape of Appendix E (index gather on
//! CPU, model on the accelerator).
//!
//! Run: `make artifacts && cargo run --release --example serve`

use cce::config::{ServeConfig, TrainConfig};
use cce::coordinator::serve::serve_trained;
use cce::data::SyntheticDataset;
use cce::runtime::{ArtifactStore, DlrmSession};

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    let artifact = "quick_cce";

    // brief training so the served model is not random
    println!("-- warm-up training ({artifact}, 200 batches) --");
    let cfg = TrainConfig {
        artifact: artifact.into(),
        epochs: 1,
        max_batches: 200,
        cluster_times: 0,
        eval_every: 200,
        ..Default::default()
    };
    let outcome = cce::coordinator::train(&store, &cfg)?;
    println!("trained to val BCE {:.5}\n", outcome.best_val_bce);
    let ckpt = outcome.best_checkpoint.expect("train always returns a checkpoint");

    // fresh session for serving (the trainer consumed its own session);
    // the best-validation checkpoint carries the trained state AND its
    // contemporaneous index maps — the pair serving must bake together
    let mut session = DlrmSession::open(&store, artifact)?;
    let m = session.manifest.clone();
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, 0)?);

    let scfg = ServeConfig { artifact: artifact.into(), requests: 20_000, ..Default::default() };
    println!(
        "-- serving {} requests (zipf skew {}, {} workers, batches ≤{}) --",
        scfg.requests, scfg.zipf_skew, scfg.workers, m.spec.eval_batch
    );
    let rep = serve_trained(&mut session, &ckpt, &ds, &scfg)?;
    println!("requests     : {}", rep.requests);
    println!("batches      : {} ({} padded rows, tail only)", rep.batches, rep.padded_rows);
    println!("throughput   : {:.0} req/s", rep.throughput_rps);
    println!("latency e2e  : {}", rep.latency.display());
    println!("queue wait   : {}", rep.queue_wait.display());
    println!(
        "snapshot     : {} KiB baked in {:.3}s",
        rep.snapshot_bytes / 1024,
        rep.bake_secs
    );
    println!(
        "index gen    : {:.3}s summed over {} workers (Appendix E: the CPU-side cost is small)",
        rep.index_secs, rep.workers
    );
    println!("device exec  : {:.1}% of wall time", 100.0 * rep.exec_secs / rep.elapsed_secs);
    Ok(())
}
