//! Serving demo: train briefly, then serve batched prediction requests and
//! report latency/throughput — the deployment shape of Appendix E (index
//! pointers on CPU, model on the accelerator).
//!
//! Run: `make artifacts && cargo run --release --example serve`

use cce::config::TrainConfig;
use cce::coordinator::serve::serve;
use cce::coordinator::trainer::build_indexer;
use cce::data::SyntheticDataset;
use cce::runtime::{ArtifactStore, DlrmSession};
use cce::tables::init::init_state;
use cce::util::Rng;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    let artifact = "quick_cce";

    // brief training so the served model is not random
    println!("-- warm-up training ({artifact}, 200 batches) --");
    let cfg = TrainConfig {
        artifact: artifact.into(),
        epochs: 1,
        max_batches: 200,
        cluster_times: 0,
        eval_every: 200,
        ..Default::default()
    };
    let outcome = cce::coordinator::train(&store, &cfg)?;
    println!("trained to val BCE {:.5}\n", outcome.best_val_bce);

    // fresh session for serving (the trainer consumed its own session)
    let mut session = DlrmSession::open(&store, artifact)?;
    let m = session.manifest.clone();
    let ds = SyntheticDataset::new(store.dataset(&m.dataset, 0)?);
    let indexer = build_indexer(&m, 0)?;
    let mut rng = Rng::new(0x57A7E);
    session.set_state(&init_state(&m.layout, m.state_size, &mut rng))?;

    println!("-- serving 20,000 requests, dynamic batches of ≤{} --", m.spec.eval_batch);
    let rep = serve(&session, &indexer, &ds, 20_000, m.spec.eval_batch)?;
    println!("requests     : {}", rep.requests);
    println!("batches      : {}", rep.batches);
    println!("throughput   : {:.0} req/s", rep.throughput_rps);
    println!("latency      : {}", rep.latency.display());
    println!(
        "index gen    : {:.1}% of wall time (Appendix E: the CPU-side cost is small)",
        100.0 * rep.index_secs / rep.elapsed_secs
    );
    println!("device exec  : {:.1}% of wall time", 100.0 * rep.exec_secs / rep.elapsed_secs);
    Ok(())
}
