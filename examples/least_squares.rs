//! Least-squares CCE (Section 3): run Algorithm 1 (dense) and Algorithm 2
//! (sparse) on a random instance and print the convergence against the
//! Theorem 3.1 envelope — the Figure 1b / Figure 8 story at example scale.
//!
//! Run: `cargo run --release --example least_squares`

use cce::cce::{
    dense_cce, optimal_loss, pq_factorized_loss, sparse_cce, theory, DenseCceOptions, NoiseKind,
    SparseCceOptions,
};
use cce::linalg::Matrix;
use cce::util::Rng;

fn main() {
    let (n, d1, d2, k, iters) = (1500, 250, 10, 40, 16);
    let mut rng = Rng::new(0);
    let x = Matrix::randn(&mut rng, n, d1);
    let y = Matrix::randn(&mut rng, n, d2);

    let opt = optimal_loss(&x, &y);
    let bp = theory::bound_params(&x, &y);
    println!("least squares: X {n}x{d1}, Y {n}x{d2}, sketch width k={k}");
    println!("optimal loss {opt:.4e}; rho = {:.3e} (ideal 1/d1 = {:.3e})\n", bp.rho, bp.rho_smart);

    let dense = dense_cce(
        &x,
        &y,
        &DenseCceOptions { k, iterations: iters, noise: NoiseKind::Iid, half_update: false, seed: 1 },
    );
    let smart = dense_cce(
        &x,
        &y,
        &DenseCceOptions { k, iterations: iters, noise: NoiseKind::Smart, half_update: false, seed: 1 },
    );
    let sparse = sparse_cce(
        &x,
        &y,
        &SparseCceOptions {
            k,
            sketch_width: k / 3,
            iterations: iters,
            kmeans_iters: 25,
            signs: false,
            seed: 1,
        },
    );

    println!(
        "{:>4} {:>14} {:>14} {:>14} {:>14}",
        "iter", "dense excess", "smart excess", "sparse excess", "bound excess"
    );
    for i in 0..=iters {
        println!(
            "{i:>4} {:>14.4e} {:>14.4e} {:>14.4e} {:>14.4e}",
            dense.losses[i] - opt,
            smart.losses[i] - opt,
            sparse.losses[i] - opt,
            bp.bound_at(i, k, d2, false) - bp.floor,
        );
    }

    let pq = pq_factorized_loss(&x, &y, k, 25, 2);
    println!(
        "\npost-hoc PQ of the optimal solution (k={k} codewords): excess {:.4e}",
        pq - opt
    );
    println!(
        "sparse CCE reaches {:.4e} without ever materializing the optimal T \
         (memory: O(d1·k) vs O(d1·d2) for the direct solve).",
        sparse.losses.last().unwrap() - opt
    );
}
