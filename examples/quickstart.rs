//! Quickstart — the end-to-end driver (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Trains the DLRM with Clustered Compositional Embeddings on the
//! synthetic Criteo-Kaggle-like dataset for two epochs with a clustering
//! event at the first epoch boundary, logging the loss curve, and
//! compares the result against the hashing-trick baseline at the SAME
//! parameter budget.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use cce::config::TrainConfig;
use cce::coordinator::train;
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    println!("== CCE quickstart: DLRM on synthetic Criteo-Kaggle ==\n");
    let base = TrainConfig {
        artifact: "sweep_kaggle_small_cce_1024".into(),
        epochs: 2,
        cluster_times: 1, // cluster at the end of epoch 1 (Algorithm 3)
        shuffle: true,
        ..Default::default()
    };

    println!("-- training CCE (T=2, c=4, 1024-row cap) --");
    let cce_run = train(&store, &base)?;

    println!("\n-- training the Hashing Trick at the same budget --");
    let hash_run = train(
        &store,
        &TrainConfig { artifact: "sweep_kaggle_small_hash_1024".into(), cluster_times: 0, ..base.clone() },
    )?;

    println!("\n== loss curves (train-window BCE) ==");
    println!("{:>8} {:>12} {:>12}", "step", "cce", "hash");
    for (i, (step, bce)) in cce_run.train_curve.iter().enumerate() {
        let h = hash_run
            .train_curve
            .get(i)
            .map(|(_, b)| format!("{b:.5}"))
            .unwrap_or_default();
        println!("{step:>8} {bce:>12.5} {h:>12}");
    }

    println!("\n== validation BCE ==");
    println!("{:>8} {:>12} {:>12}", "step", "cce", "hash");
    for (i, (step, bce)) in cce_run.val_curve.iter().enumerate() {
        let h = hash_run
            .val_curve
            .get(i)
            .map(|(_, b)| format!("{b:.5}"))
            .unwrap_or_default();
        println!("{step:>8} {bce:>12.5} {h:>12}");
    }

    println!("\n== summary ==");
    for (name, r) in [("CCE", &cce_run), ("Hashing Trick", &hash_run)] {
        println!(
            "{name:14} test BCE {:.5}  AUC {:.5}  params {}  compression {:>8.1}x (largest table {:.1}x)  {:.0} samples/s",
            r.test_bce, r.test_auc, r.embedding_params, r.compression_total,
            r.compression_largest, r.throughput,
        );
    }
    let delta = hash_run.test_bce - cce_run.test_bce;
    println!(
        "\nCCE {} the hashing trick by {:.5} BCE at the same per-table row cap \
         ({} clustering event(s), {:.2}s clustering time). NOTE: during training \
         CCE carries 2x the parameters of the hashing trick at equal cap (the \
         paper's 2kd cost, Algorithm 3); the fig4 benches compare methods on the \
         equal-parameter axis.",
        if delta > 0.0 { "beats" } else { "trails" },
        delta.abs(),
        cce_run.clusterings_run,
        cce_run.cluster_secs,
    );
    Ok(())
}
