//! Compare the full method zoo at a single parameter budget — the
//! motivating comparison of the paper's §2 (Figure 3's evolution of
//! hashing-based methods), on the quick artifacts.
//!
//! Run: `make artifacts && cargo run --release --example compare_methods`

use cce::config::TrainConfig;
use cce::coordinator::train;
use cce::experiments::report::Table;
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    let epochs: usize = std::env::args()
        .skip_while(|a| a != "--epochs")
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let mut table = Table::new(
        &format!("method comparison, kaggle_small @ 1024-row cap, {epochs} epoch(s)"),
        &["method", "test BCE", "test AUC", "emb params", "compression", "samples/s"],
    );
    for (label, artifact, clusterings) in [
        ("Hashing Trick", "sweep_kaggle_small_hash_1024", 0usize),
        ("CE (concat)", "sweep_kaggle_small_ce_1024", 0),
        ("CCE (this paper)", "sweep_kaggle_small_cce_1024", 1),
    ] {
        let cfg = TrainConfig {
            artifact: artifact.into(),
            epochs,
            cluster_times: clusterings,
            ..Default::default()
        };
        log::info!("training {label} ({artifact})");
        let r = train(&store, &cfg)?;
        table.row(vec![
            label.into(),
            format!("{:.5}", r.test_bce),
            format!("{:.5}", r.test_auc),
            r.embedding_params.to_string(),
            format!("{:.1}x", r.compression_total),
            format!("{:.0}", r.throughput),
        ]);
    }
    table.print();
    println!(
        "(The full-table baseline `quick_full` is excluded here for runtime; \
         the fig4 benches include it. DHE/ROBE budgets live in the sweep artifacts.)"
    );
    Ok(())
}
