#!/usr/bin/env bash
# Unsafe-and-atomics correctness gate (docs/UNSAFE_POLICY.md):
#
#   1. in-repo analyzer (tools/analyze): SAFETY/ORDERING comment coverage,
#      determinism-region bans, bench-JSON field drift vs verify.sh
#   2. the analyzer's own self-tests (including the seeded-violation check
#      that proves the lint actually fires)
#   3. clippy with the curated deny-list
#   4. Miri on the pointer-heavy modules (skipped when miri is not installed)
#   5. ThreadSanitizer on the serving concurrency tests (skipped unless a
#      nightly toolchain with rust-src is available; also skipped by --quick)
#
#   scripts/analyze.sh          # full pass
#   scripts/analyze.sh --quick  # skip the sanitizer pass
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

# The analyzer crate is a standalone package; prefer workspace resolution,
# fall back to its own manifest when it is not a workspace member.
if cargo pkgid -p analyze >/dev/null 2>&1; then
  analyze_run=(cargo run -q -p analyze --)
  analyze_test=(cargo test -q -p analyze)
else
  analyze_run=(cargo run -q --manifest-path tools/analyze/Cargo.toml --)
  analyze_test=(cargo test -q --manifest-path tools/analyze/Cargo.toml)
fi

echo "== analyze: SAFETY/ORDERING/determinism/bench-field lint =="
"${analyze_run[@]}" --root .

echo "== analyze: self-tests (seeded violations must be caught) =="
"${analyze_test[@]}"

echo "== cargo clippy (curated deny-list) =="
cargo clippy -- -D warnings -D clippy::undocumented_unsafe_blocks

if cargo miri --version >/dev/null 2>&1; then
  echo "== cargo miri test (mmap casts + threadpool aliasing) =="
  MIRIFLAGS="-Zmiri-disable-isolation" \
    cargo miri test --lib -- util::mmap util::threadpool
else
  echo "== miri not installed; skipping (rustup +nightly component add miri) =="
fi

if [[ "$quick" -eq 1 ]]; then
  echo "== --quick: skipping ThreadSanitizer pass =="
elif cargo +nightly --version >/dev/null 2>&1 \
    && rustc +nightly --print sysroot >/dev/null 2>&1 \
    && [[ -d "$(rustc +nightly --print sysroot)/lib/rustlib/src/rust/library" ]]; then
  echo "== ThreadSanitizer: serving concurrency tests =="
  host="$(rustc +nightly -vV | awk '/^host:/{print $2}')"
  RUSTFLAGS="-Zsanitizer=thread" \
    cargo +nightly test -Zbuild-std --target "$host" --lib -- \
    serving::batcher serving::engine
else
  echo "== nightly+rust-src unavailable; skipping ThreadSanitizer =="
fi

echo "analyze: OK"
