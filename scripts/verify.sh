#!/usr/bin/env bash
# CI gate: tier-1 verify (ROADMAP.md) plus formatting and lint.
#
#   scripts/verify.sh          # full gate
#   scripts/verify.sh --quick  # skip the release build (tests only)
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

if [[ "$quick" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "verify: OK"
