#!/usr/bin/env bash
# CI gate: tier-1 verify (ROADMAP.md) plus formatting, lint, and a smoke
# run of the clustering-event perf bench (perf tracked via
# bench_results/BENCH_cluster.json from PR 2 on).
#
#   scripts/verify.sh          # full gate
#   scripts/verify.sh --quick  # skip the release build + bench smoke
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

if [[ "$quick" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

if [[ "$quick" -eq 0 ]]; then
  echo "== perf_cluster bench (smoke) =="
  cargo bench --bench perf_cluster -- --smoke

  echo "== BENCH_cluster.json well-formed =="
  python3 - <<'PY'
import json

with open("bench_results/BENCH_cluster.json") as f:
    doc = json.load(f)
assert doc.get("schema") == "cce.perf_cluster.v1", f"bad schema: {doc.get('schema')!r}"
assert doc.get("mode") in ("smoke", "full"), f"bad mode: {doc.get('mode')!r}"
assert isinstance(doc.get("threads"), int) and doc["threads"] >= 1, "bad threads"
results = doc.get("results")
assert isinstance(results, list) and results, "results missing or empty"
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result without name: {r}"
    for key in ("mean_ns", "p50_ns", "min_ns"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, f"bad {key}: {r}"

# sync-vs-overlap group: every row must carry the stall/staleness fields
ov = [r for r in results if r.get("group") == "sync_vs_overlap"]
assert len(ov) >= 2, f"sync_vs_overlap group missing or incomplete: {len(ov)} rows"
for r in ov:
    for key in ("stall_ns", "event_wall_ns", "stale_steps"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, \
            f"sync_vs_overlap row missing {key}: {r}"
sync = [r for r in ov if " sync (" in r["name"]]
over = [r for r in ov if " overlap (" in r["name"]]
assert sync and over, f"need both sync and overlap rows: {[r['name'] for r in ov]}"
assert min(r["stall_ns"] for r in over) < min(r["stall_ns"] for r in sync), \
    "overlapped event did not reduce the per-event stall"
assert all(r["stale_steps"] >= 1 for r in over), "overlap rows must report staleness"
print(f"BENCH_cluster.json OK ({len(results)} results, mode={doc['mode']}, "
      f"overlap stall {min(r['stall_ns'] for r in over)/1e6:.2f} ms vs "
      f"sync {min(r['stall_ns'] for r in sync)/1e6:.2f} ms)")
PY
fi

echo "verify: OK"
