#!/usr/bin/env bash
# CI gate: tier-1 verify (ROADMAP.md) plus formatting, lint, and smoke
# runs of the perf benches (perf tracked via bench_results/BENCH_cluster.json
# from PR 2 on and bench_results/BENCH_serving.json from the snapshot PR on).
#
#   scripts/verify.sh          # full gate
#   scripts/verify.sh --quick  # skip the release build + bench smoke
set -euo pipefail
cd "$(dirname "$0")/.."

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

if [[ "$quick" -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

# lint + unsafe/atomics gate (includes clippy with the curated deny-list)
if [[ "$quick" -eq 1 ]]; then
  scripts/analyze.sh --quick
else
  scripts/analyze.sh
fi

if [[ "$quick" -eq 0 ]]; then
  echo "== perf_cluster bench (smoke) =="
  cargo bench --bench perf_cluster -- --smoke

  echo "== BENCH_cluster.json well-formed =="
  python3 - <<'PY'
import json

with open("bench_results/BENCH_cluster.json") as f:
    doc = json.load(f)
assert doc.get("schema") == "cce.perf_cluster.v1", f"bad schema: {doc.get('schema')!r}"
assert doc.get("mode") in ("smoke", "full"), f"bad mode: {doc.get('mode')!r}"
assert isinstance(doc.get("threads"), int) and doc["threads"] >= 1, "bad threads"
results = doc.get("results")
assert isinstance(results, list) and results, "results missing or empty"
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result without name: {r}"
    for key in ("mean_ns", "p50_ns", "min_ns"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, f"bad {key}: {r}"

# sync-vs-overlap group: every row must carry the stall/staleness fields
ov = [r for r in results if r.get("group") == "sync_vs_overlap"]
assert len(ov) >= 2, f"sync_vs_overlap group missing or incomplete: {len(ov)} rows"
for r in ov:
    for key in ("stall_ns", "event_wall_ns", "stale_steps"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, \
            f"sync_vs_overlap row missing {key}: {r}"
sync = [r for r in ov if " sync (" in r["name"]]
over = [r for r in ov if " overlap (" in r["name"]]
assert sync and over, f"need both sync and overlap rows: {[r['name'] for r in ov]}"
assert min(r["stall_ns"] for r in over) < min(r["stall_ns"] for r in sync), \
    "overlapped event did not reduce the per-event stall"
assert all(r["stale_steps"] >= 1 for r in over), "overlap rows must report staleness"

# per-group device buffers: every sync_vs_overlap row must report the event
# wire cost, and it must be pool-bounded — a sync event is one pool download
# + one pool upload; an overlapped event adds one extra pool download for the
# snapshot. The dense/metrics tail must never cross the wire during an event.
for r in ov:
    for key in ("event_bytes_downloaded", "event_bytes_uploaded",
                "pool_bytes", "full_state_bytes"):
        assert isinstance(r.get(key), int) and r[key] > 0, \
            f"sync_vs_overlap row missing {key}: {r}"
    assert r["pool_bytes"] < r["full_state_bytes"], \
        f"pool buffer not smaller than full state (gate is vacuous): {r}"
    assert r["event_bytes_downloaded"] <= 2 * r["pool_bytes"], \
        f"event downloaded more than 2x the pool buffer: {r}"
    assert r["event_bytes_uploaded"] <= r["pool_bytes"], \
        f"event uploaded more than the pool buffer: {r}"
for r in sync:
    assert r["event_bytes_downloaded"] <= r["pool_bytes"], \
        f"sync event should download the pool exactly once: {r}"

print(f"BENCH_cluster.json OK ({len(results)} results, mode={doc['mode']}, "
      f"overlap stall {min(r['stall_ns'] for r in over)/1e6:.2f} ms vs "
      f"sync {min(r['stall_ns'] for r in sync)/1e6:.2f} ms, "
      f"event wire cost {ov[0]['event_bytes_downloaded']/1024:.0f} KiB down "
      f"of {ov[0]['full_state_bytes']/1024:.0f} KiB state)")
PY

  echo "== perf_hot_paths bench (smoke) =="
  cargo bench --bench perf_hot_paths -- --smoke

  echo "== BENCH_serving.json well-formed =="
  python3 - <<'PY'
import json

with open("bench_results/BENCH_serving.json") as f:
    doc = json.load(f)
assert doc.get("schema") == "cce.perf_serving.v1", f"bad schema: {doc.get('schema')!r}"
assert doc.get("mode") in ("smoke", "full"), f"bad mode: {doc.get('mode')!r}"
results = doc.get("results")
assert isinstance(results, list) and results, "results missing or empty"
for r in results:
    assert isinstance(r.get("name"), str) and r["name"], f"result without name: {r}"
    for key in ("mean_ns", "p50_ns", "min_ns"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, f"bad {key}: {r}"

# cold start: both presets present, load time + bake time + speedup recorded,
# and the zero-copy load beats a fresh bake ≥10x at the terabyte-ish shape
cold = [r for r in results if r.get("group") == "cold_start"]
assert len(cold) >= 2, f"cold_start group missing or incomplete: {len(cold)} rows"
for r in cold:
    for key in ("cold_start_ns", "bake_ns", "speedup"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, \
            f"cold_start row missing {key}: {r}"
tb = [r for r in cold if r.get("preset") == "terabyte-ish"]
assert tb, f"terabyte-ish cold_start row missing: {[r.get('preset') for r in cold]}"
assert tb[0]["speedup"] >= 10, \
    f"mmap cold start only {tb[0]['speedup']:.1f}x faster than bake (need >=10x)"

# hot swap: install latency p99 under load must be recorded
hs = [r for r in results if r.get("group") == "hot_swap"]
assert hs, "hot_swap row missing"
for r in hs:
    assert isinstance(r.get("swap_pause_ns"), (int, float)) and r["swap_pause_ns"] > 0, \
        f"hot_swap row missing swap_pause_ns: {r}"
    assert r.get("installs", 0) >= 1, f"no snapshot installs recorded: {r}"

# parity: mapped tables must serve at a throughput comparable to owned ones
par = [r for r in results if r.get("group") == "load_parity"]
assert par, "load_parity row missing"
assert all(r.get("parity", 0) > 0 for r in par), f"bad parity rows: {par}"

# overload: block vs shed at {0.5, 1, 2, 4}x capacity. Every row carries the
# admission fields; at 4x, shed-mode p99 must stay bounded (below block-mode
# p99, and within 5x of the 1x-load p99) while block-mode backlogs.
ov = [r for r in results if r.get("group") == "overload"]
assert len(ov) >= 8, f"overload group missing or incomplete: {len(ov)} rows"
for r in ov:
    assert r.get("mode") in ("block", "shed"), f"overload row with bad mode: {r}"
    for key in ("load_mult", "offered_rps", "p99_ns", "shed_rate",
                "deadline_miss_rate", "goodput_rps", "throughput_rps"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, \
            f"overload row missing {key}: {r}"

def p99(mode, mult):
    rows = [r for r in ov if r["mode"] == mode and r["load_mult"] == mult]
    assert rows, f"overload row missing for mode={mode} load_mult={mult}"
    return rows[0]["p99_ns"]

shed4, block4 = p99("shed", 4.0), p99("block", 4.0)
base1 = max(p99("shed", 1.0), p99("block", 1.0))
assert shed4 < block4, \
    f"shed p99 at 4x ({shed4/1e6:.2f} ms) not below block p99 ({block4/1e6:.2f} ms)"
assert shed4 <= 5 * base1, \
    f"shed p99 at 4x ({shed4/1e6:.2f} ms) exceeds 5x the 1x-load p99 ({base1/1e6:.2f} ms)"
shed_rows4 = [r for r in ov if r["mode"] == "shed" and r["load_mult"] == 4.0]
assert shed_rows4[0]["shed_rate"] > 0, \
    "shed mode at 4x load reported zero shed rate — admission control inert"

# observability: telemetry (spans + trace ring) must cost <= 3% serving
# throughput vs obs::set_enabled(false) — the budget docs/OBSERVABILITY.md
# commits to
obs = [r for r in results if r.get("group") == "obs_overhead"]
assert obs, "obs_overhead row missing"
for r in obs:
    for key in ("throughput_instrumented_rps", "throughput_disabled_rps",
                "overhead_frac"):
        assert isinstance(r.get(key), (int, float)) and r[key] >= 0, \
            f"obs_overhead row missing {key}: {r}"
    assert r["throughput_instrumented_rps"] > 0, f"instrumented run served nothing: {r}"
    assert r["overhead_frac"] <= 0.03, \
        f"telemetry overhead {r['overhead_frac']*100:.2f}% exceeds the 3% budget: {r}"

print(f"BENCH_serving.json OK ({len(results)} results, mode={doc['mode']}, "
      f"terabyte cold start {tb[0]['cold_start_ns']/1e6:.2f} ms = "
      f"{tb[0]['speedup']:.0f}x over bake, "
      f"swap pause p99 {hs[0]['swap_pause_ns']/1e6:.2f} ms, "
      f"overload 4x p99 shed {shed4/1e6:.2f} ms vs block {block4/1e6:.2f} ms, "
      f"obs overhead {obs[0]['overhead_frac']*100:.2f}%)")
PY

  # End-to-end smoke of the per-field (schema v2) artifact convention:
  # train with an overlapped clustering event (pool-only wire traffic),
  # bake a trained segment, verify its checksums, and serve from it.
  # Soft-skips when no compiled artifacts are present — building them
  # needs the JAX toolchain (`cd python && python -m compile.aot`).
  echo "== per-field artifact smoke (train → overlapped event → bake → serve) =="
  art_dir="${CCE_ARTIFACTS:-artifacts}"
  if [[ -f "$art_dir/index.json" ]]; then
    bin=target/release/cce
    smoke_out=$(mktemp -d)
    "$bin" train --artifact quick_cce --seed 7 --max-batches 96 \
      --cluster-every 32 --cluster-times 2 --cluster-overlap
    "$bin" snapshot write --artifact quick_cce --seed 7 --train-steps 48 \
      --out "$smoke_out/quick.cceseg"
    "$bin" snapshot inspect "$smoke_out/quick.cceseg" --verify
    "$bin" serve --artifact quick_cce --seed 7 --requests 64 --workers 1 \
      --snapshot "$smoke_out/quick.cceseg"

    # Live telemetry smoke: the same serve path with every exporter on —
    # scrape /metrics mid-run (conservation must hold on any live snapshot),
    # then check the JSONL stats stream and the Chrome trace dump.
    echo "== live telemetry smoke (/metrics + stats.jsonl + trace.json) =="
    "$bin" serve --artifact quick_cce --seed 7 --requests 2000 --workers 2 \
      --snapshot "$smoke_out/quick.cceseg" --pace-rps 1000 \
      --metrics-addr 127.0.0.1:9184 \
      --stats-out "$smoke_out/stats.jsonl" --stats-interval-ms 100 \
      --trace-out "$smoke_out/trace.json" &
    serve_pid=$!
    python3 - <<'PY'
import time, urllib.request

# poll until the endpoint answers, then treat that response as a live scrape
body = None
for _ in range(100):
    try:
        with urllib.request.urlopen("http://127.0.0.1:9184/metrics", timeout=1) as r:
            assert r.status == 200, f"scrape returned {r.status}"
            body = r.read().decode()
            break
    except OSError:
        time.sleep(0.05)
assert body is not None, "metrics endpoint never came up"

def val(name):
    for line in body.splitlines():
        if line.startswith(name + " "):
            return float(line.split()[1])
    raise AssertionError(f"{name} missing from live scrape")

offered = val("cce_serve_requests_offered")
served = val("cce_serve_requests_served")
rejected = val("cce_serve_requests_rejected")
expired = val("cce_serve_requests_expired")
assert served + rejected + expired <= offered, \
    f"conservation violated on a live scrape: {served}+{rejected}+{expired} > {offered}"
print(f"live /metrics scrape OK (offered={offered:.0f} served={served:.0f})")
PY
    wait "$serve_pid"
    python3 - "$smoke_out" <<'PY'
import json, sys
out = sys.argv[1]

# JSONL stats stream: flat objects, monotone t_ms, and a shutdown-time final
# line whose registry counters satisfy exact conservation
lines = [json.loads(l) for l in open(f"{out}/stats.jsonl") if l.strip()]
assert lines, "stats.jsonl is empty"
t_key = "t_ms"
prev = -1.0
for obj in lines:
    assert isinstance(obj, dict) and t_key in obj, f"stats line without t_ms: {obj}"
    assert obj[t_key] >= prev, "t_ms went backwards in stats.jsonl"
    prev = obj[t_key]
final = lines[-1]
for name in ("serve.requests.offered", "serve.requests.served",
             "serve.requests.rejected", "serve.requests.expired",
             "serve.latency.ns.count"):
    assert name in final, f"final stats line missing {name}"
assert (final["serve.requests.served"] + final["serve.requests.rejected"]
        + final["serve.requests.expired"]) == final["serve.requests.offered"], \
    f"final stats line violates conservation: {final}"

# Chrome trace: a Perfetto-loadable traceEvents document with span events
doc = json.load(open(f"{out}/trace.json"))
evs = doc["traceEvents"]
assert isinstance(evs, list) and evs, "trace.json has no traceEvents"
for e in evs[:16]:
    for k in ("name", "ph", "ts", "pid", "tid"):
        assert k in e, f"trace event missing {k}: {e}"
print(f"telemetry files OK ({len(lines)} stats lines, {len(evs)} trace events)")
PY
    rm -rf "$smoke_out"
  else
    echo "skipped: no $art_dir/index.json (re-run the compiler to build per-field artifacts)"
  fi
fi

echo "verify: OK"
