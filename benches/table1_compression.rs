//! Table 1 — memory-reduction rates: for each method, the parameters
//! needed to reach the baseline BCE, with linear/quadratic extrapolation
//! ranges when the sweep never crosses it (the paper's exact procedure
//! from the Reproducibility appendix).
//!
//! Requires `make artifacts-sweep`. Scaled defaults: 1-epoch sweeps on
//! kaggle_small; `--paper` adds terabyte_sim and the multi-epoch row.

use cce::config::TrainConfig;
use cce::experiments::report::{fmt_compression, Table};
use cce::experiments::sweep::{crossing_for, run_sweep};
use cce::experiments::SweepSpec;
use cce::metrics::extrapolate::{compression_factor, Crossing};
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let paper = std::env::args().any(|a| a == "--paper");
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    let datasets: Vec<(&str, usize)> = if paper {
        vec![("kaggle_small", 196_608), ("terabyte_sim", 393_216)]
    } else {
        vec![("kaggle_small", 196_608)]
    };
    let methods =
        if paper { vec!["cce".to_string(), "ce".into(), "hash".into(), "dhe".into()] } else { vec!["cce".to_string(), "ce".into(), "hash".into()] };

    let mut t = Table::new(
        "Table 1 — memory reduction to reach baseline BCE",
        &["method", "dataset", "epochs", "embedding compression"],
    );

    for (dataset, train_samples) in datasets {
        let n_batches = train_samples.div_ceil(256);
        let caps = if paper {
            vec![64, 256, 1024, 4096, 16384, 65536]
        } else {
            vec![64, 256, 1024]
        };
        let base = TrainConfig {
            epochs: 1,
            cluster_times: 2,
            cluster_every: n_batches / 4,
            ..Default::default()
        };
        let spec = SweepSpec {
            dataset: dataset.into(),
            methods: methods.clone(),
            caps,
            seeds: vec![0],
            base: base.clone(),
        };
        let points = run_sweep(&store, &spec)?;

        // baseline = the full model's test BCE at 1 epoch
        let mut full_cfg = base.clone();
        full_cfg.artifact = spec.artifact_name("full", 0);
        full_cfg.cluster_times = 0;
        if !store.has(&full_cfg.artifact) {
            log::warn!("no full baseline for {dataset}; skipping");
            continue;
        }
        let full = cce::coordinator::train(&store, &full_cfg)?;
        let full_params = full.embedding_params as f64;
        println!(
            "baseline ({dataset}, 1 epoch): BCE {:.5} at {} params",
            full.test_bce, full.embedding_params
        );

        for m in &methods {
            let Some(crossing) = crossing_for(&points, m, full.test_bce) else {
                continue;
            };
            let (hi, lo) = compression_factor(full_params, crossing);
            let label = match crossing {
                Crossing::Measured(_) => fmt_compression(hi, None),
                Crossing::Extrapolated { .. } => fmt_compression(hi, lo),
                Crossing::Unreachable => "— (never reaches baseline)".into(),
            };
            t.row(vec![m.clone(), dataset.into(), "1".into(), label]);
        }
    }
    t.print();
    t.save_csv("table1");
    println!(
        "(Paper, for reference: CCE 212x / CE 127-155x / hash 78-122x / DHE 7-25x on \
         Kaggle @ 1 epoch; CCE 8,500x on ≤10 epochs. Absolute factors differ on the \
         synthetic substrate; the ORDERING is the reproduced claim.)"
    );
    Ok(())
}
