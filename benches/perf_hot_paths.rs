//! Perf micro-benches over the system's hot paths (EXPERIMENTS.md §Perf):
//!
//!   L3: index generation (rowwise/robe/dhe), batch generation, K-means,
//!       AUC, matmul — the coordinator-side costs.
//!   Serving: baked snapshot vs live indexer, engine throughput vs
//!       skew × workers, and the on-disk segment loop — cold start
//!       (bake vs zero-copy mmap load), owned-vs-mapped throughput
//!       parity, and hot-swap pause p99 under load.
//!   Runtime: chained train-step latency + throughput per impl
//!       (pallas vs reference lowering), predict latency, kmeans offload
//!       (rust vs PJRT HLO Lloyd step).
//!
//! Printed as mean ± std so before/after deltas in the §Perf log are
//! directly comparable. The serving-segment group also lands in
//! `bench_results/BENCH_serving.json` (schema `cce.perf_serving.v1`) so
//! cold-start and swap-pause are machine-trackable; `scripts/verify.sh`
//! smoke-runs this bench (`--smoke`) and fails if `cold_start_ns` /
//! `swap_pause_ns` go missing or the mmap load stops beating the bake.
//! The `obs_overhead` group prices the telemetry subsystem (spans + trace
//! ring vs `obs::set_enabled(false)`) and verify.sh fails above the 3%
//! budget docs/OBSERVABILITY.md commits to.
//!
//! The segment group and all L3 groups are store-independent (shapes are
//! inlined); groups needing compiled artifacts are skipped without
//! `make artifacts`.

use cce::data::batch::{BatchIter, Split};
use cce::data::synthetic::DatasetSpec;
use cce::data::SyntheticDataset;
use cce::experiments::report::Table;
use cce::kmeans::{kmeans, KmeansConfig};
use cce::runtime::session::EmbInput;
use cce::runtime::{ArtifactStore, DlrmSession};
use cce::serving::{
    self, segment, AdmissionPolicy, CountingExecutor, EngineConfig, ServingSnapshot,
    SnapshotSlot, TrafficGen,
};
use cce::tables::indexer::Indexer;
use cce::tables::layout::{SubtableId, TablePlan};
use cce::util::timer::{bench, bench_for, fmt_ns, TimingStats};
use cce::util::{Json, Rng};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

/// Mirrors `python/compile/specs.py::KAGGLE_SMALL_VOCABS` — inlined so the
/// bench runs without `make artifacts` (shapes only; no manifest reads).
const KAGGLE_SMALL_VOCABS: [usize; 26] = [
    3, 10, 27, 64, 120, 256, 540, 1_000, 1_450, 2_048, 3_000, 4_096, 6_000, 8_192, 10_000,
    14_000, 20_000, 27_000, 40_000, 55_000, 80_000, 120_000, 160_000, 220_000, 300_000, 420_000,
];

/// Mirrors `specs.py::TERABYTE_SIM_VOCABS`: one binary-order larger tails.
fn terabyte_sim_vocabs() -> Vec<usize> {
    KAGGLE_SMALL_VOCABS
        .iter()
        .map(|&v| if v < 1000 { v } else { (v * 4).min(1_200_000) })
        .collect()
}

/// A synthetic dataset over the bench vocabs, so `TrafficGen`/`BatchIter`
/// run without the artifact store's preset index.
fn bench_dataset(vocabs: &[usize]) -> SyntheticDataset {
    SyntheticDataset::new(DatasetSpec {
        name: "bench".into(),
        vocabs: vocabs.to_vec(),
        n_dense: 13,
        train_samples: 10_000,
        val_samples: 1_000,
        test_samples: 10_000,
        latent_clusters: 8,
        zipf_exponent: 1.05,
        label_noise: 0.05,
        seed: 0,
    })
}

/// A rowwise indexer with half the term-0 subtables learned, so the baked
/// tables cover the post-clustering map mix a deployed CCE model has.
fn bench_indexer(vocabs: &[usize], cap: usize) -> Indexer {
    let plan = TablePlan::new(vocabs, cap, 2, 4, 4);
    let mut rng = Rng::new(0xBA5E);
    let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
    for f in (0..vocabs.len()).step_by(2) {
        if plan.vocabs[f] > plan.k[f] {
            let assignments: Vec<u32> =
                (0..plan.vocabs[f]).map(|v| (v % plan.k[f]) as u32).collect();
            ix.set_learned(SubtableId { feature: f, term: 0, column: 0 }, assignments);
        }
    }
    ix
}

fn stat_json(name: &str, s: &TimingStats, extra: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::from(name));
    m.insert("mean_ns".to_string(), Json::from(s.mean_ns));
    m.insert("std_ns".to_string(), Json::from(s.std_ns));
    m.insert("min_ns".to_string(), Json::from(s.min_ns));
    m.insert("p50_ns".to_string(), Json::from(s.p50_ns));
    m.insert("n".to_string(), Json::from(s.n));
    for (k, v) in extra {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let store = ArtifactStore::open(ArtifactStore::default_dir()).ok();
    if store.is_none() {
        log::warn!("artifact store unavailable; skipping session-backed groups");
    }
    let mode = if smoke { " (smoke)" } else { "" };
    let mut t = Table::new(&format!("perf — hot paths{mode}"), &["path", "timing", "derived"]);
    let mut results: Vec<Json> = Vec::new();

    // ---------------- L3: index generation ------------------------------
    let vocabs: Vec<usize> = KAGGLE_SMALL_VOCABS.to_vec();
    let mut rng = Rng::new(0);
    let b = 256usize;
    let f = vocabs.len();
    let cats: Vec<u32> = (0..b * f)
        .map(|i| (rng.below(vocabs[i % f] as u64)) as u32)
        .collect();
    {
        let plan = TablePlan::new(&vocabs, 4096, 2, 4, 4);
        let ix = Indexer::new_rowwise(&mut rng, plan);
        let mut out = vec![0i32; b * f * 2 * 4];
        let s = bench(3, 50, || ix.fill_rowwise(&cats, b, &mut out));
        t.row(vec![
            "index gen rowwise (B=256, F=26, T=2, c=4)".into(),
            s.display(),
            format!("{:.1} M idx/s", (b * f * 8) as f64 / s.mean_ns * 1e3),
        ]);
    }
    {
        let ix = Indexer::new_robe(&mut rng, &vocabs, 4096, 16, 4);
        let mut out = vec![0i32; b * f * 16];
        let s = bench(3, 50, || ix.fill_elementwise(&cats, b, &mut out));
        t.row(vec![
            "index gen robe (B=256, F=26, d=16)".into(),
            s.display(),
            format!("{:.1} M idx/s", (b * f * 16) as f64 / s.mean_ns * 1e3),
        ]);
    }
    {
        let ix = Indexer::new_dhe(&mut rng, &vocabs, 64);
        let mut out = vec![0f32; b * f * 64];
        let s = bench(3, 20, || ix.fill_dhe(&cats, b, &mut out));
        t.row(vec![
            "hash-features dhe (B=256, F=26, n_hash=64)".into(),
            s.display(),
            format!("{:.1} M hash/s", (b * f * 64) as f64 / s.mean_ns * 1e3),
        ]);
    }

    // ---------------- serving: baked snapshot vs live indexer ----------
    {
        let plan = TablePlan::new(&vocabs, 4096, 2, 4, 4);
        let ix = bench_indexer(&vocabs, 4096);
        let snap = ServingSnapshot::bake(&ix);
        let mut out = vec![0i32; b * f * 2 * 4];
        let s_live = bench(3, 50, || ix.fill_rowwise(&cats, b, &mut out));
        let s_baked = bench(3, 50, || snap.fill_rowwise(&cats, b, &mut out));
        t.row(vec![
            format!("serving: index gen LIVE indexer (B=256, T={}, c={})", plan.t, plan.c),
            s_live.display(),
            format!("{:.1} M idx/s", (b * f * 8) as f64 / s_live.mean_ns * 1e3),
        ]);
        t.row(vec![
            "serving: index gen BAKED snapshot (B=256, T=2, c=4)".into(),
            s_baked.display(),
            format!(
                "{:.1} M idx/s, {:.2}x vs live",
                (b * f * 8) as f64 / s_baked.mean_ns * 1e3,
                s_live.mean_ns / s_baked.mean_ns
            ),
        ]);
    }

    // ---------------- serving: engine throughput vs skew × workers ------
    let requests = if smoke { 4_000 } else { 20_000 };
    {
        let ds = bench_dataset(&vocabs);
        let ix = bench_indexer(&vocabs, 4096);
        let slot = SnapshotSlot::new(ServingSnapshot::bake(&ix));
        for skew in [0.0f64, 0.99] {
            for workers in [1usize, 4] {
                let cfg = EngineConfig {
                    workers,
                    max_batch: 256,
                    max_wait: Duration::from_micros(200),
                    queue_depth: 4096,
                    admission: AdmissionPolicy::Block,
                    pace: None,
                };
                let mut exec = CountingExecutor::new(256);
                let traffic = TrafficGen::new(&ds, skew, 11);
                let rep = serving::run(&mut exec, &slot, traffic, &cfg, requests)?;
                t.row(vec![
                    format!(
                        "serving: engine zipf={skew} workers={workers} ({}k req)",
                        requests / 1000
                    ),
                    format!(
                        "{:.0}k req/s, p50 {}, p99 {}",
                        rep.throughput_rps / 1e3,
                        fmt_ns(rep.latency.p50_ns),
                        fmt_ns(rep.latency.p99_ns)
                    ),
                    format!("{} batches, {} padded", rep.batches, rep.padded_rows),
                ]);
            }
        }
    }

    // ---------------- serving: segment cold start (bake vs mmap load) ---
    // the ISSUE acceptance shape: zero-copy load must beat a fresh bake by
    // ≥10x at the terabyte-ish preset. Quick loads verify the header only
    // (O(264 bytes)), which is what keeps cold start O(header) not O(table).
    let kaggle: Vec<usize> = if smoke {
        KAGGLE_SMALL_VOCABS.iter().step_by(5).copied().collect()
    } else {
        KAGGLE_SMALL_VOCABS.to_vec()
    };
    let terabyte: Vec<usize> = if smoke {
        terabyte_sim_vocabs().into_iter().step_by(7).collect()
    } else {
        terabyte_sim_vocabs()
    };
    let kaggle_cap = if smoke { 256 } else { 4096 };
    let presets: [(&str, &[usize], usize); 2] = [
        ("kaggle-small", &kaggle, kaggle_cap),
        ("terabyte-ish", &terabyte, if smoke { 512 } else { 2048 }),
    ];
    let reps = if smoke { 3 } else { 5 };
    let mut seg_paths = Vec::new();
    for &(preset, pvocabs, cap) in &presets {
        let ix = bench_indexer(pvocabs, cap);
        let s_bake = {
            let mut samples = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = Instant::now();
                std::hint::black_box(ServingSnapshot::bake(&ix));
                samples.push(t0.elapsed().as_nanos() as f64);
            }
            TimingStats::from_samples(samples)
        };
        let snap = ServingSnapshot::bake(&ix);
        let path = std::env::temp_dir()
            .join(format!("cce_bench_{}_{preset}.cceseg", std::process::id()));
        let file_bytes = serving::write_segment(&snap, 0, &path)?;
        let s_load = {
            let mut samples = Vec::with_capacity(reps);
            for _ in 0..reps {
                let t0 = Instant::now();
                let loaded = segment::load_segment(&path)?;
                std::hint::black_box(loaded.snapshot.host_bytes());
                samples.push(t0.elapsed().as_nanos() as f64);
            }
            TimingStats::from_samples(samples)
        };
        let speedup = s_bake.mean_ns / s_load.mean_ns.max(1.0);
        let label = format!("segment cold start {preset} (cap={cap})");
        t.row(vec![
            label.clone(),
            format!("load {}", s_load.display()),
            format!(
                "bake {} — {speedup:.0}x faster, {:.1} MB mapped",
                fmt_ns(s_bake.mean_ns),
                file_bytes as f64 / 1e6
            ),
        ]);
        results.push(stat_json(
            &label,
            &s_load,
            vec![
                ("group", Json::from("cold_start")),
                ("preset", Json::from(preset)),
                ("cold_start_ns", Json::from(s_load.mean_ns)),
                ("bake_ns", Json::from(s_bake.mean_ns)),
                ("speedup", Json::from(speedup)),
                ("file_bytes", Json::from(file_bytes as f64)),
            ],
        ));
        seg_paths.push((preset, path));
    }

    // ---------------- serving: owned vs mapped throughput parity --------
    // same engine, same traffic; the only variable is whether the workers
    // gather from freshly-baked Vecs or from the mmapped segment sections
    {
        let ds = bench_dataset(&kaggle);
        let ix = bench_indexer(&kaggle, kaggle_cap);
        let kaggle_seg = &seg_paths[0].1;
        let cfg = EngineConfig {
            workers: 4,
            max_batch: 256,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            admission: AdmissionPolicy::Block,
            pace: None,
        };
        let run_with = |snap: ServingSnapshot| -> anyhow::Result<serving::ServeReport> {
            let slot = SnapshotSlot::new(snap);
            let mut exec = CountingExecutor::new(256);
            let traffic = TrafficGen::new(&ds, 0.99, 11);
            serving::run(&mut exec, &slot, traffic, &cfg, requests)
        };
        let rep_owned = run_with(ServingSnapshot::bake(&ix))?;
        let loaded = segment::load_segment(kaggle_seg)?;
        let mapped = loaded.snapshot.is_mapped();
        let rep_mapped = run_with(loaded.snapshot)?;
        let parity = rep_mapped.throughput_rps / rep_owned.throughput_rps.max(1.0);
        let label = format!("segment load parity kaggle-small (mapped={mapped})");
        t.row(vec![
            label.clone(),
            format!(
                "owned {:.0}k req/s, mapped {:.0}k req/s",
                rep_owned.throughput_rps / 1e3,
                rep_mapped.throughput_rps / 1e3
            ),
            format!("{:.2}x of owned", parity),
        ]);
        results.push(stat_json(
            &label,
            &rep_mapped.latency,
            vec![
                ("group", Json::from("load_parity")),
                ("throughput_owned_rps", Json::from(rep_owned.throughput_rps)),
                ("throughput_mapped_rps", Json::from(rep_mapped.throughput_rps)),
                ("parity", Json::from(parity)),
            ],
        ));
    }

    // ---------------- serving: hot-swap pause p99 under load -------------
    // a swapper thread live-installs the segment (load + compat check +
    // slot swap) while the engine serves; the install latency is the only
    // "pause" a swap can cause — workers never block on it beyond the
    // refcount-bump critical section
    {
        let ds = bench_dataset(&kaggle);
        let ix = bench_indexer(&kaggle, kaggle_cap);
        let kaggle_seg = &seg_paths[0].1;
        let slot = SnapshotSlot::new(ServingSnapshot::bake(&ix));
        let cfg = EngineConfig {
            workers: 4,
            max_batch: 256,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            admission: AdmissionPolicy::Block,
            pace: None,
        };
        let stop = AtomicBool::new(false);
        type SwapRun = (serving::ServeReport, Vec<f64>);
        let (rep, samples) = std::thread::scope(|scope| -> anyhow::Result<SwapRun> {
            let swapper = scope.spawn(|| {
                let mut samples = Vec::new();
                // ORDERING: Relaxed stop flag — samples travel through the
                // join, the flag publishes nothing
                while !stop.load(Ordering::Relaxed) {
                    let t0 = Instant::now();
                    slot.install_snapshot(kaggle_seg).expect("swap must stay compatible");
                    samples.push(t0.elapsed().as_nanos() as f64);
                    std::thread::sleep(Duration::from_micros(500));
                }
                samples
            });
            let mut exec = CountingExecutor::new(256);
            let traffic = TrafficGen::new(&ds, 0.99, 11);
            let rep = serving::run(&mut exec, &slot, traffic, &cfg, requests);
            // ORDERING: Relaxed stop flag — see the load above
            stop.store(true, Ordering::Relaxed);
            let samples = swapper.join().expect("swapper thread panicked");
            Ok((rep?, samples))
        })?;
        let s_swap = TimingStats::from_samples(samples);
        let label = "segment hot swap kaggle-small (install under load)".to_string();
        t.row(vec![
            label.clone(),
            format!("install p50 {}, p99 {}", fmt_ns(s_swap.p50_ns), fmt_ns(s_swap.p99_ns)),
            format!(
                "{} installs, {} reached device, {:.0}k req/s held",
                s_swap.n,
                rep.snapshot_swaps,
                rep.throughput_rps / 1e3
            ),
        ]);
        results.push(stat_json(
            &label,
            &s_swap,
            vec![
                ("group", Json::from("hot_swap")),
                ("swap_pause_ns", Json::from(s_swap.p99_ns)),
                ("installs", Json::from(s_swap.n)),
                ("swaps_reached_device", Json::from(rep.snapshot_swaps)),
                ("throughput_rps", Json::from(rep.throughput_rps)),
            ],
        ));
    }
    for (_, path) in &seg_paths {
        let _ = std::fs::remove_file(path);
    }

    // ---------------- serving: p99 under overload (block vs shed) --------
    // The robustness acceptance shape: drive the engine at offered loads
    // {0.5, 1, 2, 4}x its measured capacity under skew 0.99. Block admission
    // lets the backlog (and therefore arrival-to-done p99) grow without
    // bound past 1x; Shed admission (bounded queue + deadline) keeps p99
    // within a small factor of the uncontended p99 and reports what it
    // dropped instead. verify.sh gates on exactly that separation.
    {
        let ds = bench_dataset(&kaggle);
        let ix = bench_indexer(&kaggle, kaggle_cap);
        let slot = SnapshotSlot::new(ServingSnapshot::bake(&ix));
        let over_requests = if smoke { 4_000 } else { 12_000 };
        let base_cfg = EngineConfig {
            workers: 4,
            max_batch: 256,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            admission: AdmissionPolicy::Block,
            pace: None,
        };
        // calibrate capacity: unpaced, unbounded-queue run. Traffic is
        // pregenerated so synthesis cost never throttles the offered rate
        // here or in the paced runs below.
        let mut exec = CountingExecutor::new(256);
        let mut traffic = TrafficGen::new(&ds, 0.99, 11);
        traffic.pregenerate(over_requests);
        let cal = serving::run(&mut exec, &slot, traffic, &base_cfg, over_requests)?;
        let capacity_rps = cal.throughput_rps.max(1.0);
        // deadline: generous vs the uncontended tail (20x p99, >= 1 ms) so
        // at sane loads nothing expires and under overload it bounds the
        // staleness of anything that still reaches the device
        let deadline = Duration::from_nanos((cal.latency.p99_ns * 20.0) as u64)
            .max(Duration::from_millis(1));
        t.row(vec![
            "overload calibration kaggle-small (unpaced)".into(),
            format!("{:.0}k req/s capacity", capacity_rps / 1e3),
            format!("p99 {} → deadline {:?}", fmt_ns(cal.latency.p99_ns), deadline),
        ]);
        for mult in [0.5f64, 1.0, 2.0, 4.0] {
            let offered_rps = capacity_rps * mult;
            let pace = Duration::from_nanos((1e9 / offered_rps) as u64);
            for (mode, admission) in [
                ("block", AdmissionPolicy::Block),
                (
                    "shed",
                    AdmissionPolicy::Shed {
                        queue_depth: 8 * base_cfg.max_batch,
                        deadline: Some(deadline),
                    },
                ),
            ] {
                let cfg = EngineConfig {
                    admission,
                    pace: Some(pace),
                    ..base_cfg.clone()
                };
                let mut exec = CountingExecutor::new(256);
                let mut traffic = TrafficGen::new(&ds, 0.99, 11);
                traffic.pregenerate(over_requests);
                let rep = serving::run(&mut exec, &slot, traffic, &cfg, over_requests)?;
                let label = format!("overload kaggle-small {mode} {mult}x");
                t.row(vec![
                    label.clone(),
                    format!(
                        "p50 {}, p99 {}",
                        fmt_ns(rep.latency.p50_ns),
                        fmt_ns(rep.latency.p99_ns)
                    ),
                    format!(
                        "shed {:.1}%, miss {:.1}%, goodput {:.0}k req/s",
                        rep.shed_rate * 100.0,
                        rep.deadline_miss_rate * 100.0,
                        rep.goodput_rps / 1e3
                    ),
                ]);
                results.push(stat_json(
                    &label,
                    &rep.latency,
                    vec![
                        ("group", Json::from("overload")),
                        ("mode", Json::from(mode)),
                        ("load_mult", Json::from(mult)),
                        ("offered_rps", Json::from(offered_rps)),
                        ("p99_ns", Json::from(rep.latency.p99_ns)),
                        ("shed_rate", Json::from(rep.shed_rate)),
                        ("deadline_miss_rate", Json::from(rep.deadline_miss_rate)),
                        ("goodput_rps", Json::from(rep.goodput_rps)),
                        ("throughput_rps", Json::from(rep.throughput_rps)),
                    ],
                ));
            }
        }
    }

    // ---------------- serving: observability overhead --------------------
    // the ≤3% budget docs/OBSERVABILITY.md commits to: the same engine run
    // with spans + trace ring hot vs `obs::set_enabled(false)`. Counters
    // and gauges stay on in BOTH runs — they are the always-on baseline
    // the reports are derived from, not optional instrumentation.
    {
        let ds = bench_dataset(&kaggle);
        let ix = bench_indexer(&kaggle, kaggle_cap);
        let slot = SnapshotSlot::new(ServingSnapshot::bake(&ix));
        let cfg = EngineConfig {
            workers: 4,
            max_batch: 256,
            max_wait: Duration::from_micros(200),
            queue_depth: 4096,
            admission: AdmissionPolicy::Block,
            pace: None,
        };
        let obs_requests = if smoke { 6_000 } else { 20_000 };
        let run_once = || -> anyhow::Result<f64> {
            let mut exec = CountingExecutor::new(256);
            let mut traffic = TrafficGen::new(&ds, 0.99, 11);
            traffic.pregenerate(obs_requests);
            Ok(serving::run(&mut exec, &slot, traffic, &cfg, obs_requests)?.throughput_rps)
        };
        // best-of-3 after a warmup run per mode: throughput is noisy and
        // the gate is a ratio, so damp scheduler jitter on both sides
        cce::obs::trace::enable(cce::obs::trace::DEFAULT_RING_CAP);
        cce::obs::set_enabled(true);
        let _ = run_once()?;
        let mut on = 0f64;
        for _ in 0..3 {
            on = on.max(run_once()?);
        }
        cce::obs::set_enabled(false);
        let _ = run_once()?;
        let mut off = 0f64;
        for _ in 0..3 {
            off = off.max(run_once()?);
        }
        cce::obs::set_enabled(true);
        let overhead = (off - on).max(0.0) / off.max(1.0);
        let label = "obs overhead kaggle-small (spans+trace vs disabled)".to_string();
        t.row(vec![
            label.clone(),
            format!("instrumented {:.0}k req/s, disabled {:.0}k req/s", on / 1e3, off / 1e3),
            format!("{:.2}% overhead", overhead * 100.0),
        ]);
        results.push(stat_json(
            &label,
            &TimingStats::empty(),
            vec![
                ("group", Json::from("obs_overhead")),
                ("throughput_instrumented_rps", Json::from(on)),
                ("throughput_disabled_rps", Json::from(off)),
                ("overhead_frac", Json::from(overhead)),
            ],
        ));
    }

    // ---------------- L3: batch generation ------------------------------
    {
        let ds = bench_dataset(&vocabs);
        let mut it = BatchIter::new(&ds, Split::Train, 256, None);
        let mut batch = it.alloc_batch();
        let s = bench(2, 30, || {
            if !it.next_into(&mut batch) {
                it = BatchIter::new(&ds, Split::Train, 256, None);
                it.next_into(&mut batch);
            }
        });
        t.row(vec![
            "batch generation (B=256, kaggle-small shape)".into(),
            s.display(),
            format!("{:.0}k samples/s", 256.0 / s.mean_ns * 1e6),
        ]);
    }

    // ---------------- L3: K-means (the clustering-event cost) -----------
    {
        let mut rng = Rng::new(1);
        let n = if smoke { 8_192 } else { 65_536 };
        let d = 4;
        let k = if smoke { 256 } else { 4096 };
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let s = bench(1, 3, || {
            let _ = kmeans(
                &pts,
                d,
                &KmeansConfig { k, n_iter: 10, seed: 2, ..Default::default() },
            );
        });
        t.row(vec![
            format!("kmeans {n} pts, d={d}, k={k}, 10 iters"),
            s.display(),
            format!("{:.1} M pt·iter/s", (n * 10) as f64 / s.mean_ns * 1e3),
        ]);
    }

    // ---------------- runtime: train/predict per impl -------------------
    if let Some(store) = &store {
        for artifact in ["quick_cce", "quick_cce_ref"] {
            if !store.has(artifact) {
                continue;
            }
            let mut session = DlrmSession::open(store, artifact)?;
            let m = session.manifest.clone();
            let mut rng = Rng::new(3);
            let state = cce::tables::init::init_state(&m.layout, m.state_size, &mut rng);
            session.set_state(&state)?;
            let plan = TablePlan::new(&m.vocabs, m.spec.cap, m.spec.t, m.spec.c, m.spec.dc);
            let ix = Indexer::new_rowwise(&mut rng, plan);
            let dense = vec![0.1f32; m.spec.batch * m.spec.n_dense];
            let labels = vec![1.0f32; m.spec.batch];
            let mut rows = vec![0i32; session.emb_elems("train")?];
            let cats: Vec<u32> = (0..m.spec.batch * m.vocabs.len())
                .map(|i| (rng.below(m.vocabs[i % m.vocabs.len()] as u64)) as u32)
                .collect();
            ix.fill_rowwise(&cats, m.spec.batch, &mut rows);
            let s = bench_for(3, Duration::from_secs(2), || {
                session.train_step(&dense, EmbInput::Rows(&rows), &labels).unwrap();
            });
            t.row(vec![
                format!("train step {artifact} (B={})", m.spec.batch),
                s.display(),
                format!("{:.1}k samples/s", m.spec.batch as f64 / s.mean_ns * 1e6),
            ]);
            // predict
            let mut prows = vec![0i32; session.emb_elems("predict")?];
            let pcats: Vec<u32> = (0..m.spec.eval_batch * m.vocabs.len())
                .map(|i| (rng.below(m.vocabs[i % m.vocabs.len()] as u64)) as u32)
                .collect();
            ix.fill_rowwise(&pcats, m.spec.eval_batch, &mut prows);
            let pdense = vec![0.1f32; m.spec.eval_batch * m.spec.n_dense];
            let s = bench_for(2, Duration::from_secs(1), || {
                let _ = session.predict(&pdense, EmbInput::Rows(&prows)).unwrap();
            });
            t.row(vec![
                format!("predict {artifact} (B={})", m.spec.eval_batch),
                s.display(),
                format!("{:.1}k samples/s", m.spec.eval_batch as f64 / s.mean_ns * 1e6),
            ]);
        }
    }

    // ---------------- runtime: K-means offload ablation ------------------
    if let Some(store) = store.as_ref().filter(|s| s.has("kmeans_quick")) {
        let m = store.manifest("kmeans_quick")?;
        let exe = store.compile(&m, "step")?;
        let n = m.inputs["step"][0].shape[0];
        let d = m.inputs["step"][0].shape[1];
        let k = m.inputs["step"][1].shape[0];
        let mut rng = Rng::new(4);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let cen: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
        let (pts_b, cen_b) = cce::runtime::with_client(|c| {
            Ok((
                c.buffer_from_host_buffer(&pts, &[n, d], None)?,
                c.buffer_from_host_buffer(&cen, &[k, d], None)?,
            ))
        })?;
        let s_hlo = bench(1, 5, || {
            let _ = exe.execute_b(&[&pts_b, &cen_b]).unwrap();
        });
        t.row(vec![
            format!("kmeans Lloyd step HLO offload (n={n}, k={k})"),
            s_hlo.display(),
            String::new(),
        ]);
        let s_rust = bench(1, 5, || {
            let mut asg = vec![0u32; n];
            cce::kmeans::assign(&pts, &cen, d, &mut asg);
        });
        t.row(vec![
            format!("kmeans assign rust (n={n}, k={k})"),
            s_rust.display(),
            format!("offload speedup {:.2}x", s_rust.mean_ns / s_hlo.mean_ns),
        ]);
    }

    // ---------------- metrics ------------------------------------------
    {
        let mut rng = Rng::new(5);
        let scores: Vec<(f32, bool)> =
            (0..100_000).map(|_| (rng.uniform() as f32, rng.bernoulli(0.3))).collect();
        let s = bench(2, 20, || {
            let _ = cce::metrics::auc(&scores);
        });
        t.row(vec![
            "AUC over 100k scores".into(),
            s.display(),
            format!("{}/sample", fmt_ns(s.mean_ns / 1e5)),
        ]);
    }

    t.print();
    t.save_csv("perf_hot_paths");

    // ---------------- BENCH_serving.json ---------------------------------
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::from("cce.perf_serving.v1"));
    doc.insert("mode".to_string(), Json::from(if smoke { "smoke" } else { "full" }));
    doc.insert("results".to_string(), Json::Arr(results));
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_serving.json");
    std::fs::write(&path, Json::Obj(doc).to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}
