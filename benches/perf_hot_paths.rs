//! Perf micro-benches over the system's hot paths (EXPERIMENTS.md §Perf):
//!
//!   L3: index generation (rowwise/robe/dhe), batch generation, K-means,
//!       AUC, matmul — the coordinator-side costs.
//!   Runtime: chained train-step latency + throughput per impl
//!       (pallas vs reference lowering), predict latency, kmeans offload
//!       (rust vs PJRT HLO Lloyd step).
//!
//! Printed as mean ± std so before/after deltas in the §Perf log are
//! directly comparable.

use cce::data::batch::{BatchIter, Split};
use cce::data::SyntheticDataset;
use cce::experiments::report::Table;
use cce::kmeans::{kmeans, KmeansConfig};
use cce::runtime::session::EmbInput;
use cce::runtime::{ArtifactStore, DlrmSession};
use cce::serving::{self, CountingExecutor, EngineConfig, ServingSnapshot, TrafficGen};
use cce::tables::indexer::Indexer;
use cce::tables::layout::{SubtableId, TablePlan};
use cce::util::timer::{bench, bench_for, fmt_ns};
use cce::util::Rng;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    let mut t = Table::new("perf — hot paths", &["path", "timing", "derived"]);

    // ---------------- L3: index generation ------------------------------
    let vocabs: Vec<usize> = cce::data::SyntheticDataset::new(store.dataset("kaggle_small", 0)?)
        .spec
        .vocabs
        .clone();
    let mut rng = Rng::new(0);
    let b = 256usize;
    let f = vocabs.len();
    let cats: Vec<u32> = (0..b * f)
        .map(|i| (rng.below(vocabs[i % f] as u64)) as u32)
        .collect();
    {
        let plan = TablePlan::new(&vocabs, 4096, 2, 4, 4);
        let ix = Indexer::new_rowwise(&mut rng, plan);
        let mut out = vec![0i32; b * f * 2 * 4];
        let s = bench(3, 50, || ix.fill_rowwise(&cats, b, &mut out));
        t.row(vec![
            "index gen rowwise (B=256, F=26, T=2, c=4)".into(),
            s.display(),
            format!("{:.1} M idx/s", (b * f * 8) as f64 / s.mean_ns * 1e3),
        ]);
    }
    {
        let ix = Indexer::new_robe(&mut rng, &vocabs, 4096, 16, 4);
        let mut out = vec![0i32; b * f * 16];
        let s = bench(3, 50, || ix.fill_elementwise(&cats, b, &mut out));
        t.row(vec![
            "index gen robe (B=256, F=26, d=16)".into(),
            s.display(),
            format!("{:.1} M idx/s", (b * f * 16) as f64 / s.mean_ns * 1e3),
        ]);
    }
    {
        let ix = Indexer::new_dhe(&mut rng, &vocabs, 64);
        let mut out = vec![0f32; b * f * 64];
        let s = bench(3, 20, || ix.fill_dhe(&cats, b, &mut out));
        t.row(vec![
            "hash-features dhe (B=256, F=26, n_hash=64)".into(),
            s.display(),
            format!("{:.1} M hash/s", (b * f * 64) as f64 / s.mean_ns * 1e3),
        ]);
    }

    // ---------------- serving: baked snapshot vs live indexer ----------
    {
        let plan = TablePlan::new(&vocabs, 4096, 2, 4, 4);
        let mut ix = Indexer::new_rowwise(&mut rng, plan.clone());
        // learn half the term-0 subtables so the baked path covers the
        // post-clustering map mix a deployed CCE model actually has
        for f in (0..vocabs.len()).step_by(2) {
            if plan.vocabs[f] > plan.k[f] {
                let assignments: Vec<u32> =
                    (0..plan.vocabs[f]).map(|v| (v % plan.k[f]) as u32).collect();
                ix.set_learned(SubtableId { feature: f, term: 0, column: 0 }, assignments);
            }
        }
        let snap = ServingSnapshot::bake(&ix);
        let mut out = vec![0i32; b * f * 2 * 4];
        let s_live = bench(3, 50, || ix.fill_rowwise(&cats, b, &mut out));
        let s_baked = bench(3, 50, || snap.fill_rowwise(&cats, b, &mut out));
        t.row(vec![
            "serving: index gen LIVE indexer (B=256, T=2, c=4)".into(),
            s_live.display(),
            format!("{:.1} M idx/s", (b * f * 8) as f64 / s_live.mean_ns * 1e3),
        ]);
        t.row(vec![
            "serving: index gen BAKED snapshot (B=256, T=2, c=4)".into(),
            s_baked.display(),
            format!(
                "{:.1} M idx/s, {:.2}x vs live",
                (b * f * 8) as f64 / s_baked.mean_ns * 1e3,
                s_live.mean_ns / s_baked.mean_ns
            ),
        ]);
    }

    // ---------------- serving: engine throughput vs skew × workers ------
    {
        let ds = SyntheticDataset::new(store.dataset("kaggle_small", 0)?);
        let mut rng = Rng::new(7);
        let plan = TablePlan::new(&ds.spec.vocabs, 4096, 2, 4, 4);
        let ix = Indexer::new_rowwise(&mut rng, plan);
        let snap = ServingSnapshot::bake(&ix);
        let requests = 20_000;
        for skew in [0.0f64, 0.99] {
            for workers in [1usize, 4] {
                let cfg = EngineConfig {
                    workers,
                    max_batch: 256,
                    max_wait: Duration::from_micros(200),
                    queue_depth: 4096,
                };
                let mut exec = CountingExecutor::new(256);
                let traffic = TrafficGen::new(&ds, skew, 11);
                let rep = serving::run(&mut exec, &snap, traffic, &cfg, requests)?;
                t.row(vec![
                    format!("serving: engine zipf={skew} workers={workers} (20k req)"),
                    format!(
                        "{:.0}k req/s, p50 {}, p99 {}",
                        rep.throughput_rps / 1e3,
                        fmt_ns(rep.latency.p50_ns),
                        fmt_ns(rep.latency.p99_ns)
                    ),
                    format!("{} batches, {} padded", rep.batches, rep.padded_rows),
                ]);
            }
        }
    }

    // ---------------- L3: batch generation ------------------------------
    {
        let ds = SyntheticDataset::new(store.dataset("kaggle_small", 0)?);
        let mut it = BatchIter::new(&ds, Split::Train, 256, None);
        let mut batch = it.alloc_batch();
        let s = bench(2, 30, || {
            if !it.next_into(&mut batch) {
                it = BatchIter::new(&ds, Split::Train, 256, None);
                it.next_into(&mut batch);
            }
        });
        t.row(vec![
            "batch generation (B=256, kaggle_small)".into(),
            s.display(),
            format!("{:.0}k samples/s", 256.0 / s.mean_ns * 1e6),
        ]);
    }

    // ---------------- L3: K-means (the clustering-event cost) -----------
    {
        let mut rng = Rng::new(1);
        let n = 65_536;
        let d = 4;
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let s = bench(1, 3, || {
            let _ = kmeans(
                &pts,
                d,
                &KmeansConfig { k: 4096, n_iter: 10, seed: 2, ..Default::default() },
            );
        });
        t.row(vec![
            "kmeans 65k pts, d=4, k=4096, 10 iters".into(),
            s.display(),
            format!("{:.1} M pt·iter/s", (n * 10) as f64 / s.mean_ns * 1e3),
        ]);
    }

    // ---------------- runtime: train/predict per impl -------------------
    for artifact in ["quick_cce", "quick_cce_ref"] {
        if !store.has(artifact) {
            continue;
        }
        let mut session = DlrmSession::open(&store, artifact)?;
        let m = session.manifest.clone();
        let mut rng = Rng::new(3);
        let state = cce::tables::init::init_state(&m.layout, m.state_size, &mut rng);
        session.set_state(&state)?;
        let plan = TablePlan::new(&m.vocabs, m.spec.cap, m.spec.t, m.spec.c, m.spec.dc);
        let ix = Indexer::new_rowwise(&mut rng, plan);
        let dense = vec![0.1f32; m.spec.batch * m.spec.n_dense];
        let labels = vec![1.0f32; m.spec.batch];
        let mut rows = vec![0i32; session.emb_elems("train")?];
        let cats: Vec<u32> = (0..m.spec.batch * m.vocabs.len())
            .map(|i| (rng.below(m.vocabs[i % m.vocabs.len()] as u64)) as u32)
            .collect();
        ix.fill_rowwise(&cats, m.spec.batch, &mut rows);
        let s = bench_for(3, Duration::from_secs(2), || {
            session.train_step(&dense, EmbInput::Rows(&rows), &labels).unwrap();
        });
        t.row(vec![
            format!("train step {artifact} (B={})", m.spec.batch),
            s.display(),
            format!("{:.1}k samples/s", m.spec.batch as f64 / s.mean_ns * 1e6),
        ]);
        // predict
        let mut prows = vec![0i32; session.emb_elems("predict")?];
        let pcats: Vec<u32> = (0..m.spec.eval_batch * m.vocabs.len())
            .map(|i| (rng.below(m.vocabs[i % m.vocabs.len()] as u64)) as u32)
            .collect();
        ix.fill_rowwise(&pcats, m.spec.eval_batch, &mut prows);
        let pdense = vec![0.1f32; m.spec.eval_batch * m.spec.n_dense];
        let s = bench_for(2, Duration::from_secs(1), || {
            let _ = session.predict(&pdense, EmbInput::Rows(&prows)).unwrap();
        });
        t.row(vec![
            format!("predict {artifact} (B={})", m.spec.eval_batch),
            s.display(),
            format!("{:.1}k samples/s", m.spec.eval_batch as f64 / s.mean_ns * 1e6),
        ]);
    }

    // ---------------- runtime: K-means offload ablation ------------------
    if store.has("kmeans_quick") {
        let m = store.manifest("kmeans_quick")?;
        let exe = store.compile(&m, "step")?;
        let n = m.inputs["step"][0].shape[0];
        let d = m.inputs["step"][0].shape[1];
        let k = m.inputs["step"][1].shape[0];
        let mut rng = Rng::new(4);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let cen: Vec<f32> = (0..k * d).map(|_| rng.normal() as f32).collect();
        let (pts_b, cen_b) = cce::runtime::with_client(|c| {
            Ok((
                c.buffer_from_host_buffer(&pts, &[n, d], None)?,
                c.buffer_from_host_buffer(&cen, &[k, d], None)?,
            ))
        })?;
        let s_hlo = bench(1, 5, || {
            let _ = exe.execute_b(&[&pts_b, &cen_b]).unwrap();
        });
        t.row(vec![
            format!("kmeans Lloyd step HLO offload (n={n}, k={k})"),
            s_hlo.display(),
            String::new(),
        ]);
        let s_rust = bench(1, 5, || {
            let mut asg = vec![0u32; n];
            cce::kmeans::assign(&pts, &cen, d, &mut asg);
        });
        t.row(vec![
            format!("kmeans assign rust (n={n}, k={k})"),
            s_rust.display(),
            format!("offload speedup {:.2}x", s_rust.mean_ns / s_hlo.mean_ns),
        ]);
    }

    // ---------------- metrics ------------------------------------------
    {
        let mut rng = Rng::new(5);
        let scores: Vec<(f32, bool)> =
            (0..100_000).map(|_| (rng.uniform() as f32, rng.bernoulli(0.3))).collect();
        let s = bench(2, 20, || {
            let _ = cce::metrics::auc(&scores);
        });
        t.row(vec![
            "AUC over 100k scores".into(),
            s.display(),
            format!("{}/sample", fmt_ns(s.mean_ns / 1e5)),
        ]);
    }

    t.print();
    t.save_csv("perf_hot_paths");
    Ok(())
}
