//! Figure 8 + Theorem 3.1 — convergence of Dense CCE vs the proven bound.
//!
//! The paper draws X, Y iid standard normal and shows the measured loss of
//! Algorithm 1 tracks the `(1−ρ)^{ik}` envelope closely. We print measured
//! mean loss (over seeds), the ρ-bound, and the idealized 1/d₁ bound.

use cce::cce::{dense_cce, optimal_loss, theory, DenseCceOptions, NoiseKind};
use cce::experiments::report::Table;
use cce::linalg::Matrix;
use cce::util::Rng;

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let (n, d1, d2, k, iters, seeds) =
        if paper { (2_000, 400, 5, 40, 30, 10) } else { (800, 150, 5, 25, 20, 6) };
    let mut rng = Rng::new(0);
    let x = Matrix::randn(&mut rng, n, d1);
    let y = Matrix::randn(&mut rng, n, d2);
    let opt = optimal_loss(&x, &y);
    let bp = theory::bound_params(&x, &y);

    let mut mean = vec![0f64; iters + 1];
    let mut mean_half = vec![0f64; iters + 1];
    for seed in 0..seeds {
        let tr = dense_cce(
            &x,
            &y,
            &DenseCceOptions {
                k, iterations: iters, noise: NoiseKind::Iid, half_update: false, seed: seed as u64,
            },
        );
        let trh = dense_cce(
            &x,
            &y,
            &DenseCceOptions {
                k, iterations: iters, noise: NoiseKind::Iid, half_update: true, seed: seed as u64,
            },
        );
        for i in 0..=iters {
            mean[i] += tr.losses[i] / seeds as f64;
            mean_half[i] += trh.losses[i] / seeds as f64;
        }
    }

    let mut t = Table::new(
        &format!(
            "Figure 8 — Dense CCE vs Theorem 3.1 (X {n}x{d1}, Y {n}x{d2}, k={k}, {seeds} seeds; \
             rho={:.3e}, 1/d1={:.3e})",
            bp.rho, bp.rho_smart
        ),
        &["iter", "measured (full M)", "measured (M=[I|M'])", "bound (rho)", "bound (1/d1)"],
    );
    let mut violations = 0;
    for i in 0..=iters {
        let b_rho = bp.bound_at(i, k, d2, false);
        let b_d1 = bp.bound_at(i, k, d2, true);
        if mean_half[i] > b_rho * 1.1 {
            violations += 1;
        }
        t.row(vec![
            i.to_string(),
            format!("{:.4e}", mean[i] - opt),
            format!("{:.4e}", mean_half[i] - opt),
            format!("{:.4e}", b_rho - bp.floor),
            format!("{:.4e}", b_d1 - bp.floor),
        ]);
    }
    t.print();
    t.save_csv("fig8_convergence");
    println!(
        "bound violations (measured [I|M'] > 1.1x rho-bound): {violations} / {} \
         — Theorem 3.1 holds in expectation ✓",
        iters + 1
    );
    assert_eq!(violations, 0, "measured loss crossed the Theorem 3.1 envelope");
    // full-M is at least as good as the analyzed restricted form
    for i in 0..=iters {
        assert!(mean[i] <= mean_half[i] * 1.05, "full-M update should dominate at iter {i}");
    }
}
