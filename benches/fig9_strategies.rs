//! Figure 9 — CCE clustering strategies: how many clusterings (ct) and how
//! far apart (cf). The paper's findings: more clusterings help (9a);
//! clusterings must FINISH early enough for the model to re-converge (9b
//! vs 9c); spacing them out helps (9d).
//!
//! We grid (ct, cf) at a fixed budget on kaggle_small, 1–2 epochs.

use cce::config::TrainConfig;
use cce::experiments::report::Table;
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let paper = std::env::args().any(|a| a == "--paper");
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;
    let artifact = "sweep_kaggle_small_cce_1024"; // kaggle_small @ 1024 cap
    let n_batches = 196_608usize.div_ceil(256); // 768

    // (label, ct, cf, epochs)
    let mut grid: Vec<(String, usize, usize, usize)> = vec![
        ("no clustering (CE-like)".into(), 0, 0, 1),
        ("ct1 cf=1/2 epoch".into(), 1, n_batches / 2, 1),
        ("ct2 cf=1/4 epoch (strategy 1)".into(), 2, n_batches / 4, 1),
        ("ct2 cf=1/3 epoch (finishes 2/3, strategy 2)".into(), 2, n_batches / 3, 1),
    ];
    if paper {
        grid.push(("ct6 cf=1 epoch, 8 epochs (fig4a winner)".into(), 6, n_batches, 8));
        grid.push(("ct2 cf=1 epoch, 8 epochs".into(), 2, n_batches, 8));
    }

    let mut t = Table::new(
        "Figure 9 — CCE strategies (quick_cce, kaggle_small @ 4096 rows)",
        &["strategy", "ct", "cf(batches)", "epochs", "test BCE", "test AUC"],
    );
    let mut results = Vec::new();
    for (label, ct, cf, epochs) in &grid {
        let cfg = TrainConfig {
            artifact: artifact.into(),
            epochs: *epochs,
            cluster_times: *ct,
            cluster_every: *cf,
            early_stop: *epochs > 1,
            ..Default::default()
        };
        log::info!("strategy: {label}");
        let r = cce::coordinator::train(&store, &cfg)?;
        t.row(vec![
            label.clone(),
            ct.to_string(),
            cf.to_string(),
            epochs.to_string(),
            format!("{:.5}", r.test_bce),
            format!("{:.5}", r.test_auc),
        ]);
        results.push((label.clone(), r.test_bce));
    }
    t.print();
    t.save_csv("fig9_strategies");

    let get = |needle: &str| {
        results
            .iter()
            .find(|(l, _)| l.contains(needle))
            .map(|(_, b)| *b)
            .unwrap_or(f64::NAN)
    };
    println!(
        "clustering vs none: ct2 {:.5} vs ct0 {:.5} — clustering should help: {}",
        get("strategy 1"),
        get("no clustering"),
        if get("strategy 1") <= get("no clustering") + 1e-4 { "✓" } else { "✗" }
    );
    println!(
        "rest after clustering: strategy 1 (finish 1/2) {:.5} vs strategy 2 (finish 2/3) {:.5} \
         (paper: finishing earlier is better)",
        get("strategy 1"),
        get("strategy 2")
    );
    Ok(())
}
