//! Appendix H — table-collapse entropies H₁/H₂ for random hashing (CE),
//! CCE per-column clustering, circular clustering (the collapse case), and
//! post-training PQ (the "golden midpoint" reference).
//!
//! Expected shape: CE ≈ max entropy; circular collapses H₂ → H₁; CCE and
//! PQ sit between (structure without collapse).

use cce::baselines::circular_cluster_event;
use cce::coordinator::cluster::{cluster_event, ClusterConfig};
use cce::experiments::report::Table;
use cce::kmeans::{kmeans, KmeansConfig};
use cce::metrics::entropy::{h1, h2, max_h1};
use cce::runtime::manifest::{FieldDesc, InitSpec};
use cce::tables::indexer::Indexer;
use cce::tables::layout::{SubtableId, TablePlan};
use cce::util::Rng;

fn setup(vocab: usize, k: usize, c: usize, seed: u64) -> (Vec<f32>, FieldDesc, Indexer) {
    let plan = TablePlan::new(&[vocab], k, 2, c, 4);
    let mut rng = Rng::new(seed);
    let ix = Indexer::new_rowwise(&mut rng, plan.clone());
    let size = plan.total_rows * plan.dc;
    let mut state = vec![0f32; size];
    // structured pool: rows drawn from a few prototypes so clustering has
    // something real to find (pure noise would make every method look alike)
    let mut prng = Rng::new(seed ^ 77);
    let n_protos = 24;
    let protos: Vec<f32> = (0..n_protos * plan.dc).map(|_| prng.normal() as f32).collect();
    for r in 0..plan.total_rows {
        let p = prng.below(n_protos as u64) as usize;
        for e in 0..plan.dc {
            state[r * plan.dc + e] = protos[p * plan.dc + e] + 0.1 * prng.normal() as f32;
        }
    }
    let field = FieldDesc {
        name: "pool".into(),
        shape: vec![plan.total_rows, plan.dc],
        offset: 0,
        size,
        init: InitSpec::Zeros,
        group: "pool".into(),
    };
    (state, field, ix)
}

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let (vocab, k, c) = if paper { (65_536, 256, 4) } else { (8_192, 64, 4) };
    let seed = 0u64;
    let cfg = ClusterConfig { kmeans_iters: 30, points_per_centroid: 256, seed, n_threads: 0 };
    let tables = |ix: &Indexer| -> Vec<Vec<u32>> {
        (0..c).map(|j| ix.materialize(SubtableId { feature: 0, term: 0, column: j })).collect()
    };

    let mut t = Table::new(
        &format!(
            "Appendix H — assignment entropies (vocab={vocab}, k={k}, c={c}; \
             max H1 = ln k = {:.2}, max H2 ≈ 2 ln k = {:.2})",
            max_h1(k),
            2.0 * max_h1(k)
        ),
        &["method", "H1", "H2", "H2 - H1", "diagnosis"],
    );

    // 1. random hashing (CE): near-max entropies
    let (_, _, ix) = setup(vocab, k, c, seed);
    let tb = tables(&ix);
    let (a1, a2) = (h1(&tb), h2(&tb));
    t.row(vec!["CE (random hash)".into(), format!("{a1:.3}"), format!("{a2:.3}"),
               format!("{:.3}", a2 - a1), "near max (no structure)".into()]);

    // 2. CCE per-column clustering
    let (mut s, f, mut ix) = setup(vocab, k, c, seed);
    cluster_event(&mut s, &f, &mut ix, &cfg);
    let tb = tables(&ix);
    let (b1, b2) = (h1(&tb), h2(&tb));
    t.row(vec!["CCE clustering".into(), format!("{b1:.3}"), format!("{b2:.3}"),
               format!("{:.3}", b2 - b1), "golden midpoint".into()]);

    // 3. circular clustering: H2 collapses onto H1
    let (mut s, f, mut ix) = setup(vocab, k, c, seed);
    circular_cluster_event(&mut s, &f, &mut ix, &cfg);
    let tb = tables(&ix);
    let (c1, c2) = (h1(&tb), h2(&tb));
    t.row(vec!["circular clustering".into(), format!("{c1:.3}"), format!("{c2:.3}"),
               format!("{:.3}", c2 - c1), "PAIRWISE COLLAPSE".into()]);

    // 4. PQ reference: cluster an uncompressed prototype table per column
    {
        let dc = 4;
        let mut prng = Rng::new(seed ^ 99);
        let mut full = vec![0f32; vocab * dc];
        let n_protos = 24;
        let protos: Vec<f32> = (0..n_protos * dc).map(|_| prng.normal() as f32).collect();
        for r in 0..vocab {
            let p = prng.below(n_protos as u64) as usize;
            for e in 0..dc {
                full[r * dc + e] = protos[p * dc + e] + 0.1 * prng.normal() as f32;
            }
        }
        let pq_tables: Vec<Vec<u32>> = (0..c)
            .map(|j| {
                kmeans(
                    &full,
                    dc,
                    &KmeansConfig { k, n_iter: 30, seed: seed ^ j as u64, ..Default::default() },
                )
                .assignments
            })
            .collect();
        let (d1, d2) = (h1(&pq_tables), h2(&pq_tables));
        t.row(vec!["PQ (post-training ref)".into(), format!("{d1:.3}"), format!("{d2:.3}"),
                   format!("{:.3}", d2 - d1), "reference".into()]);
    }
    t.print();
    t.save_csv("appx_h_entropy");

    assert!(c2 - c1 < 0.1, "circular clustering must show pairwise collapse");
    assert!(b2 - b1 > 0.3, "CCE must not collapse");
    assert!(a1 > max_h1(k) * 0.95, "random hashing must be near max entropy");
    println!("collapse diagnostics hold ✓");
}
