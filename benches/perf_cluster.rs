//! Perf bench for the CCE clustering event — the paper's central loop and
//! the hot path PR "flat-gather + fused parallel Lloyd" reworked
//! (§Perf log, opt L3-2). Three groups:
//!
//!   * `cluster_event` end-to-end at a kaggle-small-like shape and a
//!     terabyte-ish shape (the acceptance shape for the ≥3× target);
//!   * materialization micro: per-(t, v) `global_row` enum dispatch (the
//!     pre-rework path, re-implemented here as the baseline) vs the flat
//!     `materialize_global_into` gather-accumulate;
//!   * K-means n/k/d sweeps over the fused Lloyd;
//!   * sync vs overlapped event: per-event STALL (how long the step loop
//!     blocks), event wall time, and staleness (stand-in training steps
//!     executed between snapshot and apply), mirroring the trainer's two
//!     event paths without a PJRT session — the training-step stand-in is
//!     `Indexer::fill_rowwise` over a synthetic batch, the host work that
//!     keeps running while an overlapped event computes in the background.
//!
//! Besides the usual table/CSV, results are emitted as
//! `bench_results/BENCH_cluster.json` (schema `cce.perf_cluster.v1`) so
//! the perf trajectory of the clustering event is machine-trackable from
//! this PR on; `scripts/verify.sh` smoke-runs the bench (`--smoke`) and
//! checks the JSON is well-formed.
//!
//! Run: `cargo bench --bench perf_cluster` (no artifacts needed).

use cce::coordinator::cluster::{
    apply_cluster, cluster_event, compute_cluster, ClusterConfig, ClusterOutcome,
};
use cce::experiments::report::Table;
use cce::kmeans::{kmeans, KmeansConfig};
use cce::runtime::manifest::{FieldDesc, InitSpec};
use cce::tables::indexer::Indexer;
use cce::tables::layout::{SubtableId, TablePlan};
use cce::util::timer::{bench, TimingStats};
use cce::util::{threadpool, Json, Rng};
use std::collections::BTreeMap;
use std::time::Instant;

/// Mirrors `python/compile/specs.py::KAGGLE_SMALL_VOCABS` — inlined so the
/// bench runs without `make artifacts` (shapes only; no manifest reads).
const KAGGLE_SMALL_VOCABS: [usize; 26] = [
    3, 10, 27, 64, 120, 256, 540, 1_000, 1_450, 2_048, 3_000, 4_096, 6_000, 8_192, 10_000,
    14_000, 20_000, 27_000, 40_000, 55_000, 80_000, 120_000, 160_000, 220_000, 300_000, 420_000,
];

/// Mirrors `specs.py::TERABYTE_SIM_VOCABS`: one binary-order larger tails.
fn terabyte_sim_vocabs() -> Vec<usize> {
    KAGGLE_SMALL_VOCABS
        .iter()
        .map(|&v| if v < 1000 { v } else { (v * 4).min(1_200_000) })
        .collect()
}

fn setup_event(vocabs: &[usize], cap: usize) -> (Vec<f32>, FieldDesc, Indexer) {
    let plan = TablePlan::new(vocabs, cap, 2, 4, 4);
    let mut rng = Rng::new(0xC1);
    let indexer = Indexer::new_rowwise(&mut rng, plan.clone());
    let size = plan.total_rows * plan.dc;
    let mut state = vec![0f32; size];
    Rng::new(1).fill_normal(&mut state, 0.3);
    let field = FieldDesc {
        name: "pool".into(),
        shape: vec![plan.total_rows, plan.dc],
        offset: 0,
        size,
        init: InitSpec::Zeros,
        group: "pool".into(),
    };
    (state, field, indexer)
}

/// Time `cluster_event` over fresh (state, indexer) copies; only the event
/// itself is inside the timed region.
fn bench_event(
    vocabs: &[usize],
    cap: usize,
    cfg: &ClusterConfig,
    reps: usize,
) -> (TimingStats, ClusterOutcome) {
    let (state0, field, ix0) = setup_event(vocabs, cap);
    let mut samples = Vec::with_capacity(reps);
    let mut last = ClusterOutcome::default();
    for _ in 0..reps {
        let mut state = state0.clone();
        let mut ix = ix0.clone();
        let t0 = Instant::now();
        last = cluster_event(&mut state, &field, &mut ix, cfg);
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    (TimingStats::from_samples(samples), last)
}

fn stat_json(name: &str, s: &TimingStats, extra: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::from(name));
    m.insert("mean_ns".to_string(), Json::from(s.mean_ns));
    m.insert("std_ns".to_string(), Json::from(s.std_ns));
    m.insert("min_ns".to_string(), Json::from(s.min_ns));
    m.insert("p50_ns".to_string(), Json::from(s.p50_ns));
    m.insert("n".to_string(), Json::from(s.n));
    for (k, v) in extra {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = threadpool::default_threads();
    let mode = if smoke { ", smoke" } else { "" };
    let mut t = Table::new(
        &format!("perf — clustering events ({threads} threads{mode})"),
        &["path", "timing", "derived"],
    );
    let mut results: Vec<Json> = Vec::new();

    // ---------------- cluster_event end-to-end --------------------------
    // kmeans knobs follow TrainConfig defaults (iters=10, ppc=32); smoke
    // shrinks the vocab list and budgets so verify.sh stays fast
    let kaggle: Vec<usize> = if smoke {
        KAGGLE_SMALL_VOCABS.iter().step_by(5).copied().collect()
    } else {
        KAGGLE_SMALL_VOCABS.to_vec()
    };
    let terabyte: Vec<usize> = if smoke {
        terabyte_sim_vocabs().into_iter().step_by(7).collect()
    } else {
        terabyte_sim_vocabs()
    };
    let (cap, iters, ppc, reps) = if smoke { (256, 3, 16, 1) } else { (4096, 10, 32, 3) };
    let shapes: [(&str, &[usize], usize); 2] = [
        ("cluster_event kaggle-small", &kaggle, cap),
        ("cluster_event terabyte-ish", &terabyte, if smoke { 512 } else { 2048 }),
    ];
    for &(name, vocabs, cap) in &shapes {
        let cfg = ClusterConfig {
            kmeans_iters: iters,
            points_per_centroid: ppc,
            seed: 7,
            n_threads: 0,
        };
        let (s, out) = bench_event(vocabs, cap, &cfg, reps);
        let label = format!("{name} (cap={cap}, iters={iters}, ppc={ppc})");
        t.row(vec![
            label.clone(),
            s.display(),
            format!(
                "{} subtables; job cpu: {:.2}s gather + {:.2}s kmeans",
                out.subtables_clustered, out.materialize_secs, out.kmeans_secs
            ),
        ]);
        results.push(stat_json(
            &label,
            &s,
            vec![
                ("subtables", Json::from(out.subtables_clustered)),
                ("total_inertia", Json::from(out.total_inertia)),
                ("materialize_cpu_secs", Json::from(out.materialize_secs)),
                ("kmeans_cpu_secs", Json::from(out.kmeans_secs)),
            ],
        ));
    }

    // ---------------- materialization: dispatch vs flat gather ----------
    // the pre-rework inner loop (per-(t, v) enum dispatch through
    // `global_row`) vs the flat-gather tables the event now builds; run
    // on the largest feature of the kaggle shape, single job
    {
        let (state, _, ix) = setup_event(&kaggle, cap);
        let plan = ix.plan.clone();
        let f = (0..plan.n_features()).max_by_key(|&f| plan.vocabs[f]).unwrap();
        let (vocab, dc) = (plan.vocabs[f], plan.dc);
        let mut pts = vec![0f32; vocab * dc];
        let reps_m = if smoke { 5 } else { 20 };
        let s_dispatch = bench(2, reps_m, || {
            pts.fill(0.0);
            for term in 0..plan.t {
                let id = SubtableId { feature: f, term, column: 0 };
                for v in 0..vocab as u32 {
                    let row = ix.global_row(id, v) as usize;
                    let src = &state[row * dc..(row + 1) * dc];
                    let dst = &mut pts[v as usize * dc..(v as usize + 1) * dc];
                    for e in 0..dc {
                        dst[e] += src[e];
                    }
                }
            }
        });
        let mut gather = vec![0u32; plan.t * vocab];
        let s_flat = bench(2, reps_m, || {
            for term in 0..plan.t {
                let id = SubtableId { feature: f, term, column: 0 };
                ix.materialize_global_into(id, &mut gather[term * vocab..][..vocab]);
            }
            let (t0, t1) = gather.split_at(vocab);
            for (v, dst) in pts.chunks_exact_mut(dc).enumerate() {
                dst.copy_from_slice(&state[t0[v] as usize * dc..][..dc]);
                let src = &state[t1[v] as usize * dc..][..dc];
                for (de, &se) in dst.iter_mut().zip(src) {
                    *de += se;
                }
            }
        });
        let speedup = s_dispatch.mean_ns / s_flat.mean_ns;
        t.row(vec![
            format!("materialize DISPATCH global_row (vocab={vocab}, T=2)"),
            s_dispatch.display(),
            format!("{:.1} M row/s", (vocab * plan.t) as f64 / s_dispatch.mean_ns * 1e3),
        ]);
        t.row(vec![
            format!("materialize FLAT gather (vocab={vocab}, T=2)"),
            s_flat.display(),
            format!("{speedup:.2}x vs dispatch"),
        ]);
        results.push(stat_json(
            &format!("materialize_dispatch vocab={vocab}"),
            &s_dispatch,
            vec![],
        ));
        results.push(stat_json(
            &format!("materialize_flat_gather vocab={vocab}"),
            &s_flat,
            vec![("speedup_vs_dispatch", Json::from(speedup))],
        ));
    }

    // ---------------- K-means n/k/d sweep --------------------------------
    let sweep: Vec<(usize, usize, usize)> = if smoke {
        vec![(8_192, 256, 4)]
    } else {
        vec![(65_536, 1024, 4), (65_536, 4096, 4), (262_144, 1024, 8), (65_536, 256, 16)]
    };
    for (n, k, d) in sweep {
        let mut rng = Rng::new(2);
        let pts: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let n_iter = if smoke { 3 } else { 10 };
        let reps_k = if smoke { 1 } else { 3 };
        let mut last_iters = 0;
        let s = {
            let mut samples = Vec::with_capacity(reps_k);
            for _ in 0..reps_k {
                let t0 = Instant::now();
                let r = kmeans(&pts, d, &KmeansConfig { k, n_iter, seed: 3, ..Default::default() });
                samples.push(t0.elapsed().as_nanos() as f64);
                last_iters = r.iterations;
            }
            TimingStats::from_samples(samples)
        };
        let label = format!("kmeans n={n} k={k} d={d} ({n_iter} iters)");
        t.row(vec![
            label.clone(),
            s.display(),
            format!("{:.1} M pt·iter/s", (n * last_iters) as f64 / s.mean_ns * 1e3),
        ]);
        results.push(stat_json(&label, &s, vec![("iterations", Json::from(last_iters))]));
    }

    // ---------------- sync vs overlapped event (stall / staleness) -------
    // mirrors `coordinator::trainer`'s two event paths: the synchronous
    // path stalls the step loop for compute + apply; the overlapped path
    // stalls only for the pool snapshot and the apply while stand-in
    // training steps (`fill_rowwise` over a fixed synthetic batch — the
    // consumer-side host work) run between snapshot and apply. Rows are
    // tagged `"group": "sync_vs_overlap"` and carry stall_ns /
    // event_wall_ns / stale_steps plus the per-group-buffer wire cost
    // (event_bytes_downloaded / event_bytes_uploaded / pool_bytes /
    // full_state_bytes); scripts/verify.sh fails the JSON if those
    // fields go missing and gates the event bytes against pool_bytes.
    {
        let worker = threadpool::BackgroundWorker::new("bench-cluster");
        let ov_cap = if smoke { 256 } else { 1024 };
        let (mut state0, field, ix0) = setup_event(&kaggle, ov_cap);
        // a dense-layer tail after the pool, like a real DLRM state: the
        // event paths below must never touch (or ship) this share
        let dense_tail = 4096usize;
        state0.extend(std::iter::repeat(0.25f32).take(dense_tail));
        // per-group-buffer wire accounting, mirroring DlrmSession's
        // counter rules: sync event = 1 pool download + 1 pool upload;
        // overlapped event = 2 pool downloads (snapshot + apply's pull)
        // + 1 pool upload. The dense tail never crosses.
        let pool_bytes = field.size * 4;
        let full_state_bytes = state0.len() * 4;
        let plan = ix0.plan.clone();
        let batch = 256usize;
        let f_n = plan.n_features();
        let mut rng = Rng::new(0xBEEF);
        let cats: Vec<u32> = (0..batch * f_n)
            .map(|i| rng.below(plan.vocabs[i % f_n] as u64) as u32)
            .collect();
        let mut rows = vec![0i32; batch * f_n * plan.t * plan.c];
        let cfg =
            ClusterConfig { kmeans_iters: iters, points_per_centroid: ppc, seed: 7, n_threads: 0 };

        // sync: the stall IS the whole event
        let mut sync_stall = Vec::with_capacity(reps);
        for _ in 0..reps {
            let mut state = state0.clone();
            let mut ix = ix0.clone();
            let t0 = Instant::now();
            let computed = compute_cluster(&state[..field.size], &ix, &cfg);
            let _ = apply_cluster(&mut state[..field.size], &mut ix, computed);
            sync_stall.push(t0.elapsed().as_nanos() as f64);
        }

        // overlapped: snapshot → background compute → apply at the first
        // "step boundary" where the job is done (≥ 1 step by construction,
        // exactly like the trainer's apply-after-train_step placement)
        let mut ov_stall = Vec::with_capacity(reps);
        let mut ov_wall = Vec::with_capacity(reps);
        let mut ov_stale = Vec::with_capacity(reps);
        for _ in 0..reps {
            let mut state = state0.clone();
            let mut ix = ix0.clone();
            let t_event = Instant::now();
            let snapshot = state[..field.size].to_vec();
            let ix_snap = ix.clone();
            let cfg_bg = cfg.clone();
            let mut handle = worker.submit(move || compute_cluster(&snapshot, &ix_snap, &cfg_bg));
            let mut stall = t_event.elapsed().as_nanos() as f64; // snapshot share
            let mut steps = 0usize;
            let computed = loop {
                ix.fill_rowwise(&cats, batch, &mut rows);
                std::hint::black_box(&rows);
                steps += 1;
                if let Some(c) = handle.try_join() {
                    break c;
                }
            };
            let t_apply = Instant::now();
            let _ = apply_cluster(&mut state[..field.size], &mut ix, computed);
            stall += t_apply.elapsed().as_nanos() as f64;
            ov_stall.push(stall);
            ov_wall.push(t_event.elapsed().as_nanos() as f64);
            ov_stale.push(steps as f64);
        }

        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        let s_sync = TimingStats::from_samples(sync_stall);
        let s_ov = TimingStats::from_samples(ov_stall);
        let label_sync = format!("cluster_overlap kaggle-small sync (cap={ov_cap})");
        let label_ov = format!("cluster_overlap kaggle-small overlap (cap={ov_cap})");
        t.row(vec![
            label_sync.clone(),
            s_sync.display(),
            "stall == event (no steps between snapshot and apply)".into(),
        ]);
        t.row(vec![
            label_ov.clone(),
            s_ov.display(),
            format!(
                "{:.1}x less stall; wall {:.1} ms; {:.0} stale steps/event",
                s_sync.mean_ns / s_ov.mean_ns.max(1.0),
                mean(&ov_wall) / 1e6,
                mean(&ov_stale)
            ),
        ]);
        results.push(stat_json(
            &label_sync,
            &s_sync,
            vec![
                ("group", Json::from("sync_vs_overlap")),
                ("stall_ns", Json::from(s_sync.mean_ns)),
                ("event_wall_ns", Json::from(s_sync.mean_ns)),
                ("stale_steps", Json::from(0.0)),
                ("event_bytes_downloaded", Json::from(pool_bytes)),
                ("event_bytes_uploaded", Json::from(pool_bytes)),
                ("pool_bytes", Json::from(pool_bytes)),
                ("full_state_bytes", Json::from(full_state_bytes)),
            ],
        ));
        results.push(stat_json(
            &label_ov,
            &s_ov,
            vec![
                ("group", Json::from("sync_vs_overlap")),
                ("stall_ns", Json::from(s_ov.mean_ns)),
                ("event_wall_ns", Json::from(mean(&ov_wall))),
                ("stale_steps", Json::from(mean(&ov_stale))),
                ("event_bytes_downloaded", Json::from(2 * pool_bytes)),
                ("event_bytes_uploaded", Json::from(pool_bytes)),
                ("pool_bytes", Json::from(pool_bytes)),
                ("full_state_bytes", Json::from(full_state_bytes)),
            ],
        ));
    }

    t.print();
    t.save_csv("perf_cluster");

    // ---------------- BENCH_cluster.json ---------------------------------
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::from("cce.perf_cluster.v1"));
    doc.insert("mode".to_string(), Json::from(if smoke { "smoke" } else { "full" }));
    doc.insert("threads".to_string(), Json::from(threads));
    doc.insert("results".to_string(), Json::Arr(results));
    let dir = std::path::Path::new("bench_results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join("BENCH_cluster.json");
    std::fs::write(&path, Json::Obj(doc).to_string())?;
    println!("wrote {}", path.display());
    Ok(())
}
