//! Figure 4a — best-of-10-epochs test BCE vs parameter budget, plus the
//! full-table and post-training-PQ baselines (and Figure 10a's AUC
//! columns from the same runs).
//!
//! Scaled defaults (single-core CPU PJRT): 3 caps × 4 methods × 1 seed, ≤2 epochs
//! with the paper's early stopping. `--paper` widens to 6 caps × 3 seeds ×
//! 10 epochs. Requires `make artifacts-sweep`.
//!
//! Expected shape (paper): the FULL table overfits below the compressed
//! methods' best; CCE's curve sits left of CE/hash (same BCE at ~½ the
//! parameters); PQ can't beat the full baseline it quantizes.

use cce::config::TrainConfig;
use cce::experiments::report::Table;
use cce::experiments::sweep::{curve_for, run_sweep};
use cce::experiments::{SweepSpec};
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let paper = std::env::args().any(|a| a == "--paper");
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    let caps = if paper {
        vec![64, 256, 1024, 4096, 16384, 65536]
    } else {
        vec![64, 256]
    };
    let seeds: Vec<u64> = if paper { vec![0, 1, 2] } else { vec![0] };
    let methods: Vec<String> = if paper {
        ["hash", "ce", "cce", "dhe"].iter().map(|s| s.to_string()).collect()
    } else {
        ["hash", "ce", "cce"].iter().map(|s| s.to_string()).collect()
    };
    let base = TrainConfig {
        epochs: if paper { 10 } else { 2 },
        early_stop: true,
        cluster_times: if paper { 6 } else { 1 }, // ct6 cf=epoch in the paper
        ..Default::default()
    };
    let spec = SweepSpec {
        dataset: "kaggle_small".into(),
        methods: methods.clone(),
        caps,
        seeds,
        base: base.clone(),
    };
    let points = run_sweep(&store, &spec)?;

    // full baseline (1 seed — it is 10× the compressed runtime)
    let mut full_cfg = base.clone();
    full_cfg.artifact = spec.artifact_name("full", 0);
    full_cfg.cluster_times = 0;
    let full = if store.has(&full_cfg.artifact) {
        Some(cce::coordinator::train(&store, &full_cfg)?)
    } else {
        log::warn!("full baseline artifact missing; run `make artifacts-sweep`");
        None
    };

    // PQ of the trained full model at each budget
    let pq = if store.has(&full_cfg.artifact) {
        let ks: Vec<usize> = if paper { spec.caps.clone() } else { vec![64] };
        Some(cce::experiments::pq::pq_curve(&store, &full_cfg.artifact, &base, &ks, 4)?)
    } else {
        None
    };

    let mut t = Table::new(
        &format!(
            "Figure 4a — best-of-{}-epochs test BCE vs embedding params (kaggle_small)",
            base.epochs
        ),
        &["method", "params", "mean BCE", "min", "max", "mean AUC"],
    );
    for m in &methods {
        let curve = curve_for(&points, m);
        for (params, mean, min, max) in &curve {
            // AUC from the same points
            let aucs: Vec<f64> = points
                .iter()
                .filter(|p| &p.method == m && p.outcome.embedding_params as f64 == *params)
                .map(|p| p.outcome.test_auc)
                .collect();
            let mauc = aucs.iter().sum::<f64>() / aucs.len().max(1) as f64;
            t.row(vec![
                m.clone(),
                format!("{params:.0}"),
                format!("{mean:.5}"),
                format!("{min:.5}"),
                format!("{max:.5}"),
                format!("{mauc:.5}"),
            ]);
        }
    }
    if let Some(f) = &full {
        t.row(vec![
            "full table".into(),
            f.embedding_params.to_string(),
            format!("{:.5}", f.test_bce),
            format!("{:.5}", f.test_bce),
            format!("{:.5}", f.test_bce),
            format!("{:.5}", f.test_auc),
        ]);
    }
    if let Some((full_bce, pts)) = &pq {
        for p in pts {
            t.row(vec![
                "product quantization".into(),
                format!("{:.0}", p.params),
                format!("{:.5}", p.test_bce),
                String::new(),
                String::new(),
                format!("{:.5}", p.test_auc),
            ]);
        }
        println!("(PQ quantizes a full model with test BCE {full_bce:.5}.)");
    }
    t.print();
    t.save_csv("fig4a");

    // shape assertions from the paper
    if let Some(f) = &full {
        let best_cce = curve_for(&points, "cce")
            .iter()
            .map(|&(_, m, _, _)| m)
            .fold(f64::INFINITY, f64::min);
        println!(
            "full-table test BCE {:.5} vs best CCE {:.5} — the paper's multi-epoch \
             claim is that compressed training matches or beats the overfitting \
             full table: {}",
            f.test_bce,
            best_cce,
            if best_cce <= f.test_bce + 5e-3 { "HOLDS ✓" } else { "DID NOT REPRODUCE ✗" }
        );
    }
    if let Some((full_bce, pts)) = &pq {
        let best_pq = pts.iter().map(|p| p.test_bce).fold(f64::INFINITY, f64::min);
        println!(
            "PQ never beats its base model: best PQ {best_pq:.5} >= full {full_bce:.5} − eps: {}",
            if best_pq >= full_bce - 1e-3 { "HOLDS ✓" } else { "✗" }
        );
    }
    Ok(())
}
