//! Figure 4c — Terabyte-like dataset, 1 epoch, single repetition (the
//! paper could only afford one run per algorithm at this scale; so can
//! we). Requires `make artifacts-sweep`.
//!
//! Expected shape: same ordering as 4b, with PQ notably NOT better than
//! sketch methods on this dataset (the paper's observation), and larger
//! compression head-room from the bigger vocabularies.

use cce::config::TrainConfig;
use cce::experiments::report::Table;
use cce::experiments::sweep::{curve_for, run_sweep};
use cce::experiments::SweepSpec;
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let paper = std::env::args().any(|a| a == "--paper");
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    let caps = if paper {
        vec![64, 256, 1024, 4096, 16384, 65536]
    } else {
        vec![256]
    };
    let methods = vec!["hash".to_string(), "cce".into()];
    let n_batches = 393_216usize.div_ceil(256);
    let base = TrainConfig {
        epochs: 1,
        cluster_times: 2,
        cluster_every: n_batches / 4,
        ..Default::default()
    };
    let spec = SweepSpec {
        dataset: "terabyte_sim".into(),
        methods: methods.clone(),
        caps,
        seeds: vec![0], // single repetition, like the paper
        base,
    };
    let points = run_sweep(&store, &spec)?;

    let mut t = Table::new(
        "Figure 4c — 1 epoch, terabyte_sim (single repetition)",
        &["method", "params", "test BCE", "test AUC"],
    );
    for m in &methods {
        for p in points.iter().filter(|p| &p.method == m) {
            t.row(vec![
                m.clone(),
                p.outcome.embedding_params.to_string(),
                format!("{:.5}", p.outcome.test_bce),
                format!("{:.5}", p.outcome.test_auc),
            ]);
        }
    }
    t.print();
    t.save_csv("fig4c");

    let cce = curve_for(&points, "cce");
    let hash = curve_for(&points, "hash");
    if let (Some(c), Some(h)) = (cce.first(), hash.first()) {
        println!(
            "smallest budget: CCE {:.5} vs hash {:.5} — CCE should win: {}",
            c.1,
            h.1,
            if c.1 <= h.1 + 1e-4 { "✓" } else { "✗" }
        );
    }
    Ok(())
}
