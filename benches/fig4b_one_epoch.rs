//! Figure 4b — Kaggle-like dataset, 1 epoch (the DLRM-standard setting the
//! prior work reports). CCE clusters at 1/4 and 1/2 of the epoch (the
//! paper's `ct2 cf75000` ≈ 2 clusterings within the first half).
//!
//! Expected shape: with a single epoch the hashing-based methods can't
//! reach the baseline at small budgets, but CCE sits below CE/hash at
//! every budget (it reaches baseline at ~300× fewer parameters).

use cce::config::TrainConfig;
use cce::experiments::report::Table;
use cce::experiments::sweep::{curve_for, run_sweep};
use cce::experiments::SweepSpec;
use cce::runtime::ArtifactStore;

fn main() -> anyhow::Result<()> {
    cce::util::logger::init();
    let paper = std::env::args().any(|a| a == "--paper");
    let store = ArtifactStore::open(ArtifactStore::default_dir())?;

    let caps = if paper {
        vec![64, 256, 1024, 4096, 16384, 65536]
    } else {
        vec![64, 256]
    };
    let seeds: Vec<u64> = if paper { vec![0, 1, 2] } else { vec![0] };
    let methods =
        if paper {
        vec!["hash".to_string(), "hashemb".into(), "ce".into(), "cce".into(), "robe".into()]
    } else {
        vec!["hash".to_string(), "ce".into(), "cce".into()]
    };
    // one epoch; cluster twice, finishing by half the epoch (strategy 1)
    let n_batches = 196_608usize.div_ceil(256);
    let base = TrainConfig {
        epochs: 1,
        early_stop: false,
        cluster_times: 2,
        cluster_every: n_batches / 4,
        ..Default::default()
    };
    let spec = SweepSpec {
        dataset: "kaggle_small".into(),
        methods: methods.clone(),
        caps,
        seeds,
        base: base.clone(),
    };
    let points = run_sweep(&store, &spec)?;

    let mut full_cfg = base.clone();
    full_cfg.artifact = spec.artifact_name("full", 0);
    full_cfg.cluster_times = 0;
    let full = store
        .has(&full_cfg.artifact)
        .then(|| cce::coordinator::train(&store, &full_cfg))
        .transpose()?;

    let mut t = Table::new(
        "Figure 4b — 1 epoch, kaggle_small (CCE clusters at 1/4 and 1/2 epoch)",
        &["method", "params", "mean BCE", "min", "max"],
    );
    for m in &methods {
        for (params, mean, min, max) in curve_for(&points, m) {
            t.row(vec![
                m.clone(),
                format!("{params:.0}"),
                format!("{mean:.5}"),
                format!("{min:.5}"),
                format!("{max:.5}"),
            ]);
        }
    }
    if let Some(f) = &full {
        t.row(vec![
            "full table (baseline)".into(),
            f.embedding_params.to_string(),
            format!("{:.5}", f.test_bce),
            String::new(),
            String::new(),
        ]);
    }
    t.print();
    t.save_csv("fig4b");

    // shape: CCE dominates CE at equal budgets
    let cce = curve_for(&points, "cce");
    let ce = curve_for(&points, "ce");
    let mut wins = 0;
    let mut total = 0;
    for (c1, c2) in cce.iter().zip(&ce) {
        total += 1;
        if c1.1 <= c2.1 + 1e-4 {
            wins += 1;
        }
    }
    println!("CCE ≤ CE at {wins}/{total} budgets (paper: CCE dominates at one epoch)");
    Ok(())
}
