//! Figure 1b — CCE for least squares vs the optimal sparse factorizations.
//!
//! The paper samples X ∈ R^{10⁴×10³}, Y ∈ R^{10⁴×10}, runs (sparse) CCE,
//! and compares against factorizing the optimal solution T* with one or
//! two 1s per row of H. Default scale here is 2000×300→10 so the bench
//! finishes in seconds; pass `--paper` for the paper's shape.
//!
//! Expected shape (paper): CCE's loss decreases monotonically across
//! iterations toward the 2-nnz factorized optimum, starting from the pure
//! random-sketch loss.

use cce::cce::{optimal_loss, pq2_factorized_loss, pq_factorized_loss, sparse_cce, SparseCceOptions};
use cce::experiments::report::Table;
use cce::linalg::{lstsq, Matrix};
use cce::util::Rng;

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let (n, d1, d2, k, iters) =
        if paper { (10_000, 1_000, 10, 64, 20) } else { (2_000, 300, 10, 48, 12) };
    let mut rng = Rng::new(0);
    let x = Matrix::randn(&mut rng, n, d1);
    // clusterable ground truth (Figure 1's setting implies compressible T*)
    let protos = Matrix::randn(&mut rng, k / 2, d2);
    let mut t_true = Matrix::zeros(d1, d2);
    for i in 0..d1 {
        let p = rng.below((k / 2) as u64) as usize;
        for j in 0..d2 {
            t_true[(i, j)] = protos[(p, j)] + 0.1 * rng.normal();
        }
    }
    let y = x.matmul(&t_true).add(&Matrix::randn(&mut rng, n, d2).scale(0.5));

    let opt = optimal_loss(&x, &y);
    // "optimal 1s per row": PQ of T* with k codewords (1 nnz)
    let pq1 = pq_factorized_loss(&x, &y, k, 40, 1);
    // "2 ones per row": factorize T* with [kmeans | count-sketch] and refit
    let two_nnz_best = pq2_factorized_loss(&x, &y, k, k / 3, 40, 7);

    let run = sparse_cce(
        &x,
        &y,
        &SparseCceOptions {
            k,
            sketch_width: k / 3,
            iterations: iters,
            kmeans_iters: 40,
            signs: false,
            seed: 3,
        },
    );

    let mut t = Table::new(
        &format!("Figure 1b — CCE for least squares (X {n}x{d1}, Y {n}x{d2}, k={k})"),
        &["iteration", "CCE loss", "CCE excess over optimal"],
    );
    for (i, &l) in run.losses.iter().enumerate() {
        t.row(vec![i.to_string(), format!("{l:.4e}"), format!("{:.4e}", l - opt)]);
    }
    t.print();
    t.save_csv("fig1b_lsq");

    let mut t2 = Table::new("Figure 1b — reference lines", &["line", "loss", "excess"]);
    t2.row(vec!["optimal dense T*".into(), format!("{opt:.4e}"), "0".into()]);
    t2.row(vec![
        "optimal-ish 1 one/row (PQ of T*)".into(),
        format!("{pq1:.4e}"),
        format!("{:.4e}", pq1 - opt),
    ]);
    t2.row(vec![
        "optimal-ish 2 ones/row ([A|C] of T*)".into(),
        format!("{two_nnz_best:.4e}"),
        format!("{:.4e}", two_nnz_best - opt),
    ]);
    t2.print();
    t2.save_csv("fig1b_reference");

    // the figure's qualitative claims, asserted
    let first = run.losses[0];
    let last = *run.losses.last().unwrap();
    assert!(last < first, "CCE must improve over the initial sketch");
    assert!(pq1 >= opt);
    println!(
        "shape check: initial sketch {first:.3e} → CCE {last:.3e} → 2-nnz {two_nnz_best:.3e} \
         → 1-nnz PQ {pq1:.3e} → optimal {opt:.3e}  ✓ ordering as in Figure 1b"
    );
    let _ = lstsq(&x, &y); // keep the direct solve in the binary for profiling
}
