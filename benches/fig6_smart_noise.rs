//! Figure 6 — SVD-aligned ("smart") noise converges faster.
//!
//! Paper setup: X is a random rank-10 matrix plus low-magnitude noise;
//! 40 repetitions; four lines: noise / smart noise / half noise / half
//! smart noise ("half" = the proof's restricted M = [I | M'] update).
//! Expected shape: smart ≥ iid once the excess gets small; the half/full
//! gap is much larger for iid than for smart noise.

use cce::cce::{dense_cce, optimal_loss, DenseCceOptions, NoiseKind};
use cce::experiments::report::Table;
use cce::linalg::Matrix;
use cce::util::Rng;

fn main() {
    let paper = std::env::args().any(|a| a == "--paper");
    let (n, d1, d2, k, iters, reps) =
        if paper { (500, 120, 4, 12, 30, 40) } else { (300, 80, 4, 12, 20, 8) };

    // random rank-10 + low-magnitude noise (the paper's X)
    let mut rng = Rng::new(0);
    let b = Matrix::randn(&mut rng, n, 10);
    let c = Matrix::randn(&mut rng, 10, d1);
    let x = b.matmul(&c).add(&Matrix::randn(&mut rng, n, d1).scale(0.05));
    let y = Matrix::randn(&mut rng, n, d2);
    let opt = optimal_loss(&x, &y);

    let variants: [(&str, NoiseKind, bool); 4] = [
        ("noise", NoiseKind::Iid, false),
        ("smart noise", NoiseKind::Smart, false),
        ("half noise", NoiseKind::Iid, true),
        ("half smart noise", NoiseKind::Smart, true),
    ];
    let mut curves: Vec<Vec<f64>> = vec![vec![0.0; iters + 1]; 4];
    for (vi, (_, noise, half)) in variants.iter().enumerate() {
        for rep in 0..reps {
            let tr = dense_cce(
                &x,
                &y,
                &DenseCceOptions {
                    k,
                    iterations: iters,
                    noise: *noise,
                    half_update: *half,
                    seed: 1000 + rep as u64,
                },
            );
            for i in 0..=iters {
                curves[vi][i] += (tr.losses[i] - opt) / reps as f64;
            }
        }
    }

    let mut t = Table::new(
        &format!("Figure 6 — smart vs iid noise (rank-10 X {n}x{d1}, k={k}, {reps} reps)"),
        &["iter", "noise", "smart noise", "half noise", "half smart noise"],
    );
    for i in 0..=iters {
        t.row(vec![
            i.to_string(),
            format!("{:.4e}", curves[0][i]),
            format!("{:.4e}", curves[1][i]),
            format!("{:.4e}", curves[2][i]),
            format!("{:.4e}", curves[3][i]),
        ]);
    }
    t.print();
    t.save_csv("fig6_smart_noise");

    // the figure's two qualitative claims
    let last = |v: usize| curves[v][iters];
    println!(
        "final excess: noise {:.3e}, smart {:.3e}, half {:.3e}, half-smart {:.3e}",
        last(0), last(1), last(2), last(3)
    );
    assert!(
        last(1) <= last(0) * 1.2,
        "smart noise should converge at least as fast as iid noise"
    );
    let gap_iid = last(2) / last(0).max(1e-300);
    let gap_smart = last(3) / last(1).max(1e-300);
    println!(
        "half/full degradation: iid {gap_iid:.2}x vs smart {gap_smart:.2}x \
         (paper: the effect is much larger in the non-smart case)"
    );
}
